"""Serve a small model with batched requests (decode path demo).

Runs batched greedy generation through the sharded-cache serve step —
the same step the dry-run lowers for decode_32k / long_500k at pod scale.
"""
import argparse

from repro.launch.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else []):
        toks, tput = generate(arch, batch=args.batch, prompt_len=12,
                              gen_len=12)
        print(f"[serve] {arch}: batch {args.batch}, "
              f"{tput:.1f} tok/s, sample row: {toks[0, :8].tolist()}")


if __name__ == "__main__":
    main()
