"""End-to-end driver: the paper's geospatial statistics application.

Pipeline (paper §III-D / §V-C):
  1. generate Morton-ordered spatial locations + Matern covariance
     at three correlation regimes (weak / medium / strong),
  2. factor Sigma with the OOC MxP V3 Cholesky at several accuracy
     targets (the Fig. 10/11 sweep),
  3. evaluate the Gaussian log-likelihood through the factor and the
     KL divergence against the FP64 reference,
  4. report precision histograms, byte volumes, and modeled GH200/TPU
     makespans.

This is the amortize-once/replay-many scenario of the planner API: the
MLE sweep factors same-shape covariances over and over, so ONE compiled
FP64 solver is reused across all three regimes (schedule + jit built
exactly once — see the stats line), and the likelihood is evaluated
out-of-core through the solver's blocked substitution, never forming the
dense factor.
"""
import numpy as np

import jax
jax.config.update("jax_enable_x64", True)

import repro
from repro.geo.kl import kl_divergence_mxp
from repro.geo.likelihood import gaussian_loglik
from repro.geo.matern import (BETA_MEDIUM, BETA_STRONG, BETA_WEAK,
                              generate_locations, matern_covariance)

N = 1024
TB = 128
REGIMES = [("weak", BETA_WEAK), ("medium", BETA_MEDIUM),
           ("strong", BETA_STRONG)]
ACCURACIES = [1e-5, 1e-6, 1e-8]


def main():
    locs = generate_locations(N, seed=0)
    rng = np.random.default_rng(0)

    # one FP64 plan/executor for every regime (same shape -> same schedule)
    solver64 = repro.plan(N, tb=TB, policy="v3").compile()

    for name, beta in REGIMES:
        cov = matern_covariance(locs, sigma2=1.0, beta=beta, nu=0.5)
        # synthetic observations y ~ N(0, Sigma)
        l_true = np.linalg.cholesky(cov)
        y = l_true @ rng.standard_normal(N)

        solver64.factor(cov, materialize=False)   # factor stays tiled
        ll64 = gaussian_loglik(solver64, y)       # logdet + quad via tiles
        print(f"\n=== correlation {name} (beta={beta}) ===")
        print(f"FP64 log-likelihood: {ll64:.4f}")

        for eps in ACCURACIES:
            res = kl_divergence_mxp(cov, TB, eps, policy="v3")
            cfg = repro.CholeskyConfig(tb=TB, policy="v3",
                                       eps_target=eps).specialize(cov)
            mxp = repro.plan(N, cfg).compile()
            mxp.factor(cov, materialize=False)
            llmx = gaussian_loglik(mxp, y)
            t = mxp.simulate(repro.HW["gh200"]).makespan
            hist = {k: v for k, v in res["precision_histogram"].items()
                    if v}
            print(f"  eps={eps:7.0e}  KL={res['abs_kl']:9.3e}  "
                  f"ll={llmx:12.4f}  bytes={res['loads_bytes']/1e6:7.1f}MB  "
                  f"gh200-model={t*1e3:6.2f}ms  {hist}")

    print(f"\nFP64 solver reuse across {len(REGIMES)} regimes: "
          f"{solver64.stats}")
    assert solver64.stats["jit_traces"] == 1       # traced once, replayed
    assert solver64.stats["factor_calls"] == len(REGIMES)
    # the plan cache hands back the same schedule for the same (n, config)
    assert repro.plan(N, tb=TB, policy="v3").schedule is solver64.schedule


if __name__ == "__main__":
    main()
