"""Train a ~100M-param LM for a few hundred steps with checkpoints.

Uses the qwen3 family at a ~100M reduced width (the published 14B config
is selectable with --full on a pod).  Demonstrates the full substrate:
deterministic data pipeline, pjit-able train step, AdamW (optionally
int8-quantized moments), atomic checkpoint/resume.

  PYTHONPATH=src python examples/train_lm.py            # ~200 steps
  PYTHONPATH=src python examples/train_lm.py --resume   # restart path
"""
import argparse
import dataclasses
import os

from repro.configs import get_config
from repro.launch.train import train
from repro.models.config import ModelConfig

# ~100M params: 12L x 512d x 8H, vocab 32768
CFG_100M = ModelConfig(
    name="lm-100m", family="dense",
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
    d_ff=2048, vocab=32768,
    qk_norm=True, mlp_act="silu", scan_group=1, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m_ckpt")
    ap.add_argument("--quantized-opt", action="store_true")
    args = ap.parse_args()

    import repro.configs as C
    # register the custom config under a name train() can resolve
    import repro.configs.qwen3_14b as q
    orig = C.get_config

    def patched(name, smoke=False):
        if name == "lm-100m":
            return CFG_100M
        return orig(name, smoke)

    C.get_config = patched
    import repro.launch.train as TR
    TR.get_config = patched

    total, _ = CFG_100M.param_count()
    print(f"[example] lm-100m: {total/1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")
    _, losses = train(arch="lm-100m", smoke=False, steps=args.steps,
                      batch=args.batch, seq=args.seq, lr=3e-4,
                      ckpt_dir=args.ckpt_dir, save_every=100,
                      quantized_opt=args.quantized_opt)
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
    assert losses[-1] < losses[0], "training must reduce the loss"


if __name__ == "__main__":
    main()
