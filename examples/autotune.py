"""Autotune: let the cost model pick tb / policy / cache_slots for you.

Three levels of engagement, lowest-effort first:

  1. open config   — ``CholeskyConfig(tb=0, policy="auto")``: plan()
     resolves the open axes by exact-simulation search before building
     the schedule (datasheet preset model; deterministic, no device work).
  2. explicit campaign — ``tune.tune(n, hw=...)`` returns the full ranked
     candidate table, not just the winner.
  3. calibrated    — ``tune.calibrate()`` micro-benchmarks THIS machine
     (kernel rates per precision class, link bandwidth, overheads,
     device memory) and the same search runs on measured numbers.

Winners are memoized by hardware fingerprint; set the ``REPRO_TUNE_DB``
environment variable to persist them across processes.
"""
import numpy as np

import jax
jax.config.update("jax_enable_x64", True)

import repro
from repro import tune

HW_A100 = repro.HW["a100-pcie"]


def main():
    n = 2048

    # -- 1) fully automatic: open dimensions resolve inside plan() --------
    cfg = repro.CholeskyConfig(tb=0, policy="auto", hw="gh200")
    solver = repro.plan(n, cfg).compile()
    c = solver.config
    print(f"auto-resolved for gh200:  tb={c.tb}  policy={c.policy}  "
          f"cache_slots={c.cache_slots}")
    a = repro.random_spd(n, seed=0)
    l = solver.factor(a)
    print(f"factor through tuned plan: max|L-chol(A)| = "
          f"{np.abs(l - np.linalg.cholesky(a)).max():.2e}")

    # -- 2) explicit campaign: the ranked candidate table ------------------
    result = tune.tune(n, hw="a100-pcie", use_db=False)
    print(f"\ntop candidates on a100-pcie (of {len(result.candidates)}):")
    print(f"  {'tb':>6s} {'policy':>7s} {'slots':>6s} {'makespan':>10s} "
          f"{'TF/s':>6s} {'moved GB':>9s}")
    for cand in result.candidates[:5]:
        r = cand.row()
        print(f"  {r['tb']:6d} {r['policy']:>7s} {r['cache_slots']:6d} "
              f"{r['makespan_s']:9.4f}s {r['tflops']:6.1f} "
              f"{(r['loads_bytes'] + r['stores_bytes'])/1e9:9.2f}")
    dflt = tune.score_config(n, tune.default_config(n), HW_A100)
    print(f"  hand-picked default: tb={tune.default_config(n).tb} v3 "
          f"-> {dflt.makespan:.4f}s "
          f"({dflt.makespan / result.best.makespan:.2f}x the winner)")

    # -- 3) calibrate this machine and tune against the measurement --------
    model = tune.calibrate(tb=128, repeats=1, transfer_sizes_mb=(1, 4))
    print(f"\nmeasured model: {model.name}  (fingerprint {model.fingerprint})")
    print(f"  f64 GEMM  {model.kernel_flops['gemm']['f64']/1e9:8.1f} GFlop/s"
          f"   bf16 GEMM {model.kernel_flops['gemm']['bf16']/1e9:8.1f}")
    print(f"  h2d {model.h2d_bw/1e9:.1f} GB/s   d2h {model.d2h_bw/1e9:.1f}"
          f" GB/s   mem {model.mem_bytes/1e9:.1f} GB   "
          f"launch {model.launch_overhead*1e6:.1f} us")
    measured = tune.tune(n, hw=model, use_db=False)
    mc = measured.config
    print(f"tuned for THIS machine:   tb={mc.tb}  policy={mc.policy}  "
          f"cache_slots={mc.cache_slots}  "
          f"(predicted {measured.best.makespan:.3f}s)")

    # install the measurement as the process default: every auto config
    # from here on resolves against the real machine
    tune.set_default_hardware(model)
    resolved = tune.resolve_config(n, repro.CholeskyConfig(
        tb=0, policy="auto"))
    assert resolved == mc


if __name__ == "__main__":
    main()
