"""Quickstart: plan once, factor and solve many times.

The paper's schedule is *static*: built ahead of time, replayed per
matrix.  The public API mirrors that in two phases:

  1. ``repro.plan(n, config)``  — build the op stream + cache tables once
     for a frozen ``CholeskyConfig`` (tiling, policy, precision, memory,
     backend); plans are cached by ``(n, config)``.
  2. ``.compile()``             — jit the executor once; the returned
     ``OOCSolver`` then amortizes both across every ``factor()`` /
     ``solve()`` of that shape.
"""
import numpy as np

import jax
jax.config.update("jax_enable_x64", True)

import repro
from repro.core.tiling import random_spd


def main():
    n, tb = 1024, 128
    rng = np.random.default_rng(0)

    # -- phase 1+2: FP64 V3 plan, compiled once ---------------------------
    cfg64 = repro.CholeskyConfig(tb=tb, policy="v3")
    solver = repro.plan(n, cfg64).compile()

    # -- replay across matrices: schedule + jit are built exactly once ----
    for seed in range(3):
        l64 = solver.factor(random_spd(n, seed=seed))
    print(f"matrix {n}x{n}, tiles {tb}x{tb}")
    print(f"3 factorizations through one solver: stats={solver.stats}")

    a = random_spd(n, seed=0)
    l64 = solver.factor(a)
    err64 = np.abs(l64 - np.linalg.cholesky(a)).max()
    print(f"FP64 V3   : max|L - chol(A)| = {err64:.2e}")

    # -- the factorization is a solver: blocked triangular substitution --
    b = rng.standard_normal(n)
    x = solver.solve(b)
    print(f"solve(b)  : max|Ax - b|      = {np.abs(a @ x - b).max():.2e}")

    # -- four-precision MxP at eps_target = 1e-8 --------------------------
    # eps_target plans depend on the matrix's tile norms; specialize(a)
    # freezes the Higham-Mary plan so the MxP solver is reusable too.
    cfgmx = repro.CholeskyConfig(tb=tb, policy="v3",
                                 eps_target=1e-8).specialize(a)
    mxp = repro.plan(n, cfgmx).compile()
    lmx = mxp.factor(a)
    errmx = np.abs(lmx @ lmx.T - a).max() / np.abs(a).max()
    print(f"MxP  V3   : rel residual     = {errmx:.2e}")
    print(f"precision histogram: {cfgmx.plan.histogram()}")

    # -- exact data movement + modeled platform speedups ------------------
    v64, vmx = solver.volume(), mxp.volume()
    print(f"bytes moved  FP64: {v64['total_bytes']/1e6:8.1f} MB"
          f"   MxP: {vmx['total_bytes']/1e6:8.1f} MB"
          f"   ({v64['total_bytes']/max(vmx['total_bytes'],1):.2f}x less)")
    for hw in ("a100-pcie", "gh200", "tpu-v5e"):
        t64 = solver.simulate(repro.HW[hw]).makespan
        tmx = mxp.simulate(repro.HW[hw]).makespan
        print(f"{hw:10s} modeled speedup MxP vs FP64: {t64/tmx:5.2f}x")


if __name__ == "__main__":
    main()
