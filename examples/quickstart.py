"""Quickstart: out-of-core mixed-precision Cholesky in five lines.

Factors an SPD matrix that (conceptually) exceeds device memory by
streaming tiles through a bounded slot buffer under the static V3
schedule, with per-tile precision chosen by the Higham-Mary criterion.
"""
import numpy as np

import jax
jax.config.update("jax_enable_x64", True)

from repro.core.analytics import HW, simulate, volume_report
from repro.core.cholesky import ooc_cholesky
from repro.core.tiling import random_spd


def main():
    n, tb = 1024, 128
    a = random_spd(n, seed=0)

    # FP64 baseline (paper-faithful left-looking V3)
    l64, sched64 = ooc_cholesky(a, tb, policy="v3")
    err64 = np.abs(l64 - np.linalg.cholesky(a)).max()

    # four-precision MxP at eps_target = 1e-8
    lmx, schedmx = ooc_cholesky(a, tb, policy="v3", eps_target=1e-8)
    errmx = np.abs(lmx @ lmx.T - a).max() / np.abs(a).max()

    print(f"matrix {n}x{n}, tiles {tb}x{tb}")
    print(f"FP64 V3   : max|L - chol(A)| = {err64:.2e}")
    print(f"MxP  V3   : rel residual     = {errmx:.2e}")
    print(f"precision histogram: {schedmx.plan.histogram()}")

    v64 = volume_report(sched64)
    vmx = volume_report(schedmx)
    print(f"bytes moved  FP64: {v64['total_bytes']/1e6:8.1f} MB"
          f"   MxP: {vmx['total_bytes']/1e6:8.1f} MB"
          f"   ({v64['total_bytes']/max(vmx['total_bytes'],1):.2f}x less)")

    for hw in ("a100-pcie", "gh200", "tpu-v5e"):
        t64 = simulate(sched64, HW[hw]).makespan
        tmx = simulate(schedmx, HW[hw]).makespan
        print(f"{hw:10s} modeled speedup MxP vs FP64: {t64/tmx:5.2f}x")


if __name__ == "__main__":
    main()
