"""Reproduce the paper's policy ladder (sync/async/V1/V2/V3) on one GPU.

For a sweep of matrix sizes, prints the exact data-movement volume of
every policy (Fig. 8) and the modeled makespan/TFlop/s on the paper's
three platforms plus the TPU v5e target (Fig. 6), including the
cudaMalloc-overhead effect that makes naive async lose to V1.  Closes
with the multi-device extension (Fig. 5/9): per-device op streams with
the panel-row broadcast on a shared interconnect.

Everything runs off cached ``repro.plan`` objects — one frozen
``CholeskyConfig`` per (policy, ndev), schedules built once each.
"""
import numpy as np

import jax
jax.config.update("jax_enable_x64", True)

import repro
from repro.core.analytics import HW, ascii_trace

POLICIES = ["sync", "async", "v1", "v2", "v3"]
NT = 16          # 16x16 tiles
TB = 512         # of 512x512 -> 8192^2 matrix
N = NT * TB


def main():
    print(f"matrix {N}x{N}, tile {TB}, policies {POLICIES}\n")
    plans = {p: repro.plan(N, tb=TB, policy=p) for p in POLICIES}

    print(f"{'policy':8s} {'loads':>8s} {'C2G GB':>9s} {'G2C GB':>9s} "
          f"{'hits':>6s} {'evict':>6s}")
    for p, pl in plans.items():
        rep = pl.volume()
        print(f"{p:8s} {rep['loads']:8d} {rep['c2g_bytes']/1e9:9.2f} "
              f"{rep['g2c_bytes']/1e9:9.2f} {rep['cache_hits']:6d} "
              f"{rep['evictions']:6d}")

    for hw_name in ("a100-pcie", "h100-pcie", "gh200", "tpu-v5e"):
        hw = HW[hw_name]
        print(f"\n--- {hw_name} (modeled) ---")
        for p, pl in plans.items():
            r = pl.simulate(hw)
            print(f"{p:8s} makespan {r.makespan*1e3:8.1f} ms   "
                  f"{r.tflops:6.1f} TFlop/s   "
                  f"copy-busy {100*r.h2d_busy/r.makespan:5.1f}%")

    print("\nFig.7-style trace, GH200, V3 (o=C2G # = compute g=G2C):")
    print(ascii_trace(plans["v3"].simulate(HW["gh200"],
                                           record_timeline=True)))
    print("\nFig.7-style trace, GH200, sync:")
    print(ascii_trace(plans["sync"].simulate(HW["gh200"],
                                             record_timeline=True)))

    print("\n--- multi-device V3 (block-cyclic, Fig. 5/9; docs/multidevice.md) ---")
    print(f"{'ndev':>4s} {'grid':>6s} {'per-dev C2G GB':>15s} {'bcast GB':>9s} "
          f"{'gh200 eff':>10s} {'a100 eff':>9s}")
    def efficiency(pl, hw_name):
        r = pl.simulate(HW[hw_name])
        # MultiSimResult exposes the Fig. 9 metric directly; for one
        # device it reduces to compute-busy fraction of the makespan
        if hasattr(r, "compute_efficiency"):
            return r.compute_efficiency
        return r.compute_busy / r.makespan

    # 2D block-cyclic grids shrink the broadcast itself: the (2, 2) grid
    # at 4 devices moves ~sqrt(P) less than the 1D tile-row layout
    for ndev, grid in ((1, None), (2, None), (4, None), (4, (2, 2))):
        pl = repro.plan(N, tb=TB, policy="v3", ndev=ndev, grid=grid)
        rep = pl.volume()
        if ndev > 1:
            per_dev, bcast = rep["per_device"][0]["c2g_bytes"], rep["bcast_bytes"]
        else:
            per_dev, bcast = rep["c2g_bytes"], 0
        effs = {hw: efficiency(pl, hw) for hw in ("gh200", "a100-pcie")}
        glabel = "x".join(map(str, grid)) if grid else f"{ndev}x1"
        print(f"{ndev:4d} {glabel:>6s} {per_dev/1e9:15.2f} "
              f"{bcast/1e9:9.2f} {effs['gh200']*100:9.1f}% "
              f"{effs['a100-pcie']*100:8.1f}%")


if __name__ == "__main__":
    main()
