"""Reproduce the paper's policy ladder (sync/async/V1/V2/V3) on one GPU.

For a sweep of matrix sizes, prints the exact data-movement volume of
every policy (Fig. 8) and the modeled makespan/TFlop/s on the paper's
three platforms plus the TPU v5e target (Fig. 6), including the
cudaMalloc-overhead effect that makes naive async lose to V1.  Closes
with the multi-device extension (Fig. 5/9): per-device op streams with
the panel-row broadcast on a shared interconnect.
"""
import numpy as np

import jax
jax.config.update("jax_enable_x64", True)

from repro.core.analytics import (HW, ascii_trace, simulate, simulate_multi,
                                  volume_report, volume_report_multi)
from repro.core.schedule import build_multidevice_schedule, build_schedule

POLICIES = ["sync", "async", "v1", "v2", "v3"]
NT = 16          # 16x16 tiles
TB = 512         # of 512x512 -> 8192^2 matrix


def main():
    print(f"matrix {NT*TB}x{NT*TB}, tile {TB}, policies {POLICIES}\n")
    scheds = {p: build_schedule(NT, TB, p) for p in POLICIES}

    print(f"{'policy':8s} {'loads':>8s} {'C2G GB':>9s} {'G2C GB':>9s} "
          f"{'hits':>6s} {'evict':>6s}")
    for p, s in scheds.items():
        rep = volume_report(s)
        print(f"{p:8s} {rep['loads']:8d} {rep['c2g_bytes']/1e9:9.2f} "
              f"{rep['g2c_bytes']/1e9:9.2f} {rep['cache_hits']:6d} "
              f"{rep['evictions']:6d}")

    for hw_name in ("a100-pcie", "h100-pcie", "gh200", "tpu-v5e"):
        hw = HW[hw_name]
        print(f"\n--- {hw_name} (modeled) ---")
        for p, s in scheds.items():
            r = simulate(s, hw)
            print(f"{p:8s} makespan {r.makespan*1e3:8.1f} ms   "
                  f"{r.tflops:6.1f} TFlop/s   "
                  f"copy-busy {100*r.h2d_busy/r.makespan:5.1f}%")

    print("\nFig.7-style trace, GH200, V3 (o=C2G # = compute g=G2C):")
    r = simulate(scheds["v3"], HW["gh200"], record_timeline=True)
    print(ascii_trace(r))
    print("\nFig.7-style trace, GH200, sync:")
    r = simulate(scheds["sync"], HW["gh200"], record_timeline=True)
    print(ascii_trace(r))

    print("\n--- multi-device V3 (1D block-cyclic, Fig. 5/9) ---")
    print(f"{'ndev':>4s} {'per-dev C2G GB':>15s} {'bcast GB':>9s} "
          f"{'gh200 eff':>10s} {'a100 eff':>9s}")
    for ndev in (1, 2, 4):
        ms = build_multidevice_schedule(NT, TB, ndev, "v3")
        rep = volume_report_multi(ms)
        effs = {hw: simulate_multi(ms, HW[hw]).compute_efficiency
                for hw in ("gh200", "a100-pcie")}
        print(f"{ndev:4d} {rep['per_device'][0]['c2g_bytes']/1e9:15.2f} "
              f"{rep['bcast_bytes']/1e9:9.2f} {effs['gh200']*100:9.1f}% "
              f"{effs['a100-pcie']*100:8.1f}%")


if __name__ == "__main__":
    main()
