"""Docs gate: doctest the fenced Python blocks and verify intra-repo links.

Covers README.md and every docs/*.md page:

* every ```` ```python ```` fenced block is **executed** top to bottom
  (blocks within one file share a namespace, so a page reads as one
  script).  A block whose first line contains ``doctest: skip-run`` is
  only compiled — for snippets that are illustrative or too slow for the
  gate (e.g. live calibration).
* every relative markdown link ``[text](target)`` must resolve to a file
  or directory in the repo, and a ``#fragment`` on a markdown target
  must match a heading slug in the linked (or same) file.

Run from the repo root (CI does; tests/test_docs.py shells out to it):

    PYTHONPATH=src python tools/check_docs.py

Exit code 0 = all blocks ran and all links resolve; failures print one
line each with file/line context.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

FENCE_RE = re.compile(r"^```(\w*)\s*$")
# [text](target) — excluding images' alt text edge cases is fine here
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_RUN = "doctest: skip-run"


def doc_files() -> list[pathlib.Path]:
    return [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))


def code_blocks(path: pathlib.Path):
    """Yield (start_line, language, source) for each fenced block."""
    lang, buf, start = None, [], 0
    for ln, line in enumerate(path.read_text().splitlines(), 1):
        m = FENCE_RE.match(line.strip())
        if m and lang is None:
            lang, buf, start = m.group(1) or "", [], ln + 1
        elif line.strip() == "```" and lang is not None:
            yield start, lang, "\n".join(buf)
            lang = None
        elif lang is not None:
            buf.append(line)


def _rel(path: pathlib.Path):
    """Repo-relative display path (tests feed files outside the repo)."""
    try:
        return path.relative_to(REPO)
    except ValueError:
        return path


def heading_slugs(path: pathlib.Path) -> set[str]:
    """GitHub-style anchor slugs of a markdown file's headings."""
    slugs = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if line.strip().startswith("```"):
            in_fence = not in_fence
            continue
        if not in_fence and line.startswith("#"):
            text = line.lstrip("#").strip()
            slug = re.sub(r"[^\w\- ]", "", text).strip().lower()
            slugs.add(slug.replace(" ", "-"))
    return slugs


def check_code(files, errors: list[str]) -> int:
    ran = 0
    for path in files:
        ns: dict = {"__name__": f"doctest:{path.name}"}
        for line, lang, src in code_blocks(path):
            if lang != "python":
                continue
            rel = _rel(path)
            first = src.splitlines()[0] if src.splitlines() else ""
            try:
                code = compile(src, f"{rel}:{line}", "exec")
            except SyntaxError as e:
                errors.append(f"{rel}:{line}: syntax error in python "
                              f"block: {e}")
                continue
            if SKIP_RUN in first:
                ran += 1
                continue
            try:
                exec(code, ns)
                ran += 1
            except Exception as e:  # noqa: BLE001
                errors.append(f"{rel}:{line}: python block failed: "
                              f"{type(e).__name__}: {e}")
    return ran


def check_links(files, errors: list[str]) -> int:
    checked = 0
    for path in files:
        in_fence = False
        for ln, line in enumerate(path.read_text().splitlines(), 1):
            if line.strip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                checked += 1
                rel = _rel(path)
                base, _, frag = target.partition("#")
                dest = (path.parent / base).resolve() if base else path
                if not dest.exists():
                    errors.append(f"{rel}:{ln}: broken link -> {target}")
                    continue
                if frag and dest.suffix == ".md":
                    if frag not in heading_slugs(dest):
                        errors.append(f"{rel}:{ln}: missing anchor "
                                      f"#{frag} in {base or rel}")
    return checked


def main() -> int:
    files = doc_files()
    missing = [f for f in files if not f.exists()]
    if missing:
        print(f"missing doc files: {missing}")
        return 1
    errors: list[str] = []
    nblocks = check_code(files, errors)
    nlinks = check_links(files, errors)
    for e in errors:
        print(e)
    status = "FAILED" if errors else "ok"
    print(f"docs check {status}: {len(files)} files, {nblocks} python "
          f"blocks, {nlinks} intra-repo links, {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
