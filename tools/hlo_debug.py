"""Debug: per-computation FLOP/byte/collective breakdown of one cell.

Usage: PYTHONPATH=src python tools/hlo_debug.py <arch> <shape> [multi]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import sys
import collections

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, SHAPES
from repro.launch import specs as S, hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step, make_prefill_step, make_serve_step
from repro.distributed.sharding import activation_sharding


def compile_cell(arch, shape_name, multi=False, accum_steps=1):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi)
    params_abs, p_sh, opt_abs, opt_sh = S.train_state_shardings(cfg, mesh)
    batch_abs = S.input_specs(cfg, shape)
    batch_sh = S.batch_shardings(cfg, shape, mesh)
    rep = NamedSharding(mesh, P())
    with mesh, activation_sharding(mesh, seq_sharded=shape.name == "long_500k"):
        if shape.kind == "train":
            step = make_train_step(cfg, accum_steps=accum_steps)
            jitted = jax.jit(step, in_shardings=(p_sh, opt_sh, batch_sh),
                             out_shardings=(p_sh, opt_sh,
                                            {"loss": rep, "grad_norm": rep}),
                             donate_argnums=(0, 1))
            return jitted.lower(params_abs, opt_abs, batch_abs).compile()
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, batch_sh))
            return jitted.lower(params_abs, batch_abs).compile()
        else:
            cache_abs = S.abstract_cache(cfg, shape.global_batch,
                                         shape.seq_len, jnp.dtype(cfg.dtype))
            cache_sh = S.cache_shardings(cfg, cache_abs, mesh,
                                         seq_sharded=shape.name == "long_500k")
            serve = make_serve_step(cfg)
            if cfg.is_encdec:
                fn = lambda p, c, t, pos, enc: serve(p, c, t, pos, enc_out=enc)
                args = (params_abs, cache_abs, batch_abs["token"],
                        batch_abs["pos"], batch_abs["enc_out"])
                in_sh = (p_sh, cache_sh, batch_sh["token"], batch_sh["pos"],
                         batch_sh["enc_out"])
            else:
                fn = lambda p, c, t, pos: serve(p, c, t, pos)
                args = (params_abs, cache_abs, batch_abs["token"],
                        batch_abs["pos"])
                in_sh = (p_sh, cache_sh, batch_sh["token"], batch_sh["pos"])
            jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=(1,))
            return jitted.lower(*args).compile()


def report(text, top=20):
    parsed = hlo.parse_hlo(text)
    mult, fused = hlo._call_multipliers(parsed)
    dots = []
    by_comp = collections.Counter()
    for name, comp in parsed["comps"].items():
        m = mult.get(name, 0.0)
        if m == 0:
            continue
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                fl = m * hlo._dot_flops(op, comp)
                dots.append((fl, m, name, op.result_type, op.operands[:2]))
                by_comp[name] += fl
    dots.sort(reverse=True)
    total = sum(d[0] for d in dots)
    print(f"total dot flops {total:.4e}   (over {len(dots)} dots)")
    print("\n-- top dots --")
    for fl, m, name, rt, ops in dots[:top]:
        print(f"{fl:10.3e} m={m:6.0f} {name[:40]:40s} {rt[:40]:40s} {ops}")
    print("\n-- by computation --")
    for name, fl in by_comp.most_common(12):
        print(f"{fl:10.3e} m={mult.get(name, 0):6.0f} {name[:60]}")


if __name__ == "__main__":
    arch, shape = sys.argv[1], sys.argv[2]
    multi = len(sys.argv) > 3 and sys.argv[3] == "multi"
    compiled = compile_cell(arch, shape, multi)
    report(compiled.as_text())
