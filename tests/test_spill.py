"""Disk spill tier: FETCH/SPILL schedules, stores, executors, tuner axis.

The load-bearing claims:

* the spill post-pass is *pure bookkeeping* — a spill schedule replayed
  through the bounded host tier produces a factor **bit-identical** to
  the host-resident replay, for every policy and for multi-device
  streams;
* a matrix larger than the host-slab budget factors end-to-end against
  an on-disk :class:`repro.DiskTileStore`, matching dense LAPACK to
  fp64 round-off;
* the scheduled FETCH/SPILL byte volumes crosscheck against the
  simulator's disk lane *and* against the executed byte counters
  (the ISSUE's acceptance criterion);
* the tuner only engages the disk tier when the full tile store
  overflows the model's host memory, and honours a pinned budget.
"""
import dataclasses
import json

import numpy as np
import pytest

import repro
from repro import CholeskyConfig, DiskTileStore, HW
from repro.core.analytics import simulate, simulate_multi
from repro.core.cholesky import (SpillJaxExecutor, run_multidevice_numpy,
                                 run_schedule_numpy, run_schedule_spill)
from repro.core.schedule import (OpKind, build_multidevice_schedule,
                                 build_schedule)
from repro.core.spill import (ArrayTileStore, SpilledHostStore,
                              host_residency_at)
from repro.core.tiling import random_spd, to_tiles

_NT, _TB = 6, 16
_N = _NT * _TB


def _tiles(n=_N, seed=3):
    return to_tiles(random_spd(n, seed=seed), _TB)


# ---------------------------------------------------------------------------
# The post-pass is pure bookkeeping: spill replay == plain replay, bitwise

@pytest.mark.parametrize("policy", ["sync", "async", "v1", "v2", "v3", "v4"])
def test_spill_replay_bitwise_equals_plain(policy):
    tiles = _tiles()
    plain = run_schedule_numpy(tiles, build_schedule(_NT, _TB, policy))
    sp = run_schedule_numpy(tiles, build_schedule(_NT, _TB, policy,
                                                  host_slots=4))
    assert np.array_equal(plain, sp)


@pytest.mark.parametrize("ndev,grid", [(2, None), (4, (2, 2))])
def test_multidevice_spill_bitwise_equals_plain(ndev, grid):
    tiles = _tiles()
    plain = run_multidevice_numpy(
        tiles, build_multidevice_schedule(_NT, _TB, ndev, "v3", grid=grid))
    sp = run_multidevice_numpy(
        tiles, build_multidevice_schedule(_NT, _TB, ndev, "v3", grid=grid,
                                          host_slots=5))
    assert np.array_equal(plain, sp)


# ---------------------------------------------------------------------------
# DiskTileStore

def test_disk_store_roundtrip(tmp_path):
    tiles = _tiles()
    store = DiskTileStore.from_tiles(str(tmp_path / "t.npy"), tiles)
    store.flush()
    del store
    back = DiskTileStore.open(str(tmp_path / "t.npy"))
    assert back.nt == _NT and back.tb == _TB
    assert np.array_equal(back.to_tiles(), tiles)
    back.write_tile(1, 2, np.full((_TB, _TB), 5.0))
    assert np.array_equal(back.read_tile(1, 2), np.full((_TB, _TB), 5.0))


def test_disk_store_open_errors(tmp_path):
    with pytest.raises(FileNotFoundError):
        DiskTileStore.open(str(tmp_path / "missing.npy"))
    store = DiskTileStore.create(str(tmp_path / "t.npy"), nt=3, tb=8)
    meta = json.loads((tmp_path / "t.npy.meta.json").read_text())
    assert (meta["nt"], meta["tb"]) == (3, 8)
    store.flush()
    np.save(str(tmp_path / "bad.npy"), np.zeros((4, 4)))   # not a tile store
    with pytest.raises(ValueError, match="tile store"):
        DiskTileStore.open(str(tmp_path / "bad.npy"))


# ---------------------------------------------------------------------------
# Over-budget end-to-end: the win condition

def test_over_budget_factorization_through_disk(tmp_path):
    """144 tiles, an 8-slab host cache: the full store never fits in the
    host tier, yet the factor matches dense LAPACK to fp64 round-off and
    the executed disk traffic equals the scheduled byte volumes."""
    n, tb, host_slots = 192, 16, 8
    nt = n // tb
    a = random_spd(n, seed=11)
    sched = build_schedule(nt, tb, "v3", host_slots=host_slots)
    assert nt * nt > host_slots            # genuinely over budget
    store = DiskTileStore.from_matrix(str(tmp_path / "a.npy"), a, tb)
    host = run_schedule_spill(store, sched)
    got = np.tril(DiskTileStore.open(str(tmp_path / "a.npy")).to_array())
    ref = np.linalg.cholesky(a)
    assert np.allclose(got, ref, rtol=0, atol=1e-10 * np.abs(ref).max())
    # executed counters == scheduled volumes == what the simulator bills
    assert host.fetched_bytes == sched.fetch_bytes()
    assert host.spilled_bytes == sched.spill_bytes()
    assert host.fetched_bytes > 0 and host.spilled_bytes > 0


# ---------------------------------------------------------------------------
# SpilledHostStore contracts + static residency reconstruction

def test_host_store_errors(tmp_path):
    disk = DiskTileStore.create(str(tmp_path / "t.npy"), nt=2, tb=4)
    with pytest.raises(ValueError, match="host_slots"):
        SpilledHostStore(disk, 0)
    host = SpilledHostStore(disk, 2)
    with pytest.raises(KeyError, match=r"tile \(1, 1\) is not host-resident"):
        host[1, 1]


def test_residency_reconstruction_matches_replay():
    sched = build_schedule(_NT, _TB, "v3", host_slots=4)
    host = run_schedule_spill(ArrayTileStore(_tiles()), sched)
    assert host_residency_at(sched.ops, len(sched.ops)) == host.where
    # a strict prefix reconstructs too (the restart path's actual use)
    mid = len(sched.ops) // 2
    res = host_residency_at(sched.ops, mid)
    assert all(0 <= s < 4 for s in res.values())
    assert len(set(res.values())) == len(res)      # slabs are distinct


def test_digest_folds_host_slots():
    base = build_schedule(_NT, _TB, "v3")
    s4 = build_schedule(_NT, _TB, "v3", host_slots=4)
    s5 = build_schedule(_NT, _TB, "v3", host_slots=5)
    assert base.digest() == build_schedule(_NT, _TB, "v3").digest()
    assert len({base.digest(), s4.digest(), s5.digest()}) == 3
    m0 = build_multidevice_schedule(_NT, _TB, 2, "v3")
    m4 = build_multidevice_schedule(_NT, _TB, 2, "v3", host_slots=4)
    assert m0.digest() != m4.digest()


def test_builder_rejects_spill_with_lookahead():
    with pytest.raises(ValueError, match="lookahead"):
        build_multidevice_schedule(_NT, _TB, 2, "v3", lookahead=1,
                                   host_slots=4)


# ---------------------------------------------------------------------------
# Simulator disk lane: the scheduled-vs-simulated crosscheck

def test_simulator_disk_lane_crosschecks_schedule():
    hw = HW["gh200"]
    plain = build_schedule(_NT, _TB, "v3")
    sp = build_schedule(_NT, _TB, "v3", host_slots=4)
    r0, r1 = simulate(plain, hw), simulate(sp, hw)
    assert (r0.fetch_bytes, r0.spill_bytes, r0.disk_busy) == (0, 0, 0.0)
    assert r1.fetch_bytes == sp.fetch_bytes() > 0
    assert r1.spill_bytes == sp.spill_bytes() > 0
    assert r1.disk_busy > 0
    assert r1.makespan >= r0.makespan      # the tier is never free


@pytest.mark.parametrize("ndev,grid", [(2, None), (4, (2, 2))])
def test_simulator_disk_lane_multi(ndev, grid):
    hw = HW["gh200"]
    msched = build_multidevice_schedule(_NT, _TB, ndev, "v3", grid=grid,
                                        host_slots=5)
    r = simulate_multi(msched, hw)
    assert r.fetch_bytes == msched.fetch_bytes() > 0
    assert r.spill_bytes == msched.spill_bytes() > 0
    assert r.disk_busy > 0


def test_volume_report_gains_disk_lane():
    sp = build_schedule(_NT, _TB, "v3", host_slots=4)
    rep = repro.volume_report(sp)
    assert rep["host_slots"] == 4
    assert rep["fetch_bytes"] == sp.fetch_bytes()
    assert rep["spill_bytes"] == sp.spill_bytes()
    assert rep["host_bytes"] == 8 * 4 * _TB * _TB
    assert "host_slots" not in repro.volume_report(
        build_schedule(_NT, _TB, "v3"))


# ---------------------------------------------------------------------------
# JAX executor over the disk tier

def test_spill_jax_executor_matches_numpy(tmp_path):
    tiles = _tiles()
    sched = build_schedule(_NT, _TB, "v3", host_slots=4)
    ref = run_schedule_numpy(tiles, sched)
    ex = SpillJaxExecutor(sched)
    out = ex(tiles)
    assert np.allclose(out, ref, rtol=0, atol=1e-12)
    traces = ex.jit_traces
    assert traces > 0
    out2 = ex(_tiles(seed=9))
    assert ex.jit_traces == traces         # segments retrace nothing
    assert np.allclose(out2, run_schedule_numpy(_tiles(seed=9), sched),
                       rtol=0, atol=1e-12)
    # and straight off a disk store, in place
    store = DiskTileStore.from_tiles(str(tmp_path / "t.npy"), tiles)
    ex.run_store(store)
    assert np.allclose(store.to_tiles(), ref, rtol=0, atol=1e-12)


def test_make_jax_executor_rejects_spill_schedules():
    from repro.core.cholesky import make_jax_executor
    with pytest.raises(ValueError, match="spill"):
        make_jax_executor(build_schedule(_NT, _TB, "v3", host_slots=4))


# ---------------------------------------------------------------------------
# Planner API integration

def test_plan_factor_through_spill_numpy():
    a = random_spd(_N, seed=5)
    solver = repro.plan(_N, CholeskyConfig(tb=_TB, policy="v3", host_slots=4,
                                           backend="numpy")).compile()
    l = solver.factor(a)
    assert np.allclose(np.tril(l), np.linalg.cholesky(a), atol=1e-10)
    v = solver.volume()
    assert v["fetch_bytes"] > 0 and v["spill_bytes"] > 0
    r = solver.simulate(HW["gh200"])
    assert r.fetch_bytes == v["fetch_bytes"]


def test_plan_factor_through_spill_jax():
    a = random_spd(_N, seed=5)
    solver = repro.plan(_N, CholeskyConfig(tb=_TB, policy="v3", host_slots=4,
                                           backend="jax")).compile()
    l = solver.factor(a)
    assert np.allclose(np.tril(l), np.linalg.cholesky(a), atol=1e-10)
    assert solver.stats["jit_traces"] > 0


def test_config_validation_and_backend_resolution():
    with pytest.raises(ValueError, match="host_slots must be >= 0"):
        CholeskyConfig(tb=_TB, host_slots=-1)
    with pytest.raises(ValueError, match="lookahead"):
        CholeskyConfig(tb=_TB, ndev=2, host_slots=4, lookahead=1)
    with pytest.raises(ValueError, match="NumPy replay"):
        CholeskyConfig(tb=_TB, ndev=2, host_slots=4, backend="jax")
    auto = CholeskyConfig(tb=_TB, ndev=2, host_slots=4)
    assert auto.resolved_backend() == "numpy"
    assert CholeskyConfig(tb=_TB, host_slots=4).resolved_backend() == "jax"


def test_multidevice_plan_spill_factor():
    a = random_spd(_N, seed=6)
    solver = repro.plan(_N, CholeskyConfig(tb=_TB, policy="v3", ndev=2,
                                           host_slots=5)).compile()
    l = solver.factor(a)
    assert np.allclose(np.tril(l), np.linalg.cholesky(a), atol=1e-10)


# ---------------------------------------------------------------------------
# Tuner: the host_slots axis

def test_host_slot_candidates_engage_only_when_over_budget():
    from repro.tune.search import host_slot_candidates
    roomy = HW["gh200"]
    assert host_slot_candidates(_NT, _TB, roomy) == [0]
    tight = dataclasses.replace(roomy, host_mem_bytes=40_000.0)
    cands = host_slot_candidates(_NT, _TB, tight)
    assert cands and all(c > 0 for c in cands)
    assert max(cands) <= tight.max_host_slots(_TB)


def test_search_engages_spill_under_tight_host_memory():
    from repro.tune.search import is_feasible, search
    tight = dataclasses.replace(HW["gh200"], host_mem_bytes=40_000.0)
    base = CholeskyConfig(tb=_TB, policy="v3")
    assert not is_feasible(_N, base, tight)        # store overflows host
    result = search(_N, tight, base)
    win = result.config
    assert win.host_slots > 0
    assert is_feasible(_N, win, tight)
    assert result.best.fetch_bytes > 0


def test_search_honours_pinned_host_slots():
    from repro.tune.search import search
    result = search(_N, HW["gh200"],
                    CholeskyConfig(tb=_TB, policy="v3", host_slots=12))
    assert result.config.host_slots == 12
    # and an unconstrained search on a roomy model stays host-resident
    open_r = search(_N, HW["gh200"], CholeskyConfig(tb=_TB, policy="v3"))
    assert open_r.config.host_slots == 0


def test_hostio_ops_do_not_inflate_device_slots():
    plain = build_schedule(_NT, _TB, "v3")
    sp = build_schedule(_NT, _TB, "v3", host_slots=4)
    dev_ops = [op for op in sp.ops
               if op.kind not in (OpKind.FETCH, OpKind.SPILL)]
    assert [(o.kind, o.i, o.j, o.k) for o in dev_ops] == \
        [(o.kind, o.i, o.j, o.k) for o in plain.ops]
