"""Hypothesis compatibility shim for the property-test modules.

``hypothesis`` is an *optional* dev dependency (see pyproject.toml).  When
it is installed the real ``given``/``settings``/``strategies`` are
re-exported unchanged; when it is absent the property sweeps degrade to
deterministic fixed-seed sampling so that ``pytest -x -q`` still collects
and exercises every property (with less adversarial coverage — no
shrinking, no example database).
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to fixed-seed parametrized sweeps
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def draw(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                rng = random.Random(0)
                for _ in range(n):
                    drawn = {name: s.draw(rng)
                             for name, s in strategies.items()}
                    fn(*args, **dict(kwargs, **drawn))

            # pytest must not mistake the drawn parameters for fixtures
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper

        return deco
