"""repro.obs: measured tracing, drift analysis, refine, metrics.

The load-bearing claims:

* a traced ``factor()`` records **exactly one span per executed op, in
  dispatch order**, on every executor — numpy and jax, single- and
  multi-device, spilled and in-core — and the traced result still
  matches dense LAPACK;
* the ``NullRecorder`` default is free: bit-identical output through
  the unchanged jitted path, ``jit_traces`` unmoved;
* ``drift_report`` aligns the measured trace positionally against the
  event simulator (and refuses misaligned or lossy inputs);
* ``tune.calibrate(refine_from=trace)`` returns a measured
  ``HardwareModel`` whose re-simulation predicts the same trace
  strictly better than the base model;
* the process-wide metrics registry absorbs counters and pull sources
  under one ``snapshot()`` / ``render_text()``.
"""
import json

import numpy as np
import pytest

import repro
from repro import CholeskyConfig
from repro.core import api
from repro.core.analytics import HW, simulate, simulate_multi
from repro.obs import (NULL, MODELED_KINDS, MetricsRegistry, NullRecorder,
                       TraceRecorder, chrome_trace_measured, drift_report,
                       total_abs_error, trace_view, write_jsonl)
from repro.tune import refine_from_trace

_N, _TB = 192, 48


def _spd(n=_N, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n))
    return a @ a.T + n * np.eye(n)


def _jax_devices() -> int:
    import jax
    return jax.device_count()


def _factor_traced(cfg, a=None):
    a = _spd() if a is None else a
    plan = api.plan(a.shape[0], cfg)
    rec = TraceRecorder()
    l = plan.compile().factor(a, trace=rec)
    return plan, rec, l


# ---------------------------------------------------------------------------
# span contracts: one span per executed op, every executor
# ---------------------------------------------------------------------------

_NUMPY_VARIANTS = [
    ("numpy-single", CholeskyConfig(tb=_TB, policy="v3", backend="numpy")),
    ("numpy-spill", CholeskyConfig(tb=_TB, policy="v3", backend="numpy",
                                   host_slots=8)),
    ("numpy-ndev2", CholeskyConfig(tb=_TB, policy="v3", backend="numpy",
                                   ndev=2)),
    ("numpy-ndev2-spill", CholeskyConfig(tb=_TB, policy="v3",
                                         backend="numpy", ndev=2,
                                         host_slots=8)),
    ("numpy-ndev2-lookahead", CholeskyConfig(tb=_TB, policy="v3",
                                             backend="numpy", ndev=2,
                                             lookahead=1)),
]


@pytest.mark.parametrize("label,cfg", _NUMPY_VARIANTS,
                         ids=[v[0] for v in _NUMPY_VARIANTS])
def test_numpy_executors_one_span_per_op(label, cfg):
    a = _spd()
    plan, rec, l = _factor_traced(cfg, a)
    ops = (plan.single_schedule().ops if cfg.ndev == 1
           else [op for _, op in plan.schedule.iter_dispatch_order()])
    assert len(rec.spans) == len(ops)
    assert rec.dropped == 0
    # dispatch order, monotone indices, sane clocks
    assert [s.op_index for s in rec.spans] == list(range(len(ops)))
    assert all(s.t_end >= s.t_start for s in rec.spans)
    assert np.abs(l - np.linalg.cholesky(a)).max() < 1e-10


def test_jax_single_device_one_span_per_op():
    a = _spd()
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="jax")
    plan, rec, l = _factor_traced(cfg, a)
    ops = plan.single_schedule().ops
    assert len(rec.spans) == len(ops)
    assert [s.op_index for s in rec.spans] == list(range(len(ops)))
    # spans carry the op identity the drift report aligns on
    for s, op in zip(rec.spans, ops):
        assert s.kind == op.kind.value
    assert np.abs(l - np.linalg.cholesky(a)).max() < 1e-10
    # run metadata stamped for export/refine
    assert rec.meta["n"] == _N and rec.meta["tb"] == _TB
    assert rec.makespan_s() > 0


def test_jax_spill_one_span_per_op():
    a = _spd()
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="jax", host_slots=8)
    plan, rec, l = _factor_traced(cfg, a)
    ops = plan.single_schedule().ops
    assert len(rec.spans) == len(ops)
    kinds = {s.kind for s in rec.spans}
    assert "fetch" in kinds and "spill" in kinds
    assert np.abs(l - np.linalg.cholesky(a)).max() < 1e-10


@pytest.mark.skipif("_jax_devices() < 2",
                    reason="needs >= 2 jax devices (forced host devices)")
@pytest.mark.parametrize("lookahead", [0, 1])
def test_jax_multidevice_one_span_per_op(lookahead):
    a = _spd()
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="jax", ndev=2,
                         lookahead=lookahead or None)
    plan, rec, l = _factor_traced(cfg, a)
    ops = [op for _, op in plan.schedule.iter_dispatch_order()]
    assert len(rec.spans) == len(ops)
    assert rec.meta["ndev"] == 2
    assert rec.meta["lookahead"] == lookahead
    assert {s.device for s in rec.spans} == {0, 1}
    assert np.abs(l - np.linalg.cholesky(a)).max() < 1e-10


def test_null_recorder_is_free_and_bit_identical():
    a = _spd()
    solver = api.plan(_N, CholeskyConfig(tb=_TB, policy="v3",
                                         backend="jax")).compile()
    base = solver.factor(a)
    traces0 = solver.stats["jit_traces"]
    null = NullRecorder()
    again = solver.factor(a, trace=null)
    assert np.array_equal(base, again)          # bit-identical, same path
    assert solver.stats["jit_traces"] == traces0   # no retrace
    assert len(null.spans) == 0 and not null.active
    assert np.array_equal(solver.factor(a, trace=NULL), base)


def test_ring_buffer_overflow_counts_drops():
    a = _spd()
    rec = TraceRecorder(capacity=4)
    plan = api.plan(_N, CholeskyConfig(tb=_TB, policy="v3",
                                       backend="numpy"))
    plan.compile().factor(a, trace=rec)
    assert len(rec.spans) == 4
    assert rec.dropped == len(plan.single_schedule().ops) - 4
    # a lossy trace cannot be drift-analyzed — refuse, don't misalign
    with pytest.raises(ValueError, match="dropped"):
        drift_report(rec, plan.simulate(HW["a100-pcie"],
                                        record_timeline=True))


# ---------------------------------------------------------------------------
# drift: positional alignment against the simulator
# ---------------------------------------------------------------------------

def test_drift_report_aligns_and_summarizes():
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="jax")
    plan, rec, _ = _factor_traced(cfg)
    predicted = plan.simulate(HW["a100-pcie"], record_timeline=True)
    rep = drift_report(rec, predicted)
    assert rep.nops > 0
    assert set(rep.per_kind) <= MODELED_KINDS
    assert rep.per_kind["gemm"]["count"] > 0
    for stats in rep.per_kind.values():
        assert stats["measured_s"] > 0 and stats["predicted_s"] > 0
        assert stats["ratio"] == pytest.approx(
            stats["measured_s"] / stats["predicted_s"])
    assert rep.total_abs_error > 0
    assert rep.makespan_ratio == pytest.approx(
        rep.measured_makespan / rep.predicted_makespan)
    assert len(rep.top_mispredicted) > 0
    worst = rep.top_mispredicted[0]["abs_error_s"]
    assert all(e["abs_error_s"] <= worst for e in rep.top_mispredicted)
    # fenced per-op execution serializes the overlap by construction
    assert rep.measured_overlap_efficiency == pytest.approx(0.0, abs=0.05)
    assert "drift" in rep.summary()


def test_drift_refuses_misaligned_schedule():
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="numpy")
    _, rec, _ = _factor_traced(cfg)
    other = api.plan(_N, CholeskyConfig(tb=_TB, policy="sync",
                                        backend="numpy"))
    with pytest.raises(ValueError):
        drift_report(rec, other.simulate(HW["a100-pcie"],
                                         record_timeline=True))


def test_refine_from_trace_reduces_error():
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="jax")
    plan, rec, _ = _factor_traced(cfg)
    base = HW["a100-pcie"]
    err0 = total_abs_error(rec, plan.simulate(base, record_timeline=True))
    refined = repro.tune.calibrate(refine_from=rec)
    assert refined.source == "measured"
    err1 = total_abs_error(rec, plan.simulate(refined,
                                              record_timeline=True))
    assert err1 < err0
    # explicit base + name are honored
    named = refine_from_trace(rec, base=HW["h100-pcie"], name="this-box")
    assert named.name == "this-box" and named.source == "measured"
    # refuse traces that cannot parameterize a model
    with pytest.raises(ValueError, match="empty"):
        refine_from_trace(TraceRecorder())
    bare = TraceRecorder()
    bare.record(0, "gemm", 0, 0, 10**6, 0)
    with pytest.raises(ValueError, match="tb"):
        refine_from_trace(bare)


# ---------------------------------------------------------------------------
# export: chrome lanes + jsonl
# ---------------------------------------------------------------------------

def test_chrome_trace_measured_single_device(tmp_path):
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="jax", host_slots=8)
    _, rec, _ = _factor_traced(cfg)
    path = tmp_path / "run.trace.json"
    trace = chrome_trace_measured(rec, path)
    on_disk = json.loads(path.read_text())
    assert on_disk["traceEvents"] == trace["traceEvents"]
    lanes = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M"}
    assert {"h2d", "cmp", "d2h", "dsk"} <= lanes
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == len(rec.spans)
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    with pytest.raises(ValueError, match="empty"):
        chrome_trace_measured(TraceRecorder())


def test_write_jsonl_round_trips(tmp_path):
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="numpy")
    _, rec, _ = _factor_traced(cfg)
    path = tmp_path / "run.jsonl"
    n = write_jsonl(rec, path)
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    header, rows = lines[0], lines[1:]
    assert header["event"] == "meta" and header["spans"] == n
    assert len(rows) == n == len(rec.spans)
    assert rows[0]["kind"] == rec.spans[0].kind


def test_trace_view_is_simulator_shaped():
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="numpy")
    _, rec, _ = _factor_traced(cfg)
    view = trace_view(rec)
    assert view.makespan == pytest.approx(rec.makespan_s())
    engines = {e for e, *_ in view.timeline}
    assert engines == {"h2d", "cmp", "d2h"}
    # rebased to t0, seconds
    assert min(s for _, s, *_ in view.timeline) == pytest.approx(0.0)
    assert view.tflops > 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_registry_counters_and_sources():
    reg = MetricsRegistry()
    reg.inc("x.calls")
    reg.inc("x.calls", 2)
    reg.set_gauge("x.depth", 7)
    reg.register_source("good", lambda: {"a": 1, "b": {"c": 2}})
    reg.register_source("bad", lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["counters"]["x.calls"] == 3
    assert snap["gauges"]["x.depth"] == 7
    assert snap["sources"]["good"] == {"a": 1, "b": {"c": 2}}
    assert "error" in snap["sources"]["bad"]
    text = reg.render_text()
    assert "x.calls 3" in text and "good.b.c 2" in text
    # fn-matched unregister: a stranger's fn does not evict the source
    reg.unregister_source("good", fn=lambda: None)
    assert "good" in reg.snapshot()["sources"]
    reg.unregister_source("good")
    reg.unregister_source("bad")
    assert reg.snapshot()["sources"] == {}


def test_global_registry_absorbs_solver_counters():
    from repro import obs
    before = obs.snapshot()["counters"].get("repro.factor.calls", 0)
    solver = api.plan(_N, CholeskyConfig(tb=_TB, policy="v3",
                                         backend="numpy")).compile()
    solver.factor(_spd())
    snap = obs.snapshot()
    assert snap["counters"]["repro.factor.calls"] == before + 1
    assert snap["counters"]["repro.factor.h2d_bytes"] > 0
    assert "plan_cache" in snap["sources"]
    assert "hits" in snap["sources"]["plan_cache"]
    assert "repro.factor.calls" in obs.render_text()


def test_serve_registers_metrics_source():
    from repro import obs
    from repro.serve import SolverService
    with SolverService(workers=1) as svc:
        assert "serve" in obs.snapshot()["sources"]
        snap = svc.metrics.snapshot()
        # empty window: percentiles read as "no data", not zero latency
        assert snap["latency_s"]["p50"] is None
        assert snap["latency_s"]["mean"] is None
    assert "serve" not in obs.snapshot()["sources"]


# ---------------------------------------------------------------------------
# stats unification
# ---------------------------------------------------------------------------

def test_stats_transfers_single_device():
    plan = api.plan(_N, CholeskyConfig(tb=_TB, policy="v3", backend="jax"))
    solver = plan.compile()
    solver.factor(_spd())
    t = solver.stats["transfers"]
    sched = plan.single_schedule()
    assert t["h2d_bytes"] == sched.loads_bytes()
    assert t["d2h_bytes"] == sched.stores_bytes()
    assert t["loads"] > 0 and t["stores"] > 0


def test_stats_transfers_spill_counters():
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="jax", host_slots=8)
    plan = api.plan(_N, cfg)
    solver = plan.compile()
    solver.factor(_spd())
    t = solver.stats["transfers"]
    assert t["scheduled_fetch_bytes"] == plan.schedule.fetch_bytes()
    assert t["scheduled_spill_bytes"] == plan.schedule.spill_bytes()
    # executed counters folded in from the spill executor
    assert t["fetched_bytes"] == plan.schedule.fetch_bytes()
    assert t["spilled_bytes"] == plan.schedule.spill_bytes()
    assert t["fetch_ops"] > 0 and t["spill_ops"] > 0


def test_stats_transfers_multidevice_numpy_spill():
    cfg = CholeskyConfig(tb=_TB, policy="v3", backend="numpy", ndev=2,
                         host_slots=8)
    plan = api.plan(_N, cfg)
    solver = plan.compile()
    solver.factor(_spd())
    t = solver.stats["transfers"]
    assert t["fetched_bytes"] == plan.schedule.fetch_bytes()
    assert t["spilled_bytes"] == plan.schedule.spill_bytes()
    assert t["bcast_bytes"] == plan.schedule.bcast_bytes()
