"""Byte-volume analytics (Fig. 8/12) + the three-engine event simulator."""
import numpy as np
import pytest

from repro.core.analytics import HW, ascii_trace, simulate, volume_report
from repro.core.precision import assign_precision
from repro.core.schedule import OpKind, build_schedule


def test_sync_volume_closed_form():
    """sync: every task loads its operands and stores its output."""
    nt, tb = 6, 16
    sched = build_schedule(nt, tb, "sync")
    tile = 8 * tb * tb
    loads = stores = 0
    for k in range(nt):
        loads += k * 2 + 1          # SYRK sweeps + POTRF load
        stores += k + 1             # SYRK stores + POTRF store
        m = nt - 1 - k
        loads += m * (3 * k + 2)    # GEMM triples + TRSM pair
        stores += m * (k + 1)
    assert sched.loads_bytes() == loads * tile
    assert sched.stores_bytes() == stores * tile


def test_v1_volume_closed_form():
    """V1: accumulator in residence -> loads = operands only + one C."""
    nt, tb = 6, 16
    sched = build_schedule(nt, tb, "v1")
    tile = 8 * tb * tb
    loads = 0
    for k in range(nt):
        loads += 1 + k                       # C + SYRK operands
        loads += (nt - 1 - k) * (1 + 2 * k + 1)  # C + GEMM pairs + diag
    assert sched.loads_bytes() == loads * tile
    # stores = one per lower tile (final state only)
    assert sched.stores_bytes() == tile * nt * (nt + 1) // 2


def test_volume_report_consistency():
    sched = build_schedule(8, 32, "v2")
    rep = volume_report(sched)
    assert rep["total_bytes"] == rep["c2g_bytes"] + rep["g2c_bytes"]
    assert rep["loads"] == sched.count(OpKind.LOAD)
    assert rep["matrix_bytes"] == 8 * (8 * 32) ** 2


def test_simulator_invariants():
    sched = build_schedule(8, 64, "v3")
    for hw in HW.values():
        res = simulate(sched, hw)
        assert res.makespan >= res.compute_busy - 1e-9
        assert res.makespan >= res.h2d_busy - 1e-9
        assert res.h2d_bytes == sched.loads_bytes()
        assert res.d2h_bytes == sched.stores_bytes()
        assert res.tflops > 0


def test_sync_slower_than_async():
    """Overlap (multi-stream) must beat serialized transfers once tiles
    are large enough that transfer time dominates the malloc overhead
    (at tiny tiles the paper itself observes async losing - Fig. 6)."""
    s_sync = build_schedule(8, 1024, "sync")
    s_async = build_schedule(8, 1024, "async")
    hw = HW["h100-pcie"]
    assert simulate(s_async, hw).makespan < simulate(s_sync, hw).makespan


def test_async_malloc_overhead_hurts_small_tiles():
    """Paper Fig. 6 discussion: per-task cudaMalloc/free makes async lose
    to the cache-table versions at small tile sizes."""
    hw = HW["h100-pcie"]
    t_async = simulate(build_schedule(8, 64, "async"), hw).makespan
    t_v1 = simulate(build_schedule(8, 64, "v1"), hw).makespan
    assert t_v1 < t_async


def test_v3_fastest_on_slow_interconnect():
    """Paper Fig. 6: on PCIe-class links the cache hierarchy V1<V2<=V3
    strictly dominates the no-cache async version."""
    hw = HW["a100-pcie"]
    times = {p: simulate(build_schedule(12, 64, p), hw).makespan
             for p in ("async", "v1", "v2", "v3")}
    assert times["v3"] <= times["v2"] <= times["v1"] < times["async"]


def test_mxp_moves_fewer_bytes_and_runs_faster():
    """Fig. 11/12: low precision reduces both volume and makespan."""
    nt, tb = 8, 64
    rng = np.random.default_rng(0)
    norms = np.abs(rng.standard_normal((nt, nt))) * 1e-6
    norms[np.diag_indices(nt)] = 10.0
    total = float(np.sqrt((norms ** 2).sum()))
    plan = assign_precision(norms, total, 1e-5)
    mxp = build_schedule(nt, tb, "v3", plan=plan)
    f64 = build_schedule(nt, tb, "v3")
    hw = HW["gh200"]
    assert mxp.loads_bytes() < f64.loads_bytes()
    assert simulate(mxp, hw).makespan < simulate(f64, hw).makespan


def test_load_waits_for_pending_store_war_hazard():
    """Regression: in overlap mode a LOAD into a slot must wait until a
    pending STORE has finished *reading* that slot.  Schedule: load slot 0
    (1 unit), store slot 0 (3 units on the D2H engine), reload slot 0
    (1 unit).  Without WAR tracking the reload lands at t=2 while the
    store drains until t=4; with it the reload starts at t=4."""
    from repro.core.precision import uniform_plan
    from repro.core.schedule import Op, Schedule

    tb = 1024
    plan = uniform_plan(1)
    unit = 8 * tb * tb                        # bytes moved in one "unit"
    ops = [
        Op(OpKind.LOAD, i=0, j=0, slot_c=0, bytes=unit, k=0),
        Op(OpKind.STORE, i=0, j=0, slot_c=0, bytes=3 * unit, k=0),
        Op(OpKind.LOAD, i=0, j=0, slot_c=0, bytes=unit, k=0),
    ]
    sched = Schedule(ops, nt=1, tb=tb, policy="v1", cache_slots=1, plan=plan)
    hw = HW["a100-pcie"]                      # h2d_bw == d2h_bw
    t_unit = unit / hw.h2d_bw
    res = simulate(sched, hw)
    # load [0,1], store [1,4], reload [4,5] — hazard-free replay
    assert res.makespan == pytest.approx(5 * t_unit, rel=1e-9)


def test_compute_write_waits_for_pending_store():
    """A compute op writing a slot whose previous value a STORE is still
    draining must also stall (same WAR class, compute engine side)."""
    from repro.core.precision import uniform_plan
    from repro.core.schedule import Op, Schedule

    tb = 1024
    plan = uniform_plan(1)
    unit = 8 * tb * tb
    ops = [
        Op(OpKind.LOAD, i=0, j=0, slot_c=0, bytes=unit, k=0),
        Op(OpKind.STORE, i=0, j=0, slot_c=0, bytes=3 * unit, k=0),
        Op(OpKind.POTRF, slot_c=0, k=0),
    ]
    sched = Schedule(ops, nt=1, tb=tb, policy="v1", cache_slots=1, plan=plan)
    hw = HW["a100-pcie"]
    t_unit = unit / hw.h2d_bw
    res = simulate(sched, hw)
    # POTRF may only start once the store finishes at t = 4 units
    assert res.makespan >= 4 * t_unit


def test_ascii_trace_renders():
    sched = build_schedule(4, 32, "v3")
    res = simulate(sched, HW["gh200"], record_timeline=True)
    s = ascii_trace(res)
    assert "Work" in s and "|" in s


def test_chrome_trace_spill_schedule_has_disk_lane(tmp_path):
    """A spill schedule's simulated timeline renders with a ``dsk`` lane
    whose FETCH/SPILL events are well-formed chrome://tracing JSON."""
    import json

    import repro
    from repro.core.analytics import chrome_trace

    plan = repro.plan(96, repro.CholeskyConfig(tb=16, policy="v3",
                                               host_slots=8,
                                               backend="numpy"))
    res = plan.simulate(HW["a100-pcie"], record_timeline=True)
    path = tmp_path / "spill.trace.json"
    trace = chrome_trace(res, path)
    assert json.loads(path.read_text())["traceEvents"] == trace["traceEvents"]
    lanes = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M"}
    assert "dsk" in lanes and {"h2d", "cmp", "d2h"} <= lanes
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert xs and all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    disk = [e for e in xs if e["cat"] == "dsk"]
    assert disk and all(e["name"][0] in "FW" for e in disk)
    # within a lane, the simulator's spans are issue-ordered: monotone ts
    by_lane: dict = {}
    for e in xs:
        by_lane.setdefault(e["cat"], []).append(e["ts"])
    for lane_ts in by_lane.values():
        assert lane_ts == sorted(lane_ts)


def test_chrome_trace_lookahead_pipe_lanes(tmp_path):
    """lookahead > 0 multi-device timelines carry per-device ``d*:pipe``
    lanes splitting (colored) lookahead-panel work from the trailing
    update."""
    import json

    from repro.core.analytics import chrome_trace, simulate_multi
    from repro.core.schedule import build_multidevice_schedule

    m = build_multidevice_schedule(8, 16, 2, "v3", lookahead=1)
    res = simulate_multi(m, HW["a100-pcie"], record_timeline=True)
    path = tmp_path / "lookahead.trace.json"
    trace = chrome_trace(res, path)
    assert json.loads(path.read_text())["traceEvents"] == trace["traceEvents"]
    lanes = {e["args"]["name"] for e in trace["traceEvents"]
             if e["ph"] == "M"}
    assert {"d0:pipe", "d1:pipe", "d0:cmp", "link"} <= lanes
    pipe = [e for e in trace["traceEvents"]
            if e["ph"] == "X" and e["cat"].endswith(":pipe")]
    ahead = [e for e in pipe if e["name"].startswith("ahead:")]
    trail = [e for e in pipe if e["name"].startswith("trail:")]
    assert ahead and trail and len(ahead) + len(trail) == len(pipe)
    assert all("cname" in e for e in pipe)        # colored phases
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in pipe)
