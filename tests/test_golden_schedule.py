"""Golden-schedule regression: op-stream digests pinned per policy.

The whole point of the static scheduler is that the op stream for a given
``(nt, tb, plan, policy, cache_slots)`` is *deterministic* — executors,
analytics, and the multi-device replay all assume the exact emission
order.  These digests (sha256 over every op's full field tuple, see
``Schedule.digest``) pin that order: a refactor that accidentally changes
emission — reordered loads, different slot assignment, altered cache
decisions — fails loudly here instead of silently shifting Fig. 8/9/12
numbers.

If a change to the scheduler is *intentional*, regenerate with::

    PYTHONPATH=src python -c "import test_golden_schedule as t; t.regen()"

from the tests/ directory and update GOLDEN below, saying so in the PR.
"""
import numpy as np

from repro.core.precision import assign_precision
from repro.core.schedule import build_multidevice_schedule, build_schedule

NT, TB, SLOTS = 6, 8, 6
EPS = 1e-6

GOLDEN = {
    "sync": "18f72df696a87392",
    "async": "e589eebb10449aa5",
    "v1": "84b6845bfb6bfec3",
    "v2": "78e4bdcc2dc43d53",
    "v3": "eac166216f3ca7a7",
    "v4": "381724b6f78120e0",
    "sync@ndev2": "086ddeee1fe5c3f2",
    "v1@ndev2": "69cb29ec7356fbb8",
    "v2@ndev2": "677d5bf70b1827a2",
    "v3@ndev2": "8891cd4af2103ddc",
}


def _fixed_plan():
    """Deterministic MxP plan built from pure arithmetic (no RNG): mixed
    classes exercise the per-tile byte accounting in the digests."""
    norms = np.fromfunction(
        lambda i, j: 0.25 + ((3 * i + 5 * j) % 7) / 7.0, (NT, NT))
    dist = np.fromfunction(
        lambda i, j: np.minimum(abs(i - j), 4.0), (NT, NT))
    norms = norms * (1e-2 ** dist)
    norms[np.diag_indices(NT)] = 10.0
    return assign_precision(norms, float(np.sqrt((norms ** 2).sum())), EPS)


def _digests():
    plan = _fixed_plan()
    out = {}
    for p in ("sync", "async", "v1", "v2", "v3"):
        out[p] = build_schedule(NT, TB, p, cache_slots=SLOTS,
                                plan=plan).digest()
    out["v4"] = build_schedule(NT, TB, "v4", cache_slots=10, plan=plan,
                               block=(2, 2)).digest()
    for p in ("sync", "v1", "v2", "v3"):
        out[p + "@ndev2"] = build_multidevice_schedule(
            NT, TB, 2, p, cache_slots=SLOTS, plan=plan).digest()
    return out


def regen():
    for k, v in _digests().items():
        print(f'    "{k}": "{v}",')


def test_fixed_plan_is_mixed():
    plan = _fixed_plan()
    hist = plan.histogram()
    assert sum(1 for v in hist.values() if v > 0) >= 3, hist


def test_golden_digests():
    got = _digests()
    assert got == GOLDEN, {
        k: (GOLDEN.get(k), got.get(k))
        for k in set(GOLDEN) | set(got)
        if GOLDEN.get(k) != got.get(k)
    }


def test_digests_policy_distinct():
    """The tight cache makes every policy's stream genuinely different
    (v2 vs v3 differ only through diagonal pinning, visible here)."""
    got = _digests()
    assert len(set(got.values())) == len(got)


def test_digest_stable_across_builds():
    plan = _fixed_plan()
    a = build_schedule(NT, TB, "v3", cache_slots=SLOTS, plan=plan)
    b = build_schedule(NT, TB, "v3", cache_slots=SLOTS, plan=plan)
    assert a.digest() == b.digest()
