"""Golden-schedule regression: op-stream digests pinned per policy.

The whole point of the static scheduler is that the op stream for a given
``(nt, tb, plan, policy, cache_slots)`` is *deterministic* — executors,
analytics, and the multi-device replay all assume the exact emission
order.  These digests (sha256 over every op's full field tuple, see
``Schedule.digest``) pin that order: a refactor that accidentally changes
emission — reordered loads, different slot assignment, altered cache
decisions — fails loudly here instead of silently shifting Fig. 8/9/12
numbers.

If a change to the scheduler is *intentional*, regenerate with::

    PYTHONPATH=src python -c "import test_golden_schedule as t; t.regen()"

from the tests/ directory and update GOLDEN below, saying so in the PR.
"""
import numpy as np

from repro.core.precision import assign_precision
from repro.core.schedule import build_multidevice_schedule, build_schedule

NT, TB, SLOTS = 6, 8, 6
NT4 = 8     # ndev=4 digests: at NT=6 each device owns <= 2 rows and the
            # v2/v3 streams coincide (diag pinning never changes an
            # eviction); NT=8 keeps every policy pair distinct
EPS = 1e-6

# ndev>1 digests additionally pin the executor-facing metadata the
# multi-device JAX executor addresses buffers with (panel_base + per-stream
# slot counts) — regenerated in PR 3 when that metadata entered the hash.
GOLDEN = {
    "sync": "18f72df696a87392",
    "async": "e589eebb10449aa5",
    "v1": "84b6845bfb6bfec3",
    "v2": "78e4bdcc2dc43d53",
    "v3": "eac166216f3ca7a7",
    "v4": "381724b6f78120e0",
    "sync@ndev2": "c140c5a8a8228b4d",
    "v1@ndev2": "924deedacb3e7556",
    "v2@ndev2": "ff2b7c774be8455c",
    "v3@ndev2": "45e52e2feb022562",
    "sync@ndev4": "058243eb0ae9e1dc",
    "v1@ndev4": "50207d901c572dba",
    "v2@ndev4": "e99e475ca799fb14",
    "v3@ndev4": "d85c9d7501a73d7b",
    # 2D block-cyclic (2, 2) grid at ndev=4 (PR 5): scoped partial
    # broadcasts + host-landing RECVs enter the stream; the grid shape
    # itself is folded into the hash (see MultiDeviceSchedule.digest)
    "sync@grid2x2": "22c20bfd33f54f28",
    "v1@grid2x2": "40517cc0bb9ac7cd",
    "v2@grid2x2": "937da756885fa342",
    "v3@grid2x2": "83c5b2f9cb5b8062",
}


def _fixed_plan(nt=NT):
    """Deterministic MxP plan built from pure arithmetic (no RNG): mixed
    classes exercise the per-tile byte accounting in the digests."""
    norms = np.fromfunction(
        lambda i, j: 0.25 + ((3 * i + 5 * j) % 7) / 7.0, (nt, nt))
    dist = np.fromfunction(
        lambda i, j: np.minimum(abs(i - j), 4.0), (nt, nt))
    norms = norms * (1e-2 ** dist)
    norms[np.diag_indices(nt)] = 10.0
    return assign_precision(norms, float(np.sqrt((norms ** 2).sum())), EPS)


def _digests():
    plan = _fixed_plan()
    out = {}
    for p in ("sync", "async", "v1", "v2", "v3"):
        out[p] = build_schedule(NT, TB, p, cache_slots=SLOTS,
                                plan=plan).digest()
    out["v4"] = build_schedule(NT, TB, "v4", cache_slots=10, plan=plan,
                               block=(2, 2)).digest()
    for p in ("sync", "v1", "v2", "v3"):
        out[p + "@ndev2"] = build_multidevice_schedule(
            NT, TB, 2, p, cache_slots=SLOTS, plan=plan).digest()
    plan4 = _fixed_plan(NT4)
    for p in ("sync", "v1", "v2", "v3"):
        out[p + "@ndev4"] = build_multidevice_schedule(
            NT4, TB, 4, p, cache_slots=SLOTS, plan=plan4).digest()
    for p in ("sync", "v1", "v2", "v3"):
        out[p + "@grid2x2"] = build_multidevice_schedule(
            NT4, TB, 4, p, cache_slots=SLOTS, plan=plan4,
            grid=(2, 2)).digest()
    return out


def regen():
    for k, v in _digests().items():
        print(f'    "{k}": "{v}",')


def test_fixed_plan_is_mixed():
    plan = _fixed_plan()
    hist = plan.histogram()
    assert sum(1 for v in hist.values() if v > 0) >= 3, hist


def test_golden_digests():
    got = _digests()
    assert got == GOLDEN, {
        k: (GOLDEN.get(k), got.get(k))
        for k in set(GOLDEN) | set(got)
        if GOLDEN.get(k) != got.get(k)
    }


def test_digests_policy_distinct():
    """The tight cache makes every policy's stream genuinely different
    (v2 vs v3 differ only through diagonal pinning, visible here)."""
    got = _digests()
    assert len(set(got.values())) == len(got)


def test_digest_stable_across_builds():
    plan = _fixed_plan()
    a = build_schedule(NT, TB, "v3", cache_slots=SLOTS, plan=plan)
    b = build_schedule(NT, TB, "v3", cache_slots=SLOTS, plan=plan)
    assert a.digest() == b.digest()
    ma = build_multidevice_schedule(NT, TB, 4, "v3", cache_slots=SLOTS,
                                    plan=plan)
    mb = build_multidevice_schedule(NT, TB, 4, "v3", cache_slots=SLOTS,
                                    plan=plan)
    assert ma.digest() == mb.digest()


def test_digest_pins_executor_metadata():
    """The ndev>1 digest covers the slot/panel metadata the JAX executor
    addresses device buffers with: identical op streams with a different
    panel region must not hash equal."""
    import dataclasses
    plan = _fixed_plan()
    m = build_multidevice_schedule(NT, TB, 2, "v3", cache_slots=SLOTS,
                                   plan=plan)
    assert m.panel_base == SLOTS
    assert m.stream_nslots(0) >= m.panel_base
    moved = dataclasses.replace(m, panel_base=m.panel_base + 1)
    assert moved.digest() != m.digest()
    # the ndev=1 degenerate keeps the op-only hash (from_single round-trip)
    s = build_schedule(NT, TB, "v3", cache_slots=SLOTS, plan=plan)
    m1 = build_multidevice_schedule(NT, TB, 1, "v3", cache_slots=SLOTS,
                                    plan=plan)
    assert m1.panel_base == -1
    assert m1.digest() == type(m1).from_single(s).digest()


def test_digest_pins_grid():
    """An explicit 1D grid hashes identically to the default (pre-grid
    digests stay valid), and a 2D grid is folded into the hash — two
    schedules differing only in grid address host slabs differently in
    the executor, so they must not collide."""
    import dataclasses
    plan4 = _fixed_plan(NT4)
    m_def = build_multidevice_schedule(NT4, TB, 4, "v3", cache_slots=SLOTS,
                                       plan=plan4)
    m_1d = build_multidevice_schedule(NT4, TB, 4, "v3", cache_slots=SLOTS,
                                      plan=plan4, grid=(4, 1))
    assert m_def.grid == (4, 1) and m_def.digest() == m_1d.digest()
    m_2d = build_multidevice_schedule(NT4, TB, 4, "v3", cache_slots=SLOTS,
                                      plan=plan4, grid=(2, 2))
    assert m_2d.digest() != m_def.digest()
    # identical streams with a relabeled grid must hash differently
    relabeled = dataclasses.replace(m_2d, grid=(1, 4))
    assert relabeled.digest() != m_2d.digest()
