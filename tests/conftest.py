"""Shared test config.

x64 is enabled globally: the Cholesky core is an FP64 algorithm (paper
baseline).  Model smoke configs pin their own dtypes explicitly, so they
are unaffected.  Note: NO xla_force_host_platform_device_count here —
tests see the real single CPU device; multi-device tests spawn
subprocesses (see tests/test_distributed.py).
"""
import jax

jax.config.update("jax_enable_x64", True)
