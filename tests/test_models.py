"""Per-architecture smoke tests (reduced configs) + model-component
equivalence tests (chunked attention, MoE dispatch, SSD vs recurrence)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.models import attention as A
from repro.models import transformer as T
from repro.models.config import ModelConfig


def _inputs(cfg, B=2, S=32):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.frontend:
        kw["frontend_embeds"] = jnp.zeros((B, 8, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        kw["enc_embeds"] = 0.1 * jnp.ones((B, 16, cfg.d_model), jnp.float32)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_forward(arch):
    cfg = get_config(arch, smoke=True)
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg)
    h = T.forward(params, cfg, tokens, **kw)
    logits = T.logits_from_hidden(params, cfg, h)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    # padding columns are masked
    if cfg.padded_vocab != cfg.vocab:
        assert float(logits[..., cfg.vocab:].max()) < -1e29


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_train_step(arch):
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import adamw_init
    cfg = get_config(arch, smoke=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tokens, kw = _inputs(cfg)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1), **kw}
    step = make_train_step(cfg, lr=1e-3)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_decode(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    B, ML = 2, 16
    cache = T.init_cache(cfg, B, ML, jnp.float32)
    enc_out = (0.1 * jnp.ones((B, 16, cfg.d_model), jnp.dtype(cfg.dtype))
               if cfg.is_encdec else None)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = T.decode_step(params, cfg, tok, cache,
                                      jnp.int32(pos), enc_out)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
        tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["gemma3_1b", "qwen3_14b", "mamba2_130m"])
def test_decode_matches_forward(arch):
    """Greedy decode logits == teacher-forced forward logits."""
    cfg = get_config(arch, smoke=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    h = T.forward(params, cfg, tokens)
    full_logits = T.logits_from_hidden(params, cfg, h)
    cache = T.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for pos in range(S):
        lg, cache = T.decode_step(params, cfg, tokens[:, pos:pos + 1],
                                  cache, jnp.int32(pos))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float64),
                               np.asarray(full_logits, np.float64),
                               atol=2e-2, rtol=2e-2)


def test_loss_decreases():
    """A tiny model overfits a repeated batch (end-to-end sanity)."""
    from repro.launch.steps import make_train_step
    from repro.optim.adamw import adamw_init
    cfg = get_config("qwen3_14b", smoke=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (4, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    step = jax.jit(make_train_step(cfg, lr=3e-3))
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


# ---------------------------------------------------------------------------
# Component equivalences

def test_chunked_attention_equals_full():
    rng = np.random.default_rng(0)
    B, S, H, KV, hd = 2, 128, 8, 4, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    for window, softcap, causal in [(None, None, True), (16, None, True),
                                    (None, 20.0, True), (None, None, False)]:
        full = A._sdpa(q, k, v,
                       A._block_mask(jnp.arange(S), jnp.arange(S),
                                     causal, window), softcap)
        ch = A._sdpa_chunked(q, k, v, causal=causal, window=window,
                             softcap=softcap, qchunk=32)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(full),
                                   atol=1e-5)


def test_mla_chunked_equals_full():
    cfg = get_config("deepseek_v2_lite_16b", smoke=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    # deepseek smoke: layer 0 (first_dense) sits in the unrolled prefix
    lp = params["prefix"][0]["attn"]
    x = jnp.asarray(np.random.default_rng(0).standard_normal(
        (2, 64, cfg.d_model)), jnp.float32)
    pos = jnp.arange(64)[None, :]
    full = A.apply_mla(lp, cfg, x, pos, qchunk=1 << 30)
    ch = A.apply_mla(lp, cfg, x, pos, qchunk=16)
    np.testing.assert_allclose(np.asarray(ch), np.asarray(full), atol=1e-4)


def test_moe_matches_dense_reference():
    """With ample capacity, sort-based dispatch == per-token expert math."""
    from repro.models import moe as M
    cfg = get_config("dbrx_132b", smoke=True)
    p, _ = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2, 16, cfg.d_model)) * 0.1, jnp.float32)
    out = M.apply_moe(p, cfg, x, capacity_factor=float(cfg.n_experts))

    # dense reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"].astype(jnp.float32)
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        pe = {"wi": p["wi"][e], "wo": p["wo"][e]}
        if "wg" in p:
            pe["wg"] = p["wg"][e]
        from repro.models.layers import apply_mlp
        ye = apply_mlp(pe, xt, cfg.mlp_act)
        w = ((idx == e) * gates).sum(-1)[:, None]
        ref = ref + w * ye
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=1e-4)


def test_flash_attention_model_path():
    """cfg.use_flash_attention routes apply_gqa through the Pallas
    kernel (interpret) and matches the chunked-sdpa forward."""
    import dataclasses
    cfg = get_config("qwen3_14b", smoke=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 128), 0,
                                cfg.vocab)
    h_ref = T.forward(params, cfg, tokens)
    cfg_f = dataclasses.replace(cfg, use_flash_attention=True)
    h_flash = T.forward(params, cfg_f, tokens)
    np.testing.assert_allclose(np.asarray(h_flash), np.asarray(h_ref),
                               atol=3e-4, rtol=1e-3)


def test_moe_token_conservation():
    """Property: with zero router noise every kept token's output is the
    weighted expert mix, and dropped tokens fall back to shared/zero —
    total output mass never exceeds the dense-mix bound."""
    from _hypothesis_compat import given, settings, st
    from repro.models import moe as M
    import dataclasses
    cfg0 = get_config("dbrx_132b", smoke=True)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), cf=st.sampled_from([0.5, 1.0, 8.0]))
    def prop(seed, cf):
        cfg = dataclasses.replace(cfg0, n_shared_experts=0)
        p, _ = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(np.random.default_rng(seed).standard_normal(
            (1, 16, cfg.d_model)) * 0.1, jnp.float32)
        out = M.apply_moe(p, cfg, x, capacity_factor=cf)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()
        # ample capacity == exact dense mix; tight capacity only drops
        full = M.apply_moe(p, cfg, x, capacity_factor=float(cfg.n_experts))
        if cf >= cfg.n_experts:
            np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                                       atol=1e-5)

    prop()


def test_ssd_chunked_equals_recurrence():
    """Train-path SSD == step-by-step decode recurrence."""
    from repro.models import ssm as S
    cfg = get_config("mamba2_130m", smoke=True)
    p, _ = S.init_ssm(jax.random.PRNGKey(0), cfg)
    B, L = 1, 16
    x = jnp.asarray(np.random.default_rng(2).standard_normal(
        (B, L, cfg.d_model)) * 0.3, jnp.float32)
    y_train = S.apply_ssm(p, cfg, x)
    cache = S.init_ssm_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        yt, cache = S.decode_ssm(p, cfg, x[:, t:t + 1], cache)
        ys.append(yt)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               atol=2e-4, rtol=1e-3)


def test_ring_buffer_cache_equals_full():
    """A window-length ring cache must produce the same outputs as a
    full-length cache with a window mask (positions past the buffer)."""
    cfg = get_config("gemma3_1b", smoke=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["stack"])[0]["attn"] \
        if params["stack"] is not None else params["remainder"][0]["attn"]
    window = cfg.sliding_window          # 8
    B, steps = 1, 24
    full = A.init_gqa_cache(cfg, B, steps, jnp.float32)          # linear
    ring = {"k": jnp.zeros((B, window, cfg.num_kv_heads, cfg.head_dim),
                           jnp.float32),
            "v": jnp.zeros((B, window, cfg.num_kv_heads, cfg.head_dim),
                           jnp.float32)}
    rng = np.random.default_rng(0)
    for pos in range(steps):
        x = jnp.asarray(rng.standard_normal((B, 1, cfg.d_model)) * 0.2,
                        jnp.float32)
        yf, full = A.decode_gqa(lp, cfg, x, full, jnp.int32(pos),
                                window=window)
        yr, ring = A.decode_gqa(lp, cfg, x, ring, jnp.int32(pos),
                                window=window)
        np.testing.assert_allclose(np.asarray(yr), np.asarray(yf),
                                   atol=1e-5, err_msg=f"pos={pos}")


def test_gqa_cache_len():
    assert A.gqa_cache_len(524288, None) == 524288
    assert A.gqa_cache_len(524288, 512) == 512
    assert A.gqa_cache_len(524288, 1000) == 1024
    assert A.gqa_cache_len(16, 512) == 16     # never exceeds max_len


def test_sliding_window_pattern():
    cfg = get_config("gemma3_1b", smoke=True)
    windows = [cfg.layer_window(i) for i in range(cfg.num_layers)]
    assert windows[5] is None          # every 6th layer is global
    assert windows[0] == cfg.sliding_window
    assert sum(w is None for w in windows) == cfg.num_layers // 6 + \
        (1 if cfg.num_layers % 6 > 5 else 0)


def test_jamba_interleave():
    cfg = get_config("jamba_1_5_large_398b")
    kinds = [cfg.layer_kind(i) for i in range(16)]
    assert kinds.count("attn") == 2    # 1:7 -> 2 of 16
    assert kinds[7] == "attn" and kinds[15] == "attn"


def test_param_count_orders_of_magnitude():
    for arch, lo, hi in [("qwen3_14b", 13e9, 17e9),
                         ("nemotron_4_340b", 300e9, 380e9),
                         ("mamba2_130m", 0.1e9, 0.16e9),
                         ("dbrx_132b", 110e9, 150e9)]:
        total, active = get_config(arch).param_count()
        assert lo < total < hi, (arch, total)
        assert active <= total
