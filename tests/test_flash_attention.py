"""Pallas flash-attention kernel vs the jnp oracle (interpret mode)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention, flash_gqa
from repro.models.attention import _block_mask, _sdpa


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * 0.5, jnp.float32)


@pytest.mark.parametrize("s,t,hd,bq,bk", [
    (128, 128, 64, 64, 64),
    (256, 256, 128, 64, 128),
    (128, 256, 64, 128, 64),     # cross-length (prefill against memory)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_oracle(s, t, hd, bq, bk, causal):
    if causal and s != t:
        pytest.skip("causal requires aligned q/k positions here")
    bh = 4
    q, k, v = (_rand((bh, s, hd), 0), _rand((bh, t, hd), 1),
               _rand((bh, t, hd), 2))
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                          interpret=True)
    # oracle through the model's sdpa (expects [B,S,H,hd])
    mask = _block_mask(jnp.arange(s), jnp.arange(t), causal, None)
    want = _sdpa(q[:, :, None], k[:, :, None], v[:, :, None], mask, None)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want[:, :, 0]), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_gqa_grouping(dtype):
    """GQA: 8 q heads over 2 kv heads, kv fetched via the index map."""
    b, s, h, kv, hd = 2, 128, 8, 2, 64
    q = _rand((b, s, h, hd), 3).astype(dtype)
    k = _rand((b, s, kv, hd), 4).astype(dtype)
    v = _rand((b, s, kv, hd), 5).astype(dtype)
    got = flash_gqa(q, k, v, causal=True, bq=64, bk=64, interpret=True)
    mask = _block_mask(jnp.arange(s), jnp.arange(s), True, None)
    want = _sdpa(q.astype(jnp.float32), k.astype(jnp.float32),
                 v.astype(jnp.float32), mask, None)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64),
        atol=tol, rtol=tol)


def test_flash_long_kv_streaming():
    """Many KV blocks exercise the online-softmax carry."""
    bh, s, t, hd = 1, 64, 1024, 64
    q, k, v = (_rand((bh, s, hd), 6), _rand((bh, t, hd), 7),
               _rand((bh, t, hd), 8))
    got = flash_attention(q, k, v, causal=False, bq=64, bk=64,
                          interpret=True)
    mask = _block_mask(jnp.arange(s), jnp.arange(t), False, None)
    want = _sdpa(q[:, :, None], k[:, :, None], v[:, :, None], mask, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, :, 0]),
                               atol=2e-5)
