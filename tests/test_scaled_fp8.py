"""Scaled-FP8 accuracy regression (paper Fig. 10/11, Higham–Mary).

The ``f8e4m3s`` class stores tiles in the same e4m3 format as the
unscaled class, but multiplies each tile by a per-tile power-of-two
scale chosen from its store-time amax (``precision.fp8_scale``) before
the down-cast and divides it back on promotion — so the whole tile lands
in the format's representable band and the roundoff really is the
format's 2^-4.  Two regressions pin that:

* the fig-10-style eps sweep: Matérn covariance matrices factored with
  the ``tpu-scaled`` ladder achieve backward error ≤ eps_target at every
  level the paper sweeps (1e-5 .. 1e-8);
* on an ill-scaled matrix whose off-diagonal tiles live *below* e4m3's
  subnormal floor (2^-6), the unscaled class flushes the coupling toward
  zero while the scaled class keeps the 2^-4 relative accuracy — the
  scaled error must be strictly (and decisively) smaller.
"""
import numpy as np
import pytest

from repro.core.cholesky import plan_for_matrix, run_schedule_numpy
from repro.core.precision import LADDERS, PrecisionPlan
from repro.core.schedule import build_schedule
from repro.core.tiling import from_tiles, random_spd, to_tiles

N, TB = 256, 32
EPS_SWEEP = (1e-5, 1e-6, 1e-7, 1e-8)


def _matern(n):
    from repro.geo.matern import generate_locations, matern_covariance
    locs = generate_locations(n, seed=0)
    return matern_covariance(locs, beta=0.02627)  # weak correlation


def _backward_error(a, tb, plan):
    nt = a.shape[0] // tb
    sched = build_schedule(nt, tb, "v3", plan=plan)
    l = np.tril(from_tiles(run_schedule_numpy(to_tiles(a, tb), sched)))
    return np.linalg.norm(l @ l.T - a) / np.linalg.norm(a)


@pytest.mark.parametrize("eps_target", EPS_SWEEP)
def test_eps_sweep_scaled_ladder(eps_target):
    """Backward error ≤ eps_target at every accuracy level, with the
    scaled-FP8 class actually engaged at the loose end of the sweep."""
    a = _matern(N)
    plan = plan_for_matrix(to_tiles(a, TB), eps_target,
                           ladder="tpu-scaled")
    err = _backward_error(a, TB, plan)
    assert err <= eps_target, (eps_target, err)
    if eps_target >= 1e-6:
        assert plan.histogram()["f8e4m3s"] > 0, plan.histogram()


def test_sweep_is_monotone_and_uses_fewer_low_tiles_when_tight():
    a = _matern(N)
    tiles = to_tiles(a, TB)
    errs, n_fp8 = [], []
    for eps in EPS_SWEEP:
        plan = plan_for_matrix(tiles, eps, ladder="tpu-scaled")
        errs.append(_backward_error(a, TB, plan))
        n_fp8.append(plan.histogram()["f8e4m3s"])
    assert errs[-1] < errs[0]          # tighter target -> smaller error
    assert n_fp8[-1] <= n_fp8[0]       # ... and fewer FP8 tiles


def _uniform_fp8_plan(nt, ladder_name):
    """Every off-diagonal tile pinned to the ladder's FP8 class (index
    3); diagonals stay f64 (POTRF stability, as assign_precision pins)."""
    cls = np.full((nt, nt), 3, dtype=np.int8)
    np.fill_diagonal(cls, 0)
    return PrecisionPlan(cls, LADDERS[ladder_name], 1e-6)


def test_scaled_beats_unscaled_on_ill_scaled_matrix():
    """Tiles below e4m3's subnormal floor: the unscaled class flushes
    the coupling toward zero, the scaled class recentres it — the
    scaled backward error must win by a wide margin (the measured gap
    is ~37x; 4x is the regression floor)."""
    n, tb = 128, 32
    nt = n // tb
    # off-diagonal tile amax ~ 1e-4 << FP8_MIN_NORMAL = 2^-6
    a = np.eye(n) + 1e-3 * random_spd(n, seed=3)
    err = {
        name: _backward_error(a, tb, _uniform_fp8_plan(nt, name))
        for name in ("tpu", "tpu-scaled")
    }
    assert err["tpu-scaled"] < err["tpu"] / 4.0, err


def test_classification_prefers_scaled_class_out_of_band():
    """The amax-aware criterion at the point where it matters: a tile
    whose norm ratio qualifies for FP8 *only at the format's 2^-4*
    (ratio between eps_target and 16x eps_target).  With its amax above
    e4m3's max finite 448 the unscaled class's effective roundoff
    collapses (saturation) and the tile must classify higher, while the
    scaled class recentres the band and keeps it."""
    from repro.core.precision import assign_precision

    nt, eps = 2, 1e-6
    norms = np.ones((nt, nt))
    # nt * norm / total == 8 * eps: inside (eps, 16 eps] — FP8 eligible
    # at eps_fp8 = 2^-4, ineligible once the effective eps degrades
    total = nt / (8.0 * eps)
    amax = np.full((nt, nt), 1e4)     # far above FP8_MAX = 448
    unscaled = assign_precision(norms, total, eps, ladder="tpu",
                                tile_amax=amax)
    scaled = assign_precision(norms, total, eps, ladder="tpu-scaled",
                              tile_amax=amax)
    assert unscaled.name(1, 0) != "f8e4m3", unscaled.histogram()
    assert scaled.name(1, 0) == "f8e4m3s", scaled.histogram()
    # without amax information the historical format-eps classification
    # (and the PR 9 golden plans) are preserved
    legacy = assign_precision(norms, total, eps, ladder="tpu")
    assert legacy.name(1, 0) == "f8e4m3"
