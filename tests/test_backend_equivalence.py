"""Cross-backend equivalence: the multi-device JAX executor against every
other way this repo can factor a matrix.

The correctness risk of distributed task replay lives in the
communication edges (the BCAST/RECV panel broadcast), so each case pins a
*three-way* equality on real (host-platform) devices:

    multi-device JAX executor  ==  run_multidevice_numpy  ==  LAPACK

plus, for FP64, the independently-derived shard_map einsum baseline in
``core/distributed.py`` — four implementations, two of which share no
code with the static-schedule stack.  The executed BCAST/RECV transfer
counters are cross-checked against the static schedule and the event
simulator (``analytics.crosscheck_executed_volume``): the static-schedule
claim is that the executed bytes are knowable before execution.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count`` (pattern from
``test_distributed.py``; the main pytest process keeps the real
single-device view).  ``async``/``v4`` have no multi-device schedule, so
their three-way check runs on the ndev=1 jax/numpy pair in-process.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import repro
from repro.core.tiling import from_tiles, random_spd, to_tiles

NDEVS = [2, 4]
POLICIES = ["sync", "v2", "v3"]


def _run_sub(code: str, devices: int = 4):
    env = {"XLA_FLAGS":
           f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
           "JAX_PLATFORMS": "cpu"}
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=900,
                          env=env, cwd=os.path.dirname(
                              os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


_THREE_WAY = """
    import numpy as np, jax
    jax.config.update('jax_enable_x64', True)
    import repro
    from repro.core.analytics import HW, crosscheck_executed_volume
    from repro.core.cholesky import run_multidevice_numpy
    from repro.core.tiling import from_tiles, random_spd, to_tiles

    n, tb, ndev, policy = {n}, {tb}, {ndev}, {policy!r}
    a = random_spd(n, seed=23)
    cfg = repro.CholeskyConfig(tb=tb, policy=policy, ndev=ndev,
                               backend='jax')
    assert cfg.resolved_backend() == 'jax'
    solver = repro.plan(n, cfg).compile()
    l_jax = solver.factor(a)

    # 1) vs LAPACK
    l_ref = np.linalg.cholesky(a)
    assert np.abs(l_jax - l_ref).max() < 1e-10

    # 2) vs the NumPy oracle replay of the *same* op streams (BLAS
    #    round-off only: identical op order, identical rounding events)
    l_np = np.tril(from_tiles(run_multidevice_numpy(to_tiles(a, tb),
                                                    solver.schedule)))
    assert np.abs(l_jax - l_np).max() < 1e-13

    # 3) executed interconnect traffic == static schedule == simulator
    cc = crosscheck_executed_volume(solver.schedule,
                                    solver.transfer_stats(),
                                    hw=HW['gh200'])
    assert cc['match'], cc['mismatches']

    # repeated factorization: no retrace, bitwise-identical replay
    traces = solver.stats['jit_traces']
    l2 = solver.factor(a)
    assert solver.stats['jit_traces'] == traces
    assert np.array_equal(l_jax, l2)
    print('OK')
"""


@pytest.mark.parametrize("ndev", NDEVS)
@pytest.mark.parametrize("policy", POLICIES)
def test_three_way_fp64(ndev, policy):
    out = _run_sub(_THREE_WAY.format(n=128, tb=16, ndev=ndev,
                                     policy=policy), devices=ndev)
    assert "OK" in out


@pytest.mark.parametrize("ndev", NDEVS)
def test_three_way_mxp(ndev):
    """MxP ladder: the jax executor performs the identical class-rounding
    events as the NumPy replay, and both land within the plan's accuracy
    level of LAPACK."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import HW, crosscheck_executed_volume
        from repro.core.cholesky import run_multidevice_numpy
        from repro.core.tiling import from_tiles, random_spd, to_tiles

        n, tb, ndev = 128, 16, %d
        a = random_spd(n, seed=7)
        cfg = repro.CholeskyConfig(tb=tb, policy='v3', ndev=ndev,
                                   backend='jax', eps_target=1e-6)
        solver = repro.plan(n, cfg.specialize(a)).compile()
        msched = solver.schedule
        assert msched.bcast_bytes() > 0
        l_jax = solver.factor(a)
        l_np = np.tril(from_tiles(run_multidevice_numpy(to_tiles(a, tb),
                                                        msched)))
        assert np.abs(l_jax - l_np).max() < 1e-8
        assert np.abs(l_jax - np.linalg.cholesky(a)).max() < 1e-3
        cc = crosscheck_executed_volume(msched, solver.transfer_stats(),
                                        hw=HW['gh200'])
        assert cc['match'], cc['mismatches']
        # MxP shrinks the executed interconnect bytes below uniform f64
        f64 = repro.build_multidevice_schedule(n // tb, tb, ndev, 'v3')
        assert solver.transfer_stats()['recv_bytes'] < f64.bcast_bytes()
        print('OK')
    """ % ndev, devices=ndev)
    assert "OK" in out


@pytest.mark.parametrize("grid", [(2, 2), (1, 4)])
def test_three_way_fp64_2d_grid(grid):
    """2D block-cyclic grids through the full stack on 4 forced host
    devices: jax executor == numpy replay == LAPACK, executed transfer
    counters == schedule == simulator, and — the PR 5 acceptance — the
    2D grid's *executed* broadcast bytes strictly below the 1D
    schedule's executed bytes at ndev=4, NT=8."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import HW, crosscheck_executed_volume
        from repro.core.cholesky import run_multidevice_numpy
        from repro.core.tiling import from_tiles, random_spd, to_tiles

        n, tb, grid = 128, 16, %r                      # NT = 8
        a = random_spd(n, seed=23)
        cfg = repro.CholeskyConfig(tb=tb, policy='v3', ndev=4,
                                   grid=grid, backend='jax')
        solver = repro.plan(n, cfg).compile()
        l_jax = solver.factor(a)
        assert np.abs(l_jax - np.linalg.cholesky(a)).max() < 1e-10
        l_np = np.tril(from_tiles(run_multidevice_numpy(
            to_tiles(a, tb), solver.schedule)))
        assert np.abs(l_jax - l_np).max() < 1e-13
        cc = crosscheck_executed_volume(solver.schedule,
                                        solver.transfer_stats(),
                                        hw=HW['gh200'])
        assert cc['match'], cc['mismatches']

        # executed 2D broadcast bytes strictly below executed 1D bytes
        base = repro.plan(n, repro.CholeskyConfig(
            tb=tb, policy='v3', ndev=4, backend='jax')).compile()
        base.factor(a)
        ex_2d = solver.transfer_stats()['recv_bytes']
        ex_1d = base.transfer_stats()['recv_bytes']
        assert 0 < ex_2d < ex_1d, (ex_2d, ex_1d)

        # repeated factorization: no retrace, bitwise-identical replay
        traces = solver.stats['jit_traces']
        l2 = solver.factor(a)
        assert solver.stats['jit_traces'] == traces
        assert np.array_equal(l_jax, l2)
        print('OK')
    """ % (grid,), devices=4)
    assert "OK" in out


@pytest.mark.parametrize("lookahead", [1, 2])
def test_three_way_fp64_lookahead(lookahead):
    """Pipelined-panel schedules (PR 6) through the full stack on 4
    forced host devices at the acceptance geometry ``(2, 2)``: the
    emitter's interleaved final/advance waves replay to the same factor
    as the numpy oracle and LAPACK, the executed transfer counters match
    the schedule and the simulator (the pipeline moves the same bytes as
    lookahead=0, earlier), and repeated factorization never retraces."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import HW, crosscheck_executed_volume
        from repro.core.cholesky import run_multidevice_numpy
        from repro.core.tiling import from_tiles, random_spd, to_tiles

        n, tb, la = 128, 16, %d                        # NT = 8
        a = random_spd(n, seed=23)
        cfg = repro.CholeskyConfig(tb=tb, policy='v3', ndev=4,
                                   grid=(2, 2), lookahead=la,
                                   backend='jax')
        solver = repro.plan(n, cfg).compile()
        assert solver.schedule.lookahead == la
        l_jax = solver.factor(a)
        assert np.abs(l_jax - np.linalg.cholesky(a)).max() < 1e-10
        l_np = np.tril(from_tiles(run_multidevice_numpy(
            to_tiles(a, tb), solver.schedule)))
        assert np.abs(l_jax - l_np).max() < 1e-13
        cc = crosscheck_executed_volume(solver.schedule,
                                        solver.transfer_stats(),
                                        hw=HW['gh200'])
        assert cc['match'], cc['mismatches']

        # the pipeline reorders transfers but adds none: executed bytes
        # equal the lookahead=0 schedule's on the same grid
        base = repro.plan(n, repro.CholeskyConfig(
            tb=tb, policy='v3', ndev=4, grid=(2, 2),
            backend='jax')).compile()
        assert (solver.transfer_stats()['recv_bytes']
                == base.schedule.bcast_bytes())

        # repeated factorization: no retrace, bitwise-identical replay
        traces = solver.stats['jit_traces']
        l2 = solver.factor(a)
        assert solver.stats['jit_traces'] == traces
        assert np.array_equal(l_jax, l2)
        print('OK')
    """ % lookahead, devices=4)
    assert "OK" in out


def test_executor_vs_shard_map_reference():
    """The static-schedule executor against the independently-derived
    shard_map einsum baseline (`core/distributed.py`) — no shared code
    beyond the tile layout."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.distributed import distributed_cholesky
        from repro.core.tiling import random_spd

        n, tb, ndev = 128, 16, 4
        a = random_spd(n, seed=31)
        solver = repro.plan(n, repro.CholeskyConfig(
            tb=tb, policy='v3', ndev=ndev, backend='jax')).compile()
        l_exec = solver.factor(a)
        mesh = jax.make_mesh((ndev,), ('model',))
        l_ref = distributed_cholesky(a, tb, mesh)
        assert np.abs(l_exec - l_ref).max() < 1e-11
        print('OK')
    """, devices=4)
    assert "OK" in out


def test_auto_backend_resolves_to_jax_with_devices():
    """backend='auto' + ndev>1 runs the per-device jax executor whenever
    the process sees enough devices (and the numpy replay otherwise —
    asserted in-process by test_api.py)."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.tiling import random_spd
        cfg = repro.CholeskyConfig(tb=16, policy='v3', ndev=2)
        assert cfg.resolved_backend() == 'jax'
        solver = repro.plan(64, cfg).compile()
        a = random_spd(64, seed=1)
        l = solver.factor(a)
        assert np.abs(l - np.linalg.cholesky(a)).max() < 1e-10
        assert solver.transfer_stats() is not None   # jax executor ran
        print('OK')
    """, devices=2)
    assert "OK" in out


def test_solver_surface_on_multidevice_jax_factor():
    """OOCSolver.solve/solve_lower/logdet work unchanged on top of the
    multi-device jax factor (acceptance: factor/solve/logdet on 4
    host-platform devices)."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import scipy.linalg as sla
        import repro
        from repro.core.tiling import random_spd
        n = 128
        a = random_spd(n, seed=5)
        solver = repro.plan(n, repro.CholeskyConfig(
            tb=16, policy='v3', ndev=4, backend='jax')).compile()
        assert solver.factor(a, materialize=False) is None
        b = np.linspace(0, 1, n)
        ref = np.linalg.cholesky(a)
        assert np.abs(solver.solve(b)
                      - sla.cho_solve((ref, True), b)).max() < 1e-10
        assert np.abs(solver.solve_lower(b)
                      - sla.solve_triangular(ref, b, lower=True)).max() < 1e-10
        assert abs(solver.logdet()
                   - 2 * np.log(np.diag(ref)).sum()) < 1e-9
        print('OK')
    """, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Policies without a multi-device schedule: three-way check at ndev=1
# (jax unrolled jit == numpy oracle == LAPACK), in-process.

@pytest.mark.parametrize("policy", ["async", "v4"])
def test_single_device_three_way(policy):
    n, tb = 96, 16
    a = random_spd(n, seed=17)
    l_jax = repro.plan(n, tb=tb, policy=policy,
                       backend="jax").compile().factor(a)
    l_np = repro.plan(n, tb=tb, policy=policy,
                      backend="numpy").compile().factor(a)
    ref = np.linalg.cholesky(a)
    assert np.abs(l_jax - ref).max() < 1e-11
    assert np.abs(l_jax - l_np).max() < 1e-13


# ---------------------------------------------------------------------------
# Fused column-step megakernels (CholeskyConfig.fuse_columns): the fused
# trace swaps every column step's compute group for one pallas launch but
# must leave the *data-movement record* — and the factor — equivalent.

def test_fused_three_way_single_device():
    """ndev=1: fused jax == unfused jax == numpy oracle == LAPACK, with
    the executed transfer view identical to the static schedule's (the
    fused trace changes compute launches, never transfers)."""
    n, tb = 128, 16
    a = random_spd(n, seed=29)
    fused = repro.plan(n, tb=tb, policy="v3", backend="jax",
                       fuse_columns=True).compile()
    l_fused = fused.factor(a)
    l_jax = repro.plan(n, tb=tb, policy="v3",
                       backend="jax").compile().factor(a)
    l_np = repro.plan(n, tb=tb, policy="v3",
                      backend="numpy").compile().factor(a)
    assert np.abs(l_fused - np.linalg.cholesky(a)).max() < 1e-10
    assert np.abs(l_fused - l_jax).max() < 1e-12
    assert np.abs(l_fused - l_np).max() < 1e-12
    # executed == scheduled bytes: the fused executor's transfer stats
    # are the schedule's own LOAD/STORE record, unchanged by fusion
    sched = fused.schedule
    t = fused.stats["transfers"]
    assert t["h2d_bytes"] == sched.loads_bytes() > 0
    assert t["d2h_bytes"] == sched.stores_bytes() > 0
    # repeated factorization: no retrace, bitwise-identical replay
    traces = fused.stats["jit_traces"]
    l2 = fused.factor(a)
    assert fused.stats["jit_traces"] == traces
    assert np.array_equal(l_fused, l2)


def test_fused_three_way_ndev2():
    """ndev=2 on forced host devices: the fused multi-device executor ==
    numpy replay == LAPACK, executed BCAST/RECV counters == schedule ==
    simulator, and the fused factor matches the unfused executor's."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import HW, crosscheck_executed_volume
        from repro.core.cholesky import run_multidevice_numpy
        from repro.core.tiling import from_tiles, random_spd, to_tiles

        n, tb = 128, 16
        a = random_spd(n, seed=31)
        cfg = repro.CholeskyConfig(tb=tb, policy='v3', ndev=2,
                                   backend='jax', fuse_columns=True,
                                   eps_target=1e-6, ladder='tpu-scaled')
        solver = repro.plan(n, cfg.specialize(a)).compile()
        l_fused = solver.factor(a)
        assert np.abs(l_fused - np.linalg.cholesky(a)).max() < 1e-3
        l_np = np.tril(from_tiles(run_multidevice_numpy(
            to_tiles(a, tb), solver.schedule)))
        assert np.abs(l_fused - l_np).max() < 1e-8
        base = repro.plan(n, cfg.specialize(a),
                          fuse_columns=False).compile()
        l_base = base.factor(a)
        assert np.abs(l_fused - l_base).max() < 1e-8
        cc = crosscheck_executed_volume(solver.schedule,
                                        solver.transfer_stats(),
                                        hw=HW['gh200'])
        assert cc['match'], cc['mismatches']
        assert solver.transfer_stats() == base.transfer_stats()
        traces = solver.stats['jit_traces']
        l2 = solver.factor(a)
        assert solver.stats['jit_traces'] == traces
        assert np.array_equal(l_fused, l2)
        print('OK')
    """, devices=2)
    assert "OK" in out


def test_fused_three_way_lookahead():
    """Fused segments under the pipelined emitter (lookahead=1): the
    recv-free dispatch chunks merge into wider fused segments, but the
    factor and the executed byte record stay those of the schedule."""
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import HW, crosscheck_executed_volume
        from repro.core.cholesky import run_multidevice_numpy
        from repro.core.tiling import from_tiles, random_spd, to_tiles

        n, tb = 128, 16
        a = random_spd(n, seed=37)
        cfg = repro.CholeskyConfig(tb=tb, policy='v3', ndev=2,
                                   lookahead=1, backend='jax',
                                   fuse_columns=True)
        solver = repro.plan(n, cfg).compile()
        assert solver.schedule.lookahead == 1
        l_fused = solver.factor(a)
        assert np.abs(l_fused - np.linalg.cholesky(a)).max() < 1e-10
        l_np = np.tril(from_tiles(run_multidevice_numpy(
            to_tiles(a, tb), solver.schedule)))
        assert np.abs(l_fused - l_np).max() < 1e-12
        cc = crosscheck_executed_volume(solver.schedule,
                                        solver.transfer_stats(),
                                        hw=HW['gh200'])
        assert cc['match'], cc['mismatches']
        # the pipeline (and the fused segment merging) reorders
        # transfers but adds none
        assert (solver.transfer_stats()['recv_bytes']
                == solver.schedule.bcast_bytes())
        print('OK')
    """, devices=2)
    assert "OK" in out


def test_fused_three_way_spill():
    """Fused segments over the bounded host tier (host_slots > 0): the
    fused spill executor == numpy spill replay == LAPACK, with executed
    FETCH/SPILL bytes == scheduled == simulated."""
    from repro.core.analytics import HW, simulate
    from repro.core.cholesky import run_schedule_spill
    from repro.core.spill import ArrayTileStore

    n, tb, host_slots = 128, 16, 10
    a = random_spd(n, seed=41)
    fused = repro.plan(n, tb=tb, policy="v3", backend="jax",
                       host_slots=host_slots, fuse_columns=True).compile()
    l_fused = fused.factor(a)
    assert np.abs(l_fused - np.linalg.cholesky(a)).max() < 1e-10
    sched = fused.schedule.to_single()
    store = ArrayTileStore(to_tiles(a, tb))
    run_schedule_spill(store, sched)
    l_np = np.tril(from_tiles(store.to_tiles()))
    assert np.abs(l_fused - l_np).max() < 1e-12
    # executed disk lane == static schedule == event simulator
    t = fused.stats["transfers"]
    assert t["fetched_bytes"] == sched.fetch_bytes() > 0
    assert t["spilled_bytes"] == sched.spill_bytes() > 0
    r = simulate(sched, HW["gh200"])
    assert t["fetched_bytes"] == r.fetch_bytes
    assert t["spilled_bytes"] == r.spill_bytes
