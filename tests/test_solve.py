"""Blocked triangular substitution (`core/solve.py`) through the solver API.

Property sweeps (hypothesis when installed, fixed-seed fallback via
``_hypothesis_compat``) over the dimensions that shape the tile loops:
tile count, right-hand-side width, policy, and precision ladder; plus the
edge cases the sweeps cannot reach — ``tb`` not dividing ``n`` (rejected
eagerly), ``materialize=False`` (the OOC mode: the dense factor is never
assembled), and MxP factors feeding the f64 substitution.
"""
import numpy as np
import pytest
import scipy.linalg as sla

from _hypothesis_compat import given, settings, st

import repro
from repro.core.solve import (cho_solve_tiles, logdet_tiles,
                              solve_lower_t_tiles, solve_lower_tiles)
from repro.core.tiling import random_spd, to_tiles


def _solver(n, tb, policy="v3", **kw):
    return repro.plan(n, tb=tb, policy=policy, **kw).compile()


# ---------------------------------------------------------------------------
# Property sweeps (hypothesis or fixed-seed fallback)

@settings(max_examples=10, deadline=None)
@given(nt=st.integers(min_value=1, max_value=6),
       tb=st.sampled_from([8, 16, 24]),
       nrhs=st.integers(min_value=0, max_value=3),
       policy=st.sampled_from(["sync", "v1", "v3", "v4"]))
def test_solve_matches_scipy(nt, tb, nrhs, policy):
    """solve() == scipy cho_solve for every tiling/policy/rhs shape
    (nrhs=0 means a 1-D right-hand side)."""
    n = nt * tb
    a = random_spd(n, seed=nt * 131 + tb)
    rng = np.random.default_rng(nt * 7 + nrhs)
    b = rng.standard_normal(n if nrhs == 0 else (n, nrhs))
    s = _solver(n, tb, policy, backend="numpy")
    s.factor(a)
    x = s.solve(b)
    assert x.shape == b.shape
    ref = sla.cho_solve((np.linalg.cholesky(a), True), b)
    assert np.abs(x - ref).max() < 1e-9


@settings(max_examples=10, deadline=None)
@given(nt=st.integers(min_value=1, max_value=5),
       tb=st.sampled_from([8, 16]),
       seed=st.integers(min_value=0, max_value=99))
def test_solve_lower_and_transpose_roundtrip(nt, tb, seed):
    """L z = b then L^T x = z reconstructs cho_solve; each half matches
    dense triangular solves on the materialized factor."""
    n = nt * tb
    a = random_spd(n, seed=seed)
    s = _solver(n, tb, backend="numpy")
    l = s.factor(a)
    b = np.random.default_rng(seed).standard_normal(n)
    z = s.solve_lower(b)
    assert np.abs(z - sla.solve_triangular(l, b, lower=True)).max() < 1e-9
    tiles = to_tiles(np.tril(l), tb)
    x = solve_lower_t_tiles(tiles, z)
    assert np.abs(x - s.solve(b)).max() < 1e-9


@settings(max_examples=8, deadline=None)
@given(nt=st.integers(min_value=1, max_value=5),
       seed=st.integers(min_value=0, max_value=99))
def test_logdet_matches_slogdet(nt, seed):
    n = nt * 16
    a = random_spd(n, seed=seed)
    s = _solver(n, 16, backend="numpy")
    s.factor(a)
    sign, ref = np.linalg.slogdet(a)
    assert sign > 0
    assert s.logdet() == pytest.approx(ref, rel=1e-10)


@settings(max_examples=6, deadline=None)
@given(ladder=st.sampled_from(["tpu", "gpu"]),
       eps=st.sampled_from([1e-6, 1e-8]),
       policy=st.sampled_from(["v1", "v3"]))
def test_solve_on_mxp_factor_tracks_eps(ladder, eps, policy):
    """An MxP factor still solves: the residual follows the plan's
    accuracy level, not fp64 round-off."""
    n, tb = 96, 16
    a = random_spd(n, seed=3)
    cfg = repro.CholeskyConfig(tb=tb, policy=policy, eps_target=eps,
                               ladder=ladder, backend="numpy")
    s = repro.plan(n, cfg.specialize(a)).compile()
    s.factor(a)
    b = np.ones(n)
    x = s.solve(b)
    assert np.abs(a @ x - b).max() < max(1e3 * eps, 1e-8)


# ---------------------------------------------------------------------------
# Edge cases the sweeps cannot reach

@pytest.mark.parametrize("n, tb", [(100, 16), (64, 48), (17, 2)])
def test_tb_not_dividing_n_rejected_eagerly(n, tb):
    """Planning (not factoring) rejects a tiling that does not cover the
    matrix — the error arrives before any schedule is built."""
    with pytest.raises(ValueError, match="multiple"):
        repro.plan(n, tb=tb, policy="v3")


def test_materialize_false_never_forms_dense_factor():
    """materialize=False is the OOC mode: factor() returns None, the tile
    store feeds solve/solve_lower/logdet, and results equal the
    materialized path bit for bit (same replay, same tiles)."""
    n, tb = 96, 32
    a = random_spd(n, seed=12)
    s1 = _solver(n, tb)
    s2 = _solver(n, tb)
    l = s1.factor(a, materialize=True)
    assert s2.factor(a, materialize=False) is None
    b = np.arange(n, dtype=np.float64) / n
    assert np.array_equal(s1.solve(b), s2.solve(b))
    assert np.array_equal(s1.solve_lower(b), s2.solve_lower(b))
    assert s1.logdet() == s2.logdet()
    assert np.abs(s2.logdet()
                  - 2 * np.sum(np.log(np.diag(np.linalg.cholesky(a))))) < 1e-9
    del l


def test_solve_shape_validation():
    n, tb = 64, 16
    a = random_spd(n, seed=0)
    s = _solver(n, tb)
    s.factor(a)
    with pytest.raises(ValueError, match="rows"):
        s.solve(np.ones(n + 1))
    with pytest.raises(ValueError, match="malformed"):
        cho_solve_tiles(np.zeros((2, 3, tb, tb)), np.ones(n))


def test_solve_functions_on_raw_tile_store():
    """The module-level tile routines accept any factored store — the
    executors' output contract (strictly-upper tiles never read)."""
    n, tb = 80, 16
    a = random_spd(n, seed=4)
    ref = np.linalg.cholesky(a)
    tiles = to_tiles(ref, tb)
    # poison the strictly-upper tiles: solves must never read them
    nt = n // tb
    for i in range(nt):
        for j in range(i + 1, nt):
            tiles[i, j] = np.nan
    b = np.linspace(-1, 1, n)
    z = solve_lower_tiles(tiles, b)
    assert np.abs(z - sla.solve_triangular(ref, b, lower=True)).max() < 1e-10
    x = cho_solve_tiles(tiles, b)
    assert np.abs(x - sla.cho_solve((ref, True), b)).max() < 1e-9
    assert np.isfinite(logdet_tiles(tiles))


def test_logdet_names_offending_tile_on_invalid_factor():
    """A non-positive diagonal entry (a factorization that lost positive
    definiteness, e.g. under an over-aggressive precision ladder) used to
    surface as a bare numpy log warning and a silent nan; it must raise
    and say exactly which tile is broken."""
    n, tb = 64, 16
    tiles = to_tiles(np.linalg.cholesky(random_spd(n, seed=8)), tb)
    tiles[1, 1, 2, 2] = 0.0
    tiles[1, 1, 3, 3] = -4.0
    with pytest.raises(ValueError) as exc:
        logdet_tiles(tiles)
    msg = str(exc.value)
    assert "diagonal tile (1, 1)" in msg
    assert "[2, 3]" in msg                 # the offending local indices
    assert "positive definiteness" in msg


# ---------------------------------------------------------------------------
# Stacked multi-RHS (0.7): the serve batcher's substrate

def test_stacked_solve_matches_scipy_per_column():
    """solve(B) for a wide (n, k) stack: every column matches scipy
    cho_solve to 1e-10 and the single-RHS solve of that column."""
    n, tb, k = 96, 16, 24
    a = random_spd(n, seed=21)
    rng = np.random.default_rng(21)
    B = rng.standard_normal((n, k))
    s = _solver(n, tb, backend="numpy")
    s.factor(a)
    X = s.solve(B)
    ref = sla.cho_solve((np.linalg.cholesky(a), True), B)
    assert np.abs(X - ref).max() < 1e-10
    for j in range(0, k, 5):
        assert np.allclose(X[:, j], s.solve(B[:, j]), rtol=0, atol=1e-12)


def test_rhs_block_panels_match_unblocked():
    """Column-panel tiling (rhs_block) only reorders scheduling: results
    match the one-sweep stack and cover the uneven-tail panel."""
    n, tb, k = 64, 16, 7
    a = random_spd(n, seed=22)
    rng = np.random.default_rng(22)
    B = rng.standard_normal((n, k))
    s = _solver(n, tb, backend="numpy")
    s.factor(a)
    tiles = s._factored_tiles()
    full = cho_solve_tiles(tiles, B)
    for rb in (1, 2, 3, k, k + 5):
        assert np.abs(cho_solve_tiles(tiles, B, rhs_block=rb)
                      - full).max() < 1e-12
    z_full = solve_lower_tiles(tiles, B)
    assert np.abs(solve_lower_tiles(tiles, B, rhs_block=2)
                  - z_full).max() < 1e-12
    with pytest.raises(ValueError, match="rhs_block"):
        cho_solve_tiles(tiles, B, rhs_block=0)


def test_stacked_rhs_validation():
    n, tb = 64, 16
    a = random_spd(n, seed=23)
    s = _solver(n, tb, backend="numpy")
    s.factor(a)
    with pytest.raises(ValueError, match="0 columns"):
        s.solve(np.empty((n, 0)))
    with pytest.raises(ValueError, match="vector"):
        s.solve(np.ones((n, 2, 2)))
    with pytest.raises(TypeError, match="real-valued"):
        s.solve(np.ones(n, dtype=complex))
