"""Concurrent planner use: the plan cache under thread pressure.

The serve worker pool hammers ``repro.plan()`` + ``compile()`` from many
threads; before 0.7 the cache was an unguarded OrderedDict (corruptible
``move_to_end``/``popitem``) and a concurrent miss could build the same
schedule twice.  These tests pin the contract the service relies on:

* mixed-shape stress from N threads never corrupts the cache and keeps
  its size bounded;
* concurrent misses on one key collapse to exactly ONE schedule build
  and ONE jit trace (the amortization contract, now also under threads);
* results from concurrently compiled/executed solvers are bit-identical
  to serial execution.
"""
import threading

import numpy as np
import pytest

import repro
from repro.core import api

TB = 16
SHAPES = [(32, "v3"), (48, "v2"), (64, "v3"), (48, "v3"), (32, "v2")]


def _cfg(policy, **kw):
    return repro.CholeskyConfig(tb=TB, policy=policy, backend="numpy", **kw)


def _hammer(nthreads, fn):
    """Run fn(thread_index) on nthreads threads, re-raising any failure."""
    errs = []

    def wrap(i):
        try:
            fn(i)
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=wrap, args=(i,))
               for i in range(nthreads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errs:
        raise errs[0]


def test_stress_mixed_shapes_bounded_and_bit_identical():
    """8 threads x mixed shapes: one build per distinct (n, config),
    bounded cache, results bit-identical to serial."""
    api.clear_plan_cache()
    before = api.schedule_build_count()
    mats = {n: repro.random_spd(n, seed=n) for n, _ in SHAPES}
    serial = {}
    for n, policy in SHAPES:
        s = repro.plan(n, _cfg(policy)).compile()
        serial[(n, policy)] = s.factor(mats[n])
    after_serial = api.schedule_build_count()
    results = {}
    lock = threading.Lock()

    def worker(i):
        for rep in range(6):
            n, policy = SHAPES[(i + rep) % len(SHAPES)]
            solver = repro.plan(n, _cfg(policy)).compile()
            l = solver.factor(mats[n])
            with lock:
                results.setdefault((n, policy), []).append(l)

    _hammer(8, worker)
    # every concurrent result equals the serial factorization bit for bit
    for key, ls in results.items():
        for l in ls:
            assert np.array_equal(l, serial[key])
    # the serial warm-up built each distinct plan once; the stress added
    # NOTHING (all 48 thread-iterations were cache hits)
    assert after_serial - before == len(set(SHAPES))
    assert api.schedule_build_count() == after_serial
    stats = api.plan_cache_stats()
    assert stats["size"] <= stats["max"]


def test_concurrent_misses_collapse_to_one_build():
    """N threads planning the SAME cold key race on the miss path: the
    lock makes exactly one of them build; the rest share the plan."""
    api.clear_plan_cache()
    n = 80
    before = api.schedule_build_count()
    plans = []
    lock = threading.Lock()

    def worker(i):
        p = repro.plan(n, _cfg("v3"))
        with lock:
            plans.append(p)

    _hammer(12, worker)
    assert api.schedule_build_count() - before == 1
    assert all(p is plans[0] for p in plans)


def test_concurrent_compile_single_jit_trace():
    """compile() raced from many threads builds one executor; after the
    first factor, the jit-trace counter stays at one per plan."""
    api.clear_plan_cache()
    n = 48
    cfg = repro.CholeskyConfig(tb=TB, policy="v3", backend="jax")
    a = repro.random_spd(n, seed=5)
    solvers = []
    lock = threading.Lock()

    def worker(i):
        s = repro.plan(n, cfg).compile()
        with lock:
            solvers.append(s)

    _hammer(8, worker)
    execs = {id(s._executor) for s in solvers}
    assert len(execs) == 1, "compile() raced into multiple executors"
    # serial first factor (one trace), then concurrent factors reuse it
    ref = solvers[0].factor(a)

    def factor_worker(i):
        assert np.array_equal(solvers[i % len(solvers)].factor(a), ref)

    _hammer(8, factor_worker)
    assert solvers[0].stats["jit_traces"] == 1


def test_clear_plan_cache_concurrent_with_plan():
    """clear_plan_cache() racing plan() never corrupts the cache."""
    api.clear_plan_cache()
    stop = threading.Event()

    def clearer(i):
        while not stop.is_set():
            api.clear_plan_cache()

    def planner(i):
        try:
            for rep in range(30):
                n, policy = SHAPES[rep % len(SHAPES)]
                p = repro.plan(n, _cfg(policy))
                assert p.n == n
        finally:
            stop.set()

    t = threading.Thread(target=clearer, args=(0,))
    t.start()
    try:
        _hammer(4, planner)
    finally:
        stop.set()
        t.join()
    stats = api.plan_cache_stats()
    assert 0 <= stats["size"] <= stats["max"]


def test_cache_stats_counters_move():
    api.clear_plan_cache()
    s0 = api.plan_cache_stats()
    repro.plan(32, _cfg("v3"))
    repro.plan(32, _cfg("v3"))
    s1 = api.plan_cache_stats()
    assert s1["misses"] == s0["misses"] + 1
    assert s1["hits"] == s0["hits"] + 1
