"""Static-schedule invariants: dependency safety, cache behaviour,
byte-volume ordering (paper Fig. 8), hypothesis property sweeps."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.precision import uniform_plan, LADDERS, BYTES
from repro.core.schedule import OpKind, build_schedule

POLICIES = ["sync", "async", "v1", "v2", "v3"]
ALL_POLICIES = POLICIES + ["v4"]


def _replay_dependencies(sched):
    """Simulate slot residency; every compute op must see the right tiles
    and no tile may be consumed before the producing column finished."""
    resident = {}           # slot -> (i, j)
    factored = set()        # tiles in final state
    for op in sched.ops:
        if op.kind is OpKind.LOAD:
            resident[op.slot_c] = (op.i, op.j)
        elif op.kind is OpKind.STORE:
            factored.add((op.i, op.j))
        elif op.kind is OpKind.SYRK:
            a = resident[op.slot_a]
            assert a in factored, f"SYRK consumed unfactored tile {a}"
        elif op.kind is OpKind.GEMM:
            for s in (op.slot_a, op.slot_b):
                t = resident[s]
                assert t in factored, f"GEMM consumed unfactored tile {t}"
        elif op.kind is OpKind.TRSM:
            d = resident[op.slot_a]
            assert d in factored and d[0] == d[1], \
                f"TRSM needs a factored diagonal, got {d}"
    return factored


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_dependency_safety(policy):
    sched = build_schedule(6, 8, policy)
    factored = _replay_dependencies(sched)
    # every lower tile reaches final state (v4 stores partials too, so
    # subset check for it; exact for the paper policies)
    want = {(i, j) for j in range(6) for i in range(j, 6)}
    assert factored >= want if policy == "v4" else factored == want


@pytest.mark.parametrize("policy", POLICIES)
def test_task_counts(policy):
    nt = 5
    sched = build_schedule(nt, 4, policy)
    assert sched.count(OpKind.POTRF) == nt
    assert sched.count(OpKind.TRSM) == nt * (nt - 1) // 2
    assert sched.count(OpKind.SYRK) == sum(k for k in range(nt))
    assert sched.count(OpKind.GEMM) == sum(
        k * (nt - 1 - k) for k in range(nt))


def test_volume_ordering():
    """Paper Fig. 8: V3 <= V2 <= V1 < async; stores(V*) = triangle only."""
    nt, tb = 8, 16
    loads = {p: build_schedule(nt, tb, p).loads_bytes() for p in POLICIES}
    assert loads["v3"] <= loads["v2"] <= loads["v1"] < loads["async"]
    assert loads["sync"] == loads["async"]  # same op stream, fewer streams
    tri_bytes = 8 * tb * tb * (nt * (nt + 1) // 2)
    for p in ("v1", "v2", "v3"):
        assert build_schedule(nt, tb, p).stores_bytes() == tri_bytes


def test_async_allocs():
    sched = build_schedule(5, 4, "async")
    assert sched.count(OpKind.ALLOC) == sched.count(OpKind.LOAD)


def test_v2_cache_hits_reduce_loads():
    s1 = build_schedule(8, 4, "v1")
    s2 = build_schedule(8, 4, "v2", cache_slots=100)
    assert s2.hits > 0
    assert s2.count(OpKind.LOAD) < s1.count(OpKind.LOAD)


def test_v3_pins_diagonal():
    """With a tiny cache, V3 still never reloads the diagonal inside one
    column sweep (it is pinned until the column's TRSMs finish)."""
    nt = 6
    sched = build_schedule(nt, 4, "v3", cache_slots=4)
    diag_loads_per_k = {}
    for op in sched.ops:
        if op.kind is OpKind.LOAD and op.i == op.j:
            diag_loads_per_k.setdefault((op.i, op.k), 0)
            diag_loads_per_k[(op.i, op.k)] += 1
    for (i, k), n in diag_loads_per_k.items():
        assert n == 1, f"diagonal ({i},{i}) loaded {n}x in column {k}"


def test_cache_thrash_raises():
    with pytest.raises(RuntimeError, match="pinned"):
        build_schedule(8, 4, "v3", cache_slots=3)


@settings(max_examples=25, deadline=None)
@given(
    nt=st.integers(2, 7),
    policy=st.sampled_from(POLICIES),
    slots=st.integers(6, 24),
)
def test_property_schedule_valid(nt, policy, slots):
    sched = build_schedule(nt, 4, policy, cache_slots=slots)
    factored = _replay_dependencies(sched)
    assert len(factored) == nt * (nt + 1) // 2
    # byte accounting is self-consistent
    assert sched.loads_bytes() == sum(
        o.bytes for o in sched.ops if o.kind is OpKind.LOAD)
    if policy in ("v2", "v3"):
        assert sched.hits + sched.misses == sum(
            1 for o in sched.ops
            if o.kind is OpKind.LOAD) + sched.hits


# ---------------------------------------------------------------------------
# V4 (beyond-paper 2D-blocked left-looking)

@pytest.mark.parametrize("block", [(2, 2), (4, 4), (8, 4)])
def test_v4_correct(block):
    import numpy as np
    from repro.core.cholesky import run_schedule_numpy
    from repro.core.tiling import from_tiles, random_spd, to_tiles
    nt, tb = 12, 16
    a = random_spd(nt * tb, seed=7)
    sched = build_schedule(nt, tb, "v4", block=block)
    out = run_schedule_numpy(to_tiles(a, tb), sched)
    np.testing.assert_allclose(np.tril(from_tiles(out)),
                               np.linalg.cholesky(a), atol=1e-11)


def test_v4_amortizes_loads():
    """Bigger blocks -> fewer C2G loads (the (h+w)/(h*w) scaling),
    and V4 < V3 under a bounded cache (the OOC regime)."""
    nt, tb, slots = 24, 16, 40
    v3 = build_schedule(nt, tb, "v3", cache_slots=slots)
    l44 = build_schedule(nt, tb, "v4", cache_slots=slots,
                         block=(4, 4)).loads_bytes()
    l84 = build_schedule(nt, tb, "v4", cache_slots=slots,
                         block=(8, 4)).loads_bytes()
    assert l84 < l44 < v3.loads_bytes()


def test_v4_slot_validation():
    with pytest.raises(ValueError, match="slots"):
        build_schedule(8, 16, "v4", cache_slots=5, block=(4, 4))


@settings(max_examples=15, deadline=None)
@given(nt=st.integers(2, 6), eps=st.sampled_from([1e-5, 1e-6, 1e-8]))
def test_property_mxp_bytes_le_fp64(nt, eps):
    """MxP schedules never move more bytes than uniform FP64 (Fig. 12)."""
    from repro.core.precision import assign_precision
    rng = np.random.default_rng(nt)
    norms = np.abs(rng.standard_normal((nt, nt))) * 1e-3
    norms[np.diag_indices(nt)] += 10.0
    total = float(np.sqrt((norms ** 2).sum()))
    plan = assign_precision(norms, total, eps)
    mxp = build_schedule(nt, 8, "v3", plan=plan)
    f64 = build_schedule(nt, 8, "v3", plan=uniform_plan(nt))
    assert mxp.loads_bytes() <= f64.loads_bytes()
