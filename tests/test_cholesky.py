"""End-to-end OOC Cholesky correctness: every policy/backend vs LAPACK,
MxP accuracy scaling, JAX-vs-NumPy executor agreement."""
import numpy as np
import pytest

from repro.core.cholesky import ooc_cholesky, run_schedule_numpy
from repro.core.schedule import build_schedule
from repro.core.tiling import random_spd, to_tiles, from_tiles

POLICIES = ["sync", "async", "v1", "v2", "v3"]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fp64_exact(policy, backend):
    a = random_spd(192, seed=3)
    l, sched = ooc_cholesky(a, 48, policy=policy, backend=backend)
    ref = np.linalg.cholesky(a)
    np.testing.assert_allclose(l, ref, atol=1e-12)


@pytest.mark.parametrize("tb", [16, 32, 96])
def test_tile_sizes(tb):
    a = random_spd(192, seed=5)
    l, _ = ooc_cholesky(a, tb, policy="v3")
    np.testing.assert_allclose(l, np.linalg.cholesky(a), atol=1e-12)


def test_backends_agree_mxp():
    a = random_spd(128, seed=11)
    l1, s1 = ooc_cholesky(a, 32, policy="v3", eps_target=1e-6,
                          backend="numpy")
    l2, s2 = ooc_cholesky(a, 32, policy="v3", eps_target=1e-6, backend="jax")
    assert (s1.plan.classes == s2.plan.classes).all()
    np.testing.assert_allclose(l1, l2, atol=1e-10)


def test_mxp_error_scales_with_eps():
    """Looser eps_target -> more low-precision tiles -> larger error, and
    the factorization error stays within a few orders of eps_target."""
    from repro.geo.matern import matern_covariance, generate_locations
    locs = generate_locations(256, seed=0)
    a = matern_covariance(locs, beta=0.02627)  # weak correlation
    errs = {}
    for eps in (1e-4, 1e-8):
        l, sched = ooc_cholesky(a, 64, policy="v3", eps_target=eps)
        errs[eps] = np.abs(l @ l.T - a).max()
    assert errs[1e-8] < errs[1e-4]
    assert errs[1e-8] < 1e-5


def test_mxp_policies_same_plan_same_result():
    """The precision plan is policy-independent; V1/V2/V3 must agree
    bitwise in fp64 and near-bitwise in MxP (same rounding events)."""
    a = random_spd(160, seed=2)
    ls = [ooc_cholesky(a, 32, policy=p, eps_target=1e-6,
                       backend="numpy")[0] for p in ("v1", "v2", "v3")]
    np.testing.assert_allclose(ls[0], ls[1], atol=1e-12)
    np.testing.assert_allclose(ls[1], ls[2], atol=1e-12)


def test_pallas_kernel_executor():
    """use_pallas=True (interpret mode) runs the tile kernels end-to-end."""
    import jax
    a = random_spd(128, seed=9).astype(np.float32)
    l, _ = ooc_cholesky(a, 64, policy="v3", backend="jax",
                        compute_dtype=np.float32, use_pallas=True)
    ref = np.linalg.cholesky(a.astype(np.float64))
    assert np.abs(l - ref).max() < 5e-3


def test_schedule_executor_roundtrip():
    """run_schedule_numpy leaves the strictly-upper tiles untouched and
    factorizes the lower triangle in place."""
    a = random_spd(96, seed=1)
    tiles = to_tiles(a, 32)
    sched = build_schedule(3, 32, "v3")
    out = run_schedule_numpy(tiles, sched)
    full = from_tiles(out)
    np.testing.assert_allclose(np.tril(full), np.linalg.cholesky(a),
                               atol=1e-12)
