"""Higham-Mary per-tile precision assignment (paper §IV-C, Fig. 4)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.precision import (EPS, FP8_MAX, FP8_MIN_NORMAL, LADDERS,
                                  assign_precision, class_eps, fp8_scale,
                                  fp8_unscaled_eps, scale_table, tile_amax,
                                  tile_norms, uniform_plan)


def _norms(nt, decay=1e-4, seed=0):
    rng = np.random.default_rng(seed)
    n = np.abs(rng.standard_normal((nt, nt)))
    for j in range(nt):
        for i in range(j, nt):
            n[i, j] *= decay ** abs(i - j)
    n[np.diag_indices(nt)] = 10.0
    return n, float(np.sqrt((n ** 2).sum()))


def test_diagonal_pinned_high():
    norms, total = _norms(6)
    plan = assign_precision(norms, total, 1e-6)
    for k in range(6):
        assert plan.classes[k, k] == 0


def test_monotone_in_eps():
    """Tighter eps_target never lowers any tile's precision."""
    norms, total = _norms(8)
    loose = assign_precision(norms, total, 1e-4).classes
    tight = assign_precision(norms, total, 1e-10).classes
    assert (tight <= loose).all()


def test_monotone_in_norm():
    """A tile with smaller relative norm never gets higher precision."""
    norms, total = _norms(8)
    plan = assign_precision(norms, total, 1e-6)
    nt = 8
    for j in range(nt):
        for i in range(j + 1, nt):
            for i2 in range(j + 1, nt):
                if norms[i, j] < norms[i2, j]:
                    assert plan.classes[i, j] >= plan.classes[i2, j] or \
                        norms[i, j] == norms[i2, j]


def test_distance_decay_uses_low_precision():
    """Strong off-diagonal decay must produce some sub-f32 tiles
    (the spatial-statistics structure the paper harvests, Fig. 4)."""
    norms, total = _norms(12, decay=1e-6)
    plan = assign_precision(norms, total, 1e-5)
    hist = plan.histogram()
    assert hist["bf16"] + hist["f8e4m3"] > 0


def test_gpu_ladder_matches_paper():
    assert LADDERS["gpu"] == ("f64", "f32", "f16", "f8e4m3")
    assert LADDERS["tpu"] == ("f64", "f32", "bf16", "f8e4m3")


def test_uniform_plan():
    plan = uniform_plan(5, "f64")
    assert (plan.classes == 0).all()
    assert plan.histogram()["f64"] == 15  # lower triangle of 5x5


def test_criterion_boundary():
    """A tile exactly at the threshold takes the lower precision."""
    nt = 2
    norms = np.ones((nt, nt))
    eps = 1e-6
    # pick ||A|| so that n*norm/total == eps/eps_f32 exactly
    total = nt * 1.0 / (eps / EPS["f32"])
    plan = assign_precision(norms, total, eps)
    assert plan.ladder[plan.classes[1, 0]] in ("f32", "bf16", "f8e4m3")


@settings(max_examples=20, deadline=None)
@given(nt=st.integers(2, 10), seed=st.integers(0, 99),
       eps=st.sampled_from([1e-4, 1e-6, 1e-8]))
def test_property_assignment_valid(nt, seed, eps):
    norms, total = _norms(nt, seed=seed)
    plan = assign_precision(norms, total, eps)
    assert plan.classes.min() >= 0
    assert plan.classes.max() < len(plan.ladder)
    # criterion actually holds for every demoted tile
    for j in range(nt):
        n_col = nt - j
        for i in range(j + 1, nt):
            c = plan.classes[i, j]
            if c > 0:
                ratio = n_col * norms[i, j] / total
                assert ratio <= eps / EPS[plan.ladder[c]] + 1e-12


def test_scaled_ladders():
    assert LADDERS["tpu-scaled"] == ("f64", "f32", "bf16", "f8e4m3s")
    assert LADDERS["gpu-scaled"] == ("f64", "f32", "f16", "f8e4m3s")
    assert EPS["f8e4m3s"] == EPS["f8e4m3"] == 2.0 ** -4


def test_fp8_scale_band():
    """The per-tile scale always recentres amax into (FP8_MAX/2,
    FP8_MAX] with an exact power of two; degenerate amaxes scale by 1."""
    rng = np.random.default_rng(0)
    for amax in 10.0 ** rng.uniform(-30, 30, 500):
        s = fp8_scale(float(amax))
        m, e = np.frexp(s)
        assert m == 0.5 and s > 0          # exact power of two
        assert FP8_MAX / 2 < amax * s <= FP8_MAX, (amax, s)
    # boundary pins: 448 itself stays put, one ulp above halves
    assert fp8_scale(FP8_MAX) == 1.0
    assert fp8_scale(np.nextafter(FP8_MAX, np.inf)) == 0.5
    assert fp8_scale(1.0) == 256.0
    assert fp8_scale(0.0) == 1.0
    assert fp8_scale(float("inf")) == 1.0
    assert fp8_scale(float("nan")) == 1.0


def test_fp8_unscaled_eps_degrades_out_of_band():
    u = EPS["f8e4m3"]
    assert fp8_unscaled_eps(1.0) == u                   # in band
    assert fp8_unscaled_eps(FP8_MAX) == u
    sat = fp8_unscaled_eps(10.0 * FP8_MAX)              # saturation
    assert sat == 1.0 - FP8_MAX / (10.0 * FP8_MAX)
    assert fp8_unscaled_eps(FP8_MIN_NORMAL / 1024) == 1.0   # full flush
    # the scaled class never degrades
    assert class_eps("f8e4m3s", amax=10.0 * FP8_MAX) == u
    assert class_eps("f8e4m3s", amax=FP8_MIN_NORMAL / 1024) == u
    # amax=None preserves the historical format-eps behaviour
    assert class_eps("f8e4m3", amax=None) == u


def test_classification_boundary_amax_aware():
    """The unit pin of the classification boundary: a tile whose ratio
    sits between eps_target and 16x eps_target is FP8-eligible exactly
    when the class achieves the format's 2^-4 — granted in band, denied
    (unscaled) once amax saturates e4m3, kept (scaled) regardless."""
    nt, eps = 2, 1e-6
    norms = np.ones((nt, nt))
    total = nt / (8.0 * eps)            # ratio == 8 eps, needs eps <= 2^-3
    in_band = np.full((nt, nt), 1.0)
    saturating = np.full((nt, nt), 1e4)
    grant = assign_precision(norms, total, eps, ladder="tpu",
                             tile_amax=in_band)
    deny = assign_precision(norms, total, eps, ladder="tpu",
                            tile_amax=saturating)
    keep = assign_precision(norms, total, eps, ladder="tpu-scaled",
                            tile_amax=saturating)
    assert grant.name(1, 0) == "f8e4m3"
    assert deny.name(1, 0) != "f8e4m3"
    assert keep.name(1, 0) == "f8e4m3s"


def test_scale_table_rides_plan():
    from repro.core.tiling import to_tiles
    rng = np.random.default_rng(1)
    x = rng.standard_normal((64, 64))
    a = x @ x.T + 64 * np.eye(64)
    tiles = to_tiles(a, 16)
    norms, total = tile_norms(tiles)
    plan = assign_precision(norms, total, 1e-4, ladder="tpu-scaled",
                            tile_amax=tile_amax(tiles))
    table = scale_table(tiles, plan)
    am = tile_amax(tiles)
    for j in range(plan.nt):
        for i in range(plan.nt):
            if plan.name(i, j) == "f8e4m3s":
                assert table[i, j] == np.float32(fp8_scale(float(am[i, j])))
            else:
                assert table[i, j] == 1.0


def test_tile_norms_symmetric_weighting():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((64, 64))
    a = x @ x.T + 64 * np.eye(64)
    from repro.core.tiling import to_tiles
    tiles = to_tiles(a, 16)
    norms, total = tile_norms(tiles)
    assert abs(total - np.linalg.norm(a)) / np.linalg.norm(a) < 1e-12
