"""repro.tune: calibration, candidate search, db persistence, planner
integration, and the tuner's feasibility/optimality invariants."""
import json

import numpy as np
import pytest

import repro
from repro import tune
from repro.core import api
from repro.core.analytics import HW, HardwareModel, chrome_trace, simulate
from repro.core.schedule import (build_schedule, default_cache_slots,
                                 min_cache_slots)

from _hypothesis_compat import given, settings, st

PRESETS = tuple(HW)


@pytest.fixture(autouse=True)
def _fresh_tuning_state():
    tune.clear_tuning_cache()
    tune.set_default_hardware(None)
    api.clear_plan_cache()
    yield
    tune.clear_tuning_cache()
    tune.set_default_hardware(None)
    api.clear_plan_cache()


def _ooc_n(hw: HardwareModel) -> int:
    """Smallest power of two whose f64 matrix is ~2x device memory."""
    n = 1 << 12
    while 8 * n * n < 2 * hw.mem_bytes:
        n <<= 1
    return n


# ---------------------------------------------------------------------------
# search invariants

def test_every_candidate_is_feasible_on_every_preset():
    """The search's core promise: tb | n, slot minimums respected, and
    the device-memory cap honoured — for every candidate, not just the
    winner — at an n where the matrix genuinely exceeds mem_bytes."""
    for name in PRESETS:
        hw = HW[name]
        n = _ooc_n(hw)
        assert 8 * n * n > hw.mem_bytes          # genuinely out-of-core
        res = tune.search(n, hw)
        assert res.candidates
        for cand in res.candidates:
            c = cand.config
            assert tune.is_feasible(n, c, hw), (name, c)
            assert n % c.tb == 0
            assert c.cache_slots >= min_cache_slots(c.policy, c.block)
            assert c.cache_slots * c.tb * c.tb * 8 <= hw.mem_bytes
            assert not c.needs_tuning


def test_tuned_beats_or_matches_default_on_every_preset():
    """Acceptance bar: at OOC sizes the tuned config's simulated makespan
    is <= the hand-picked default (V3, nt~32, builder-default slots)."""
    for name in PRESETS:
        hw = HW[name]
        n = _ooc_n(hw)
        best = tune.search(n, hw).best
        dflt = tune.score_config(n, tune.default_config(n), hw)
        assert best.makespan <= dflt.makespan * (1 + 1e-12), name


def test_search_is_deterministic():
    hw = HW["tpu-v5e"]
    n = _ooc_n(hw)
    r1 = tune.search(n, hw)
    r2 = tune.search(n, hw)
    assert [c.config for c in r1.candidates] == \
        [c.config for c in r2.candidates]
    assert [c.makespan for c in r1.candidates] == \
        [c.makespan for c in r2.candidates]


def test_search_respects_pinned_dimensions():
    hw = HW["gh200"]
    n = _ooc_n(hw)
    tb = n // 16
    res = tune.search(n, hw, repro.CholeskyConfig(tb=tb, policy="auto"))
    assert all(c.config.tb == tb for c in res.candidates)
    assert len({c.config.policy for c in res.candidates}) > 1
    res = tune.search(n, hw, repro.CholeskyConfig(tb=0, policy="v3"))
    assert all(c.config.policy == "v3" for c in res.candidates)
    assert len({c.config.tb for c in res.candidates}) > 1


def test_search_winner_simulates_to_its_reported_makespan():
    """The ranked numbers are exact replays: rebuilding the winner's
    schedule and simulating it reproduces the reported makespan."""
    hw = HW["tpu-v5e"]
    n = _ooc_n(hw)
    best = tune.search(n, hw).best
    c = best.config
    sched = build_schedule(n // c.tb, c.tb, c.policy, c.cache_slots,
                           block=c.block)
    assert simulate(sched, hw).makespan == pytest.approx(
        best.makespan, rel=1e-12)


@settings(max_examples=6, deadline=None)
@given(preset=st.sampled_from(PRESETS),
       nt=st.integers(4, 12),
       ndev=st.integers(1, 2))
def test_property_search_feasible_and_ranked(preset, nt, ndev):
    """Hypothesis-compat sweep: any (preset, n, ndev) search returns
    feasible candidates in monotone makespan order, winner first.
    Slots pinned (feasible for every policy) to bound the sweep's cost —
    the slot axis is covered by the preset tests above."""
    hw = HW[preset]
    n = nt * 256
    cfg = repro.CholeskyConfig(tb=0, policy="auto", ndev=ndev,
                               cache_slots=24)
    res = tune.search(n, hw, cfg)
    spans = [c.makespan for c in res.candidates]
    assert spans == sorted(spans)
    assert res.best.makespan == min(spans)
    for cand in res.candidates:
        assert tune.is_feasible(n, cand.config, hw)
        assert cand.config.ndev == ndev


def test_search_skips_infeasible_policies_under_pinned_slots():
    """Regression: a pinned budget below some policy's minimum used to
    *raise* out of the search (the feasibility probe constructed a
    validating config) instead of filtering that policy out."""
    hw = HW["gh200"]
    # 8 slots: v4 (needs 22) must be skipped, v2/v3/sync/async/v1 remain
    res = tune.search(4096, hw, repro.CholeskyConfig(
        tb=0, policy="auto", cache_slots=8))
    pols = {c.config.policy for c in res.candidates}
    assert "v4" not in pols and {"v2", "v3"} <= pols
    assert all(c.config.cache_slots == 8 for c in res.candidates)
    # a custom v4 block with policy="auto" searches too (non-v4
    # candidates shed the block instead of failing validation)
    res = tune.search(4096, hw, repro.CholeskyConfig(
        tb=0, policy="auto", cache_slots=30, block=(2, 3)))
    assert any(c.config.policy != "v4" for c in res.candidates)
    for c in res.candidates:
        assert c.config.block == ((2, 3) if c.config.policy == "v4"
                                  else (4, 4))


def test_plan_auto_cache_tracks_default_hardware():
    """Regression: the auto-key plan cache used to mask
    set_default_hardware() — plan() returned the plan tuned for the
    previous model."""
    import dataclasses
    n = 2048
    auto = repro.CholeskyConfig(tb=0, policy="auto")
    p1 = repro.plan(n, auto)
    # 8 MB of device memory cannot hold p1's tile size at any policy
    # minimum: the winner must change under the new default model
    tiny = dataclasses.replace(HW["gh200"], mem_bytes=8e6, name="tiny-mem")
    tune.set_default_hardware(tiny)
    p2 = repro.plan(n, auto)
    assert p2 is not p1 and p2.config != p1.config
    assert p2.config == tune.resolve_config(n, auto)
    assert p2.config.tb * p2.config.tb * 8 * p2.config.cache_slots <= 8e6
    # a config-side hw pin is unaffected by the process default
    pinned = repro.CholeskyConfig(tb=0, policy="auto", hw="a100-pcie")
    p3 = repro.plan(n, pinned)
    tune.set_default_hardware(None)
    assert repro.plan(n, pinned) is p3


def test_db_hit_respects_pinned_block(tmp_path):
    """Regression: _matches_pins ignored the v4 block, so a db hit could
    hand back a winner violating the requested update block."""
    db = tune.TuningDB(str(tmp_path / "db.json"))
    n = 2048
    c44 = tune.resolve_config(
        n, repro.CholeskyConfig(tb=0, policy="v4", hw="gh200"), db=db)
    assert c44.block == (4, 4)
    c23 = tune.resolve_config(
        n, repro.CholeskyConfig(tb=0, policy="v4", block=(2, 3),
                                hw="gh200"), db=db)
    assert c23.block == (2, 3)


def test_memory_cap_forces_small_footprint():
    """Shrinking mem_bytes must shrink every candidate's footprint (the
    OOC constraint the paper sweeps by hand across platforms)."""
    import dataclasses
    hw = HW["a100-pcie"]
    tiny = dataclasses.replace(hw, mem_bytes=2e9)
    n = 1 << 13
    for cand in tune.search(n, tiny).candidates:
        assert cand.footprint_bytes <= tiny.mem_bytes


def test_mxp_dimension_with_sample_matrix():
    """eps_target + sample adds the precision dimension: the winner at a
    loose eps on a strongly-diagonal matrix should move fewer bytes than
    the f64 winner."""
    n = 1024
    rng = np.random.default_rng(0)
    b = rng.standard_normal((n, n)) / np.sqrt(n)
    a = b @ b.T * 1e-7 + np.diag(1.0 + np.abs(rng.standard_normal(n)))
    hw = HW["gh200"]
    cfg = repro.CholeskyConfig(tb=n // 8, policy="auto")
    f64 = tune.search(n, hw, cfg)
    mxp = tune.tune(n, cfg, hw=hw, sample=a, eps_target=1e-5, use_db=False)
    assert mxp.best.config.plan is not None
    assert mxp.best.loads_bytes < f64.best.loads_bytes
    # the tuned MxP config is directly plannable and factors correctly
    l = repro.plan(n, mxp.best.config).compile().factor(a)
    assert np.abs(l @ l.T - a).max() / np.abs(a).max() < 1e-4


# ---------------------------------------------------------------------------
# db persistence

def test_db_round_trip(tmp_path):
    path = str(tmp_path / "tune.json")
    db = tune.TuningDB(path)
    plan = repro.uniform_plan(8, "f32")
    cfg = repro.CholeskyConfig(tb=128, policy="v4", cache_slots=30,
                               block=(4, 4), plan=plan, hw="gh200")
    db.put("fp123", 1024, 1, 1e-6, cfg, predicted_makespan=1.25,
           hw_name="gh200", hw_source="datasheet")
    # a fresh handle reads the same config back, by value
    db2 = tune.TuningDB(path)
    got = db2.get("fp123", 1024, 1, 1e-6)
    assert got == cfg
    assert got.plan == plan
    rec = db2.get_record("fp123", 1024, 1, 1e-6)
    assert rec["predicted_makespan_s"] == 1.25
    # key misses: different fingerprint / n / ndev / eps
    assert db2.get("other", 1024, 1, 1e-6) is None
    assert db2.get("fp123", 2048, 1, 1e-6) is None
    assert db2.get("fp123", 1024, 2, 1e-6) is None
    assert db2.get("fp123", 1024, 1, None) is None
    # the file is plain JSON (the contract: diffable, hand-editable)
    blob = json.loads(open(path).read())
    assert blob["schema"] == 1 and len(blob["records"]) == 1


def test_db_in_memory_mode():
    db = tune.TuningDB(None)
    cfg = repro.CholeskyConfig(tb=64, policy="v3")
    db.put("fp", 512, 1, None, cfg, 0.5)
    assert db.get("fp", 512, 1, None) == cfg
    assert db.path is None


def test_db_corrupt_file_degrades_to_empty(tmp_path):
    path = str(tmp_path / "tune.json")
    with open(path, "w") as f:
        f.write("{not json")
    assert len(tune.TuningDB(path)) == 0


# ---------------------------------------------------------------------------
# planner integration: plan(n, auto-config)

def test_plan_resolves_auto_config():
    cfg = repro.CholeskyConfig(tb=0, policy="auto", hw="a100-pcie")
    pl = repro.plan(2048, cfg)
    c = pl.config
    assert not c.needs_tuning
    assert 2048 % c.tb == 0
    assert c.policy in ("sync", "async", "v1", "v2", "v3", "v4")
    assert tune.is_feasible(2048, c, HW["a100-pcie"])
    # repeat plan() with the same auto config: same cached plan object
    assert repro.plan(2048, cfg) is pl
    # the resolved concrete config keys the same plan too
    assert repro.plan(2048, c) is pl


def test_plan_auto_resolution_is_deterministic_and_solves():
    n = 512
    before = api.schedule_build_count()
    solver = repro.plan(n, repro.CholeskyConfig(tb=0, policy="auto")).compile()
    a = repro.random_spd(n, seed=3)
    l = solver.factor(a)
    assert np.abs(l - np.linalg.cholesky(a)).max() < 1e-10
    api.clear_plan_cache()
    tune.clear_tuning_cache()
    cfg2 = repro.plan(n, repro.CholeskyConfig(tb=0, policy="auto")).config
    assert cfg2 == solver.config       # same winner after a full reset
    assert api.schedule_build_count() - before >= 1


def test_plan_auto_respects_pinned_policy():
    pl = repro.plan(1024, repro.CholeskyConfig(tb=0, policy="v1"))
    assert pl.config.policy == "v1" and pl.config.tb > 0


def test_resolve_config_uses_db_and_pins(tmp_path):
    db = tune.TuningDB(str(tmp_path / "db.json"))
    auto = repro.CholeskyConfig(tb=0, policy="auto", hw="gh200")
    c1 = tune.resolve_config(1024, auto, db=db)
    assert len(db) == 1
    # db hit: no new record, same config
    assert tune.resolve_config(1024, auto, db=db) == c1
    assert len(db) == 1
    # a pinned request the cached winner violates re-searches
    pinned = repro.CholeskyConfig(tb=0, policy="sync", hw="gh200")
    c2 = tune.resolve_config(1024, pinned, db=db)
    assert c2.policy == "sync"


def test_set_default_hardware_changes_resolution():
    import dataclasses
    n = 1024
    # a model with absurd launch overhead punishes small tiles hard
    slow = dataclasses.replace(HW["gh200"], launch_overhead=5e-2,
                               name="slow-launch")
    fast_cfg = tune.resolve_config(n, repro.CholeskyConfig(
        tb=0, policy="auto"))
    tune.set_default_hardware(slow)
    slow_cfg = tune.resolve_config(n, repro.CholeskyConfig(
        tb=0, policy="auto"))
    assert slow_cfg.tb >= fast_cfg.tb
    assert slow_cfg.tb == n // 2       # fewest ops the search allows


def test_specialize_on_open_tb_raises():
    cfg = repro.CholeskyConfig(tb=0, policy="auto", eps_target=1e-6)
    with pytest.raises(ValueError, match="tb"):
        cfg.specialize(repro.random_spd(256, seed=0))


# ---------------------------------------------------------------------------
# eager config validation (mem cap + slot minimums)

def test_config_mem_cap_validation():
    # 2000 slots of 4096^2 f64 tiles = 268 GB > gh200's 96 GB
    with pytest.raises(ValueError, match="mem_bytes"):
        repro.CholeskyConfig(tb=4096, policy="v3", cache_slots=2000,
                             hw="gh200")
    # same budget is fine without a device bound declared
    repro.CholeskyConfig(tb=4096, policy="v3", cache_slots=2000)
    with pytest.raises(ValueError, match="unknown hw"):
        repro.CholeskyConfig(tb=64, hw="dgx-9000")


@pytest.mark.parametrize("policy, bad", [
    ("v3", 3), ("v2", 2), ("v1", 3), ("sync", 2), ("async", 1),
])
def test_config_slot_minimum_validation(policy, bad):
    """An unbuildable slot budget now fails at config construction, not
    as a cache-thrash RuntimeError deep inside schedule building."""
    with pytest.raises(ValueError, match="cache slots"):
        repro.CholeskyConfig(tb=64, policy=policy, cache_slots=bad)
    repro.CholeskyConfig(tb=64, policy=policy,
                         cache_slots=min_cache_slots(policy))


# ---------------------------------------------------------------------------
# calibration (live CPU backend)

def test_calibrate_end_to_end_and_drives_search():
    model = tune.calibrate(tb=32, repeats=1, transfer_sizes_mb=(1,))
    assert model.source == "measured"
    assert model.fingerprint == tune.hardware_fingerprint()
    assert model.mem_bytes > 0
    assert model.h2d_bw > 0 and model.d2h_bw > 0
    assert model.launch_overhead > 0
    for task in ("potrf", "trsm", "syrk", "gemm"):
        for cls in ("f64", "f32", "bf16", "f8e4m3"):
            assert model.kernel_flops[task][cls] > 0, (task, cls)
    assert set(model.flops) >= {"f64", "f32", "bf16", "f8e4m3"}
    # the measured model drives the same search path as the presets
    res = tune.tune(4096, hw=model, use_db=False)
    assert tune.is_feasible(4096, res.config, model)
    assert res.hw.source == "measured"
    # and round-trips through its JSON form
    clone = tune.model_from_dict(tune.model_to_dict(model))
    assert clone == model


def test_task_rate_falls_back_to_class_peak():
    hw = HW["gh200"]
    assert hw.task_rate("gemm", "f64") == hw.flops["f64"]
    measured = HardwareModel(
        "m", {"f64": 1e12}, 1e9, 1e9, 0.0,
        kernel_flops={"gemm": {"f64": 2e12}})
    assert measured.task_rate("gemm", "f64") == 2e12
    assert measured.task_rate("potrf", "f64") == 1e12   # not measured


# ---------------------------------------------------------------------------
# chrome trace export

def test_chrome_trace_single_and_multi(tmp_path):
    hw = HW["gh200"]
    r = repro.plan(256, tb=64, policy="v3").simulate(
        hw, record_timeline=True)
    path = tmp_path / "t.json"
    trace = chrome_trace(r, path)
    blob = json.loads(path.read_text())
    assert blob["traceEvents"] == trace["traceEvents"]
    spans = [e for e in blob["traceEvents"] if e["ph"] == "X"]
    meta = [e for e in blob["traceEvents"] if e["ph"] == "M"]
    assert len(spans) == len(r.timeline)
    assert {m["args"]["name"] for m in meta} == {"h2d", "cmp", "d2h"}
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["ts"] + e["dur"] <= r.makespan * 1e6 * (1 + 1e-9)
    # multi-device timelines carry per-device engines + the shared link
    rm = repro.plan(256, tb=64, policy="v3", ndev=2).simulate(
        hw, record_timeline=True)
    tm = chrome_trace(rm)
    names = {e["args"]["name"] for e in tm["traceEvents"]
             if e["ph"] == "M"}
    assert "link" in names and "d0:cmp" in names and "d1:cmp" in names
    # timeline not recorded -> actionable error
    with pytest.raises(ValueError, match="record_timeline"):
        chrome_trace(repro.plan(256, tb=64, policy="v3").simulate(hw))
