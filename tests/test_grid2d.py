"""2D block-cyclic (p x q) grid ownership: ownership-map invariants,
scoped-broadcast volumes, replay correctness, simulator accounting, and
the tuner's grid dimension.

The acceptance bar of PR 5 lives here: at ndev=4, NT=8, the (2, 2) grid
schedule's *scheduled* inter-device broadcast bytes are strictly below
the 1D schedule's (the executed counterpart is pinned on real forced
host devices in tests/test_backend_equivalence.py), with every grid and
policy staying exact against LAPACK through the NumPy replay.
"""
import numpy as np
import pytest

import repro
from repro import tune
from repro.core.analytics import HW, simulate_multi, volume_report_multi
from repro.core.cholesky import run_multidevice_numpy
from repro.core.distributed import (grid_broadcast_bytes,
                                    panel_broadcast_bytes)
from repro.core.schedule import OpKind, build_multidevice_schedule
from repro.core.tiling import TileLayout, from_tiles, random_spd, to_tiles

from _hypothesis_compat import given, settings, st

POLICIES = ["sync", "v1", "v2", "v3"]
GRIDS4 = [(4, 1), (2, 2), (1, 4)]


def _grids_of(ndev):
    return [(p, ndev // p) for p in range(1, ndev + 1) if ndev % p == 0]


# ---------------------------------------------------------------------------
# ownership map

@settings(max_examples=20, deadline=None)
@given(nt=st.integers(1, 12), p=st.integers(1, 4), q=st.integers(1, 4))
def test_property_every_tile_owned_exactly_once(nt, p, q):
    """The grid ownership map is a partition: every tile has exactly one
    owner, and that owner is a valid device id."""
    ndev = p * q
    layout = TileLayout(nt * 8, 8)
    for i in range(nt):
        for j in range(nt):
            owners = [d for d in range(ndev)
                      if layout.owner_grid(i, j, (p, q)) == d]
            assert len(owners) == 1
            assert 0 <= owners[0] < ndev
    # the 1D degenerate agrees with the historical row rule
    for i in range(nt):
        assert layout.owner_grid(i, 0, (ndev, 1)) == layout.owner(i, ndev)


@settings(max_examples=12, deadline=None)
@given(nt=st.integers(2, 9), p=st.integers(1, 3), q=st.integers(1, 3),
       policy=st.sampled_from(POLICIES))
def test_property_tasks_partition_by_owner(nt, p, q, policy):
    """Every tile's finalizing STORE lands on exactly the stream of its
    grid owner — across all grids and policies."""
    ndev = p * q
    layout = TileLayout(nt * 8, 8)
    m = build_multidevice_schedule(nt, 8, ndev, policy, grid=(p, q))
    stored = {}
    for d in range(ndev):
        for op in m.streams[d]:
            if op.kind is OpKind.STORE:
                assert layout.owner_grid(op.i, op.j, (p, q)) == d, \
                    (op.i, op.j, d)
                stored[(op.i, op.j)] = True
    # every lower tile is stored at least once (sync stores partials too)
    for j in range(nt):
        for i in range(j, nt):
            assert (i, j) in stored
    # compute totals are grid-invariant (work moves, it never duplicates)
    assert m.count(OpKind.POTRF) == nt
    assert m.count(OpKind.TRSM) == nt * (nt - 1) // 2
    assert m.count(OpKind.GEMM) == sum(k * (nt - 1 - k) for k in range(nt))


# ---------------------------------------------------------------------------
# broadcast volumes

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("grid", GRIDS4)
def test_scheduled_volume_matches_analytic(policy, grid):
    nt, tb = 10, 8
    m = build_multidevice_schedule(nt, tb, 4, policy, grid=grid)
    assert m.bcast_bytes() == grid_broadcast_bytes(nt, tb, grid)
    # BCAST egress always equals the sum of its receivers' RECV ingress
    assert sum(o.bytes for s in m.streams for o in s
               if o.kind is OpKind.BCAST) == m.bcast_bytes()


def test_grid_broadcast_bytes_reduces_to_1d():
    for ndev in (1, 2, 3, 4, 8):
        assert grid_broadcast_bytes(9, 16, (ndev, 1)) == \
            panel_broadcast_bytes(9, 16, ndev)


@pytest.mark.parametrize("ndev", [4, 6, 8])
def test_2d_volume_below_1d_for_ndev_ge_4(ndev):
    """Every true 2D factorization of ndev >= 4 moves strictly fewer
    broadcast bytes than the 1D tile-row layout — scheduled, for every
    policy (the broadcast structure is policy-independent)."""
    nt, tb = 8, 8
    one_d = build_multidevice_schedule(nt, tb, ndev, "v3")
    for grid in _grids_of(ndev):
        if grid == (ndev, 1):
            continue
        m = build_multidevice_schedule(nt, tb, ndev, "v3", grid=grid)
        assert m.bcast_bytes() < one_d.bcast_bytes(), grid


def test_acceptance_ndev4_nt8_grid22_strictly_below_1d():
    """PR 5 acceptance: at ndev=4, NT=8, the (2, 2) grid's scheduled
    broadcast bytes are strictly below the 1D schedule's."""
    nt, tb = 8, 32
    m1 = build_multidevice_schedule(nt, tb, 4, "v3")
    m2 = build_multidevice_schedule(nt, tb, 4, "v3", grid=(2, 2))
    assert m2.bcast_bytes() < m1.bcast_bytes()
    # and the event simulator pushes exactly those bytes over the link
    for hw in (HW["a100-pcie"], HW["gh200"]):
        r1, r2 = simulate_multi(m1, hw), simulate_multi(m2, hw)
        assert r2.link_bytes == m2.bcast_bytes() < r1.link_bytes


def test_mxp_grid_volume_follows_classes():
    from repro.core.precision import assign_precision
    nt = 8
    norms = np.fromfunction(
        lambda i, j: 0.25 + ((3 * i + 5 * j) % 7) / 7.0, (nt, nt))
    norms *= 1e-6
    norms[np.diag_indices(nt)] = 10.0
    plan = assign_precision(norms, float(np.sqrt((norms ** 2).sum())), 1e-5)
    mxp = build_multidevice_schedule(nt, 16, 4, "v3", plan=plan,
                                     grid=(2, 2))
    f64 = build_multidevice_schedule(nt, 16, 4, "v3", grid=(2, 2))
    assert 0 < mxp.bcast_bytes() < f64.bcast_bytes()


# ---------------------------------------------------------------------------
# replay correctness + structural invariants

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("grid", [(2, 2), (1, 4), (2, 3), (3, 2), (1, 2)])
def test_numpy_replay_exact_on_grids(policy, grid):
    nt, tb = 12, 16
    ndev = grid[0] * grid[1]
    a = random_spd(nt * tb, seed=11)
    m = build_multidevice_schedule(nt, tb, ndev, policy, grid=grid)
    out = run_multidevice_numpy(to_tiles(a, tb), m)
    np.testing.assert_allclose(np.tril(from_tiles(out)),
                               np.linalg.cholesky(a), atol=1e-10)


def test_host_landing_recvs_only_on_2d_grids():
    """Row-scoped ownership RECVs (slot_c < 0) exist iff q > 1, and they
    target exactly the finalized off-diagonal column tiles."""
    nt = 8
    m1 = build_multidevice_schedule(nt, 8, 4, "v3")
    assert all(o.slot_c >= 0 for s in m1.streams for o in s
               if o.kind is OpKind.RECV)
    m2 = build_multidevice_schedule(nt, 8, 4, "v3", grid=(2, 2))
    host_recvs = [o for s in m2.streams for o in s
                  if o.kind is OpKind.RECV and o.slot_c < 0]
    assert host_recvs
    for o in host_recvs:
        assert o.i > o.j and o.j == o.k     # finalized (m, k), m > k
    # each off-diagonal tile reaches its q-1 = 1 grid-row peer exactly once
    assert len(host_recvs) == nt * (nt - 1) // 2


def test_column_device_order_covers_all_ops():
    """iter_column_order's internal assertion: every op of every stream
    is yielded exactly once, for 2D grids too."""
    for grid in GRIDS4 + [(2, 3)]:
        ndev = grid[0] * grid[1]
        m = build_multidevice_schedule(9, 8, ndev, "v2", grid=grid)
        seen = sum(1 for _ in m.iter_column_order())
        assert seen == sum(len(s) for s in m.streams)


def test_simulate_multi_invariants_on_grids():
    for grid in GRIDS4:
        m = build_multidevice_schedule(12, 128, 4, "v3", grid=grid)
        for hw in HW.values():
            r = simulate_multi(m, hw)
            assert r.link_bytes == m.bcast_bytes()
            for d, dev in enumerate(r.devices):
                assert r.makespan >= dev.finish - 1e-12
                assert dev.h2d_bytes == m.loads_bytes(d)
                assert dev.d2h_bytes == m.stores_bytes(d)
            assert 0 < r.compute_efficiency <= 1.0 + 1e-12


def test_modeled_2d_makespan_improves_on_congested_link():
    """The point of the 2D grid: on a slow shared interconnect the
    reduced broadcast volume shows up as modeled makespan."""
    from repro.core.distributed import modeled_scaling
    nt, tb = 16, 1024
    m1 = build_multidevice_schedule(nt, tb, 4, "v3")
    m2 = build_multidevice_schedule(nt, tb, 4, "v3", grid=(2, 2))
    hw = HW["a100-pcie"]
    assert simulate_multi(m2, hw).makespan < simulate_multi(m1, hw).makespan
    rows = modeled_scaling(nt, tb, ndevs=(1, 4), hw_name="a100-pcie",
                           grid_of={4: (2, 2)})
    assert rows[1]["grid"] == [2, 2]
    assert rows[1]["bcast_bytes"] == m2.bcast_bytes()


def test_volume_report_multi_carries_grid():
    m = build_multidevice_schedule(8, 16, 4, "v2", grid=(2, 2))
    rep = volume_report_multi(m)
    assert rep["grid"] == [2, 2]
    assert sum(d["recv_bytes"] for d in rep["per_device"]) == \
        rep["bcast_bytes"]


# ---------------------------------------------------------------------------
# config + planner + tuner integration

def test_config_grid_validation():
    repro.CholeskyConfig(tb=32, ndev=4, grid=(2, 2))
    repro.CholeskyConfig(tb=32, ndev=4, grid=(1, 4))
    with pytest.raises(ValueError, match="factor ndev"):
        repro.CholeskyConfig(tb=32, ndev=4, grid=(3, 2))
    with pytest.raises(ValueError, match="two positive ints"):
        repro.CholeskyConfig(tb=32, ndev=4, grid=(4,))
    with pytest.raises(ValueError, match="two positive ints"):
        repro.CholeskyConfig(tb=32, ndev=4, grid=(4, 0))
    # hashable by value (keys the plan cache)
    a = repro.CholeskyConfig(tb=32, ndev=4, grid=(2, 2))
    b = repro.CholeskyConfig(tb=32, ndev=4, grid=[2, 2])
    assert a == b and hash(a) == hash(b)


def test_plan_threads_grid_to_schedule():
    from repro.core import api
    api.clear_plan_cache()
    pl = repro.plan(128, tb=16, policy="v3", ndev=4, grid=(2, 2),
                    backend="numpy")
    assert pl.schedule.grid == (2, 2)
    solver = pl.compile()
    a = random_spd(128, seed=3)
    l = solver.factor(a)
    np.testing.assert_allclose(l, np.linalg.cholesky(a), atol=1e-10)
    # grid is part of the plan-cache key
    pl1d = repro.plan(128, tb=16, policy="v3", ndev=4, backend="numpy")
    assert pl1d is not pl and pl1d.schedule.grid == (4, 1)
    # ...but an explicit 1D pin canonicalizes to the same cached plan as
    # grid=None (a tuner winner must not re-jit an identical schedule)
    pinned = repro.plan(128, tb=16, policy="v3", ndev=4, grid=(4, 1),
                        backend="numpy")
    assert pinned is pl1d


def test_search_enumerates_grids_and_prefers_cheaper_links():
    hw = HW["a100-pcie"]
    res = tune.search(1024, hw, repro.CholeskyConfig(
        tb=128, policy="v3", ndev=4, cache_slots=24))
    grids = {tuple(c.row()["grid"]) for c in res.candidates}
    assert grids == set(GRIDS4)
    by_grid = {tuple(c.row()["grid"]): c for c in res.candidates}
    assert by_grid[(2, 2)].link_bytes < by_grid[(4, 1)].link_bytes
    # a pinned grid freezes the axis
    res2 = tune.search(1024, hw, repro.CholeskyConfig(
        tb=128, policy="v3", ndev=4, cache_slots=24, grid=(2, 2)))
    assert all(c.config.grid == (2, 2) for c in res2.candidates)
    # winners validate + build end to end
    best = res.best.config
    assert not best.needs_tuning
    repro.CholeskyConfig(**{f.name: getattr(best, f.name)
                            for f in best.__dataclass_fields__.values()})


def test_resolve_config_respects_grid_pin(tmp_path):
    db = tune.TuningDB(str(tmp_path / "db.json"))
    open_cfg = repro.CholeskyConfig(tb=0, policy="auto", ndev=4,
                                    hw="a100-pcie")
    c_open = tune.resolve_config(1024, open_cfg, db=db)
    assert c_open.grid is not None
    pinned = repro.CholeskyConfig(tb=0, policy="auto", ndev=4,
                                  grid=(1, 4), hw="a100-pcie")
    c_pin = tune.resolve_config(1024, pinned, db=db)
    assert c_pin.grid == (1, 4)


def test_db_round_trips_grid(tmp_path):
    db = tune.TuningDB(str(tmp_path / "db.json"))
    cfg = repro.CholeskyConfig(tb=64, policy="v3", ndev=4, grid=(2, 2))
    db.put("fp", 512, 4, None, cfg, 0.1)
    got = tune.TuningDB(str(tmp_path / "db.json")).get("fp", 512, 4, None)
    assert got == cfg and got.grid == (2, 2)


# ---------------------------------------------------------------------------
# measured link bandwidth -> simulate_multi defaults

def test_simulate_multi_uses_model_link_bw_by_default():
    import dataclasses
    m = build_multidevice_schedule(8, 256, 4, "v3", grid=(2, 2))
    hw = HW["a100-pcie"]
    measured = dataclasses.replace(hw, link_bw=4 * hw.h2d_bw)
    r_default = simulate_multi(m, measured)
    r_explicit = simulate_multi(m, hw, link_bw=4 * hw.h2d_bw)
    assert r_default.makespan == r_explicit.makespan
    assert r_default.link_busy == r_explicit.link_busy
    # presets carry no measured link: they fall back to h2d_bw
    assert simulate_multi(m, hw).makespan == \
        simulate_multi(m, hw, link_bw=hw.h2d_bw).makespan


def test_calibrate_reports_link_bw_field():
    model = tune.calibrate(tb=16, repeats=1, transfer_sizes_mb=(1,))
    # single-device processes measure nothing and fall back (0.0); with
    # >= 2 visible devices the measured rate must be positive (the CI
    # multi-device leg runs this file under 4 forced host devices)
    import jax
    if len(jax.devices()) >= 2:
        assert model.link_bw > 0
    else:
        assert model.link_bw == 0.0
    clone = tune.model_from_dict(tune.model_to_dict(model))
    assert clone == model
