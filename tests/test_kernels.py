"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle,
swept over shapes and dtypes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

SHAPES = [64, 128, 256, 384]
DTYPES = [jnp.float32, jnp.bfloat16]


def _spd(n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, n)).astype(np.float32) / np.sqrt(n)
    a = x @ x.T + 2.0 * np.eye(n, dtype=np.float32)
    return jnp.asarray(a, dtype=dtype)


def _mat(n, dtype, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, n)).astype(np.float32),
                       dtype=dtype)


def _tol(dtype):
    return {"float32": 2e-4, "bfloat16": 6e-2}[jnp.dtype(dtype).name]


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_potrf(n, dtype):
    a = _spd(n, dtype)
    got = np.asarray(ops.potrf(a, interpret=True), np.float64)
    want = np.asarray(ref.potrf_ref(a.astype(jnp.float32)), np.float64)
    np.testing.assert_allclose(np.tril(got), np.tril(want),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_trsm(n, dtype):
    l = jnp.asarray(np.asarray(
        ref.potrf_ref(_spd(n, jnp.float32))), dtype=dtype)
    c = _mat(n, dtype)
    got = np.asarray(ops.trsm(l, c, interpret=True), np.float64)
    want = np.asarray(ref.trsm_ref(l.astype(jnp.float32),
                                   c.astype(jnp.float32)), np.float64)
    np.testing.assert_allclose(got, want, atol=20 * _tol(dtype),
                               rtol=20 * _tol(dtype))


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_syrk(n, dtype):
    c, a = _spd(n, dtype), _mat(n, dtype)
    got = np.asarray(ops.syrk_update(c, a, interpret=True), np.float64)
    want = np.asarray(ref.syrk_update_ref(c.astype(jnp.float32),
                                          a.astype(jnp.float32)), np.float64)
    np.testing.assert_allclose(got, want, atol=n * _tol(dtype) / 16,
                               rtol=_tol(dtype))


@pytest.mark.parametrize("n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gemm(n, dtype):
    c, a, b = _spd(n, dtype), _mat(n, dtype), _mat(n, dtype, seed=7)
    got = np.asarray(ops.gemm_update(c, a, b, interpret=True), np.float64)
    want = np.asarray(ref.gemm_update_ref(
        c.astype(jnp.float32), a.astype(jnp.float32),
        b.astype(jnp.float32)), np.float64)
    np.testing.assert_allclose(got, want, atol=n * _tol(dtype) / 16,
                               rtol=_tol(dtype))


def test_gemm_fp8_inputs():
    """fp8-e4m3 operands accumulate in f32 (MxP tile contract)."""
    n = 128
    a = _mat(n, jnp.float8_e4m3fn)
    b = _mat(n, jnp.float8_e4m3fn, seed=5)
    c = _spd(n, jnp.float32)
    got = ops.gemm_update(c, a.astype(jnp.float32), b.astype(jnp.float32),
                          interpret=True)
    want = c - a.astype(jnp.float32) @ b.astype(jnp.float32).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_f64_dispatches_to_xla():
    """f64 tiles must take the stock XLA path (no f64 MXU on TPU)."""
    a = _spd(128, jnp.float64)
    got = ops.potrf(a)
    want = jnp.linalg.cholesky(a)
    np.testing.assert_allclose(np.asarray(jnp.tril(got)), np.asarray(want),
                               atol=1e-12)
