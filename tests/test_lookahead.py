"""Lookahead pipelined-panel schedules (PR 6): the task-DAG emitter.

Three contracts pin the refactor:

1. **Bit-identity at lookahead=0** — the two-stage (DAG -> topological
   emitter) builder must reproduce the old per-column emission loop's
   streams *exactly*, op for op, for every policy x ndev x grid.  The
   golden digests in test_golden_schedule.py pin the absolute history;
   here the property is checked structurally (explicit ``lookahead=0``
   == default build) so a future digest regen can't silently drop it.

2. **DAG safety at every depth** — ``verify_dispatch`` symbolically
   replays the dispatch order and asserts no POTRF/TRSM/SYRK/GEMM
   consumes a tile before its task-DAG predecessors completed, that
   broadcasts only ship finalized panel tiles, and that the full DAG is
   covered.  This is the simulator invariant that catches emitter
   reordering bugs.

3. **Numerics** — the NumPy oracle replay of a pipelined schedule still
   equals LAPACK (the jax executor legs live in
   test_backend_equivalence.py under forced host devices).

Plus the knob surface: slot minimums (each depth pins one extra slot),
digest folding (lookahead>0 distinct, lookahead=0 unchanged), the tuner
dimension (enumerated when open, honored when pinned), and the db
round-trip.
"""
import dataclasses

import numpy as np
import pytest

import repro
from repro.core.analytics import HW, simulate_multi
from repro.core.api import CholeskyConfig
from repro.core.cholesky import run_multidevice_numpy
from repro.core.precision import assign_precision
from repro.core.schedule import (build_multidevice_schedule,
                                 default_cache_slots, min_cache_slots)
from repro.core.taskgraph import (build_task_dag, potrf, syrk, trsm,
                                  verify_dispatch)
from repro.core.tiling import TileLayout, from_tiles, random_spd, to_tiles

POLICIES = ("sync", "v1", "v2", "v3")


def _plan(nt):
    norms = np.fromfunction(
        lambda i, j: 0.25 + ((3 * i + 5 * j) % 7) / 7.0, (nt, nt))
    dist = np.fromfunction(lambda i, j: np.minimum(abs(i - j), 4.0), (nt, nt))
    norms = norms * (1e-2 ** dist)
    norms[np.diag_indices(nt)] = 10.0
    return assign_precision(norms, float(np.sqrt((norms ** 2).sum())), 1e-6)


# -- 1. lookahead=0 bit-identity --------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("ndev,grid", [(1, None), (2, None), (4, None),
                                       (4, (2, 2)), (4, (1, 4))])
def test_lookahead0_streams_bit_identical(policy, ndev, grid):
    nt = 8
    plan = _plan(nt)
    base = build_multidevice_schedule(nt, 16, ndev, policy, plan=plan,
                                      grid=grid)
    explicit = build_multidevice_schedule(nt, 16, ndev, policy, plan=plan,
                                          grid=grid, lookahead=0)
    assert explicit.streams == base.streams
    assert explicit.digest() == base.digest()
    assert explicit.lookahead == 0 and explicit.dispatch is None


# -- 2. the DAG-safety invariant --------------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("ndev,grid", [(2, None), (4, None), (4, (2, 2))])
@pytest.mark.parametrize("lookahead", [0, 1, 2])
def test_dispatch_respects_task_dag(policy, ndev, grid, lookahead):
    nt = 10
    m = build_multidevice_schedule(nt, 16, ndev, policy, plan=_plan(nt),
                                   grid=grid, lookahead=lookahead)
    # every POTRF/TRSM/SYRK/GEMM of the nt-column factorization replayed,
    # each after its predecessors — verify_dispatch raises otherwise
    assert verify_dispatch(m) == len(build_task_dag(nt).preds)


def test_task_dag_rejects_out_of_order():
    dag = build_task_dag(3)
    with pytest.raises(AssertionError):
        dag.complete(potrf(1))          # needs syrk(1, 0) first
    dag.complete(potrf(0))
    with pytest.raises(AssertionError):
        dag.complete(potrf(0))          # double-run
    with pytest.raises(AssertionError):
        dag.complete(syrk(1, 0))        # needs trsm(1, 0) first
    dag.complete(trsm(1, 0))
    dag.complete(syrk(1, 0))
    dag.complete(potrf(1))              # now legal
    assert not dag.all_done()


def test_dag_shape():
    dag = build_task_dag(4)
    # 4 potrf + 6 trsm + 6 syrk + 4 gemm(m,k,n) chains for nt=4
    kinds = {}
    for t in dag.preds:
        kinds[t.kind] = kinds.get(t.kind, 0) + 1
    assert kinds["potrf"] == 4
    assert kinds["trsm"] == 6
    assert kinds["syrk"] == 6
    assert kinds["gemm"] == 4


def test_dispatch_chunks_cover_streams():
    for lookahead in (0, 2):
        m = build_multidevice_schedule(8, 16, 4, "v3", plan=_plan(8),
                                       grid=(2, 2), lookahead=lookahead)
        seen = [0] * m.ndev
        for d, start, stop, _k, phase in m.dispatch_chunks():
            assert start == seen[d], "chunks must tile each stream in order"
            assert phase in ("panel", "update", "recv", "recv-ahead",
                             "push", "advance")
            seen[d] = stop
        assert seen == [len(s) for s in m.streams]


# -- 3. numerics of pipelined schedules -------------------------------------

@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("grid", [(4, 1), (2, 2)])
@pytest.mark.parametrize("lookahead", [1, 2])
def test_numpy_replay_matches_lapack(policy, grid, lookahead):
    n, tb = 128, 16
    a = random_spd(n, seed=3)
    m = build_multidevice_schedule(n // tb, tb, 4, policy, grid=grid,
                                   lookahead=lookahead)
    assert m.lookahead == lookahead and m.dispatch is not None
    l = np.tril(from_tiles(run_multidevice_numpy(to_tiles(a, tb), m)))
    assert np.abs(l - np.linalg.cholesky(a)).max() < 1e-10


# -- digests, slots, validation ---------------------------------------------

def test_digest_folds_lookahead():
    plan = _plan(8)
    digs = [build_multidevice_schedule(8, 16, 4, "v3", plan=plan,
                                       grid=(2, 2), lookahead=la).digest()
            for la in (0, 1, 2)]
    assert len(set(digs)) == 3
    # and deterministically
    again = build_multidevice_schedule(8, 16, 4, "v3", plan=plan,
                                       grid=(2, 2), lookahead=2).digest()
    assert again == digs[2]


def test_slot_minimums_scale_with_depth():
    for policy in POLICIES:
        base = min_cache_slots(policy)
        for la in (1, 2, 3):
            assert min_cache_slots(policy, lookahead=la) == base + la
    assert (default_cache_slots("v3", 8, multidevice=True, lookahead=2)
            == default_cache_slots("v3", 8, multidevice=True) + 2)
    assert TileLayout(128, 16).panel_slots(2) == 3 * 8


def test_builder_validation():
    with pytest.raises(ValueError, match="lookahead"):
        build_multidevice_schedule(8, 16, 4, "v3", lookahead=8)   # >= nt
    with pytest.raises(ValueError, match="lookahead"):
        build_multidevice_schedule(8, 16, 1, "v3", lookahead=1)   # ndev=1
    with pytest.raises(ValueError, match="cache slots"):
        build_multidevice_schedule(8, 16, 4, "v3", cache_slots=4,
                                   lookahead=2)                   # < 4+2
    m = build_multidevice_schedule(8, 16, 4, "v3", cache_slots=6,
                                   lookahead=2)
    assert m.panel_base == 6


def test_config_validation_and_plan_threading():
    with pytest.raises(ValueError, match="ndev"):
        CholeskyConfig(tb=16, lookahead=1)
    with pytest.raises(ValueError, match="lookahead"):
        CholeskyConfig(tb=16, ndev=4, lookahead=-1)
    with pytest.raises(ValueError, match="cache slots"):
        CholeskyConfig(tb=16, ndev=4, policy="v3", cache_slots=4,
                       lookahead=2)
    p = repro.plan(128, CholeskyConfig(tb=16, ndev=4, grid=(2, 2),
                                       lookahead=2, backend="numpy"))
    assert p.schedule.lookahead == 2
    # lookahead=0 canonicalizes to the default plan-cache entry
    p0 = repro.plan(128, CholeskyConfig(tb=16, ndev=4, grid=(2, 2),
                                        lookahead=0, backend="numpy"))
    pn = repro.plan(128, CholeskyConfig(tb=16, ndev=4, grid=(2, 2),
                                        backend="numpy"))
    assert p0 is pn


# -- tuner dimension + db round-trip ----------------------------------------

def test_search_enumerates_open_lookahead():
    from repro.tune.search import search
    res = search(256, HW["gh200"], CholeskyConfig(
        tb=32, policy="v3", ndev=4, backend="numpy"))
    las = {r["lookahead"] for r in res.table()}
    assert las == {0, 1, 2}
    # the winner pins what it searched (plan()/db replay the same depth)
    assert res.config.lookahead is not None


def test_search_honors_pinned_lookahead():
    from repro.tune.search import search
    res = search(256, HW["gh200"], CholeskyConfig(
        tb=32, policy="v3", ndev=4, lookahead=1, backend="numpy"))
    assert {r["lookahead"] for r in res.table()} == {1}
    assert all(c.config.lookahead == 1 for c in res.candidates)


def test_pipelined_2x2_wins_compute_bound_model():
    """The PR 6 acceptance mechanism at test scale: on the compute-bound
    gh200 model the pipelined (2, 2) beats its own lookahead=0 schedule
    (fig9 records the full (2,2)-vs-(4,1) win at benchmark scale)."""
    nt, tb = 16, 512
    base = simulate_multi(build_multidevice_schedule(
        nt, tb, 4, "v3", grid=(2, 2)), HW["gh200"])
    piped = simulate_multi(build_multidevice_schedule(
        nt, tb, 4, "v3", grid=(2, 2), lookahead=2), HW["gh200"])
    assert piped.makespan < base.makespan


def test_db_roundtrip_and_pin_matching():
    from repro.tune.autotune import _matches_pins
    from repro.tune.db import config_from_dict, config_to_dict
    cfg = CholeskyConfig(tb=32, policy="v3", ndev=4, grid=(2, 2),
                         lookahead=2)
    assert config_from_dict(config_to_dict(cfg)) == cfg
    open_req = CholeskyConfig(tb=0, policy="auto", ndev=4)
    assert _matches_pins(cfg, open_req, 256)          # open accepts any
    pinned = dataclasses.replace(open_req, lookahead=1)
    assert not _matches_pins(cfg, pinned, 256)        # wrong depth
    assert _matches_pins(cfg, dataclasses.replace(open_req, lookahead=2),
                         256)
