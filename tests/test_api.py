"""Planner/executor API: config validation, plan caching, executor reuse
(schedule built + jitted exactly once per plan), blocked solve accuracy,
and the deprecated ooc_cholesky shim's equivalence + unified return type."""
import numpy as np
import pytest
import scipy.linalg as sla

import repro
from repro.core import api
from repro.core.schedule import MultiDeviceSchedule, OpKind
from repro.core.tiling import random_spd, to_tiles


# ---------------------------------------------------------------------------
# CholeskyConfig eager validation

@pytest.mark.parametrize("kwargs, match", [
    # tb=0 is now the autotune sentinel (see test_tune.py); negatives
    # remain invalid
    (dict(tb=-1), "tb"),
    (dict(tb=32, policy="bogus"), "policy"),
    (dict(tb=32, backend="torch"), "backend"),
    (dict(tb=32, ladder="cuda"), "ladder"),
    (dict(tb=32, eps_target=0.0), "eps_target"),
    (dict(tb=32, cache_slots=-1), "cache_slots"),
    (dict(tb=32, ndev=0), "ndev"),
    (dict(tb=32, block=(2,)), "block"),
    (dict(tb=32, policy="v3", block=(2, 2)), "only meaningful for"),
    (dict(tb=32, policy="v4", cache_slots=5), "slots"),
    (dict(tb=32, use_pallas=True, backend="numpy"), "use_pallas"),
    (dict(tb=32, compute_dtype=np.float32, backend="numpy"),
     "compute_dtype"),
    (dict(tb=32, eps_target=1e-6, plan=repro.uniform_plan(4)), "not both"),
])
def test_config_validation(kwargs, match):
    with pytest.raises(ValueError, match=match):
        repro.CholeskyConfig(**kwargs)


# auto-backend resolution (and with it use_pallas/compute_dtype
# validation) is device-count-dependent by design; these guards make the
# expectations explicit instead of assuming a single-device process
import jax as _jax

_NDEVICES = len(_jax.devices())
_single_device = pytest.mark.skipif(
    _NDEVICES > 1, reason="needs a process where jax sees one device "
    "(auto resolves ndev=2 to the jax executor here)")


@pytest.mark.parametrize("kwargs, match", [
    # kwargs invalid for multi-device schedules on any device count
    (dict(use_pallas=True, backend="numpy"), "use_pallas"),
    (dict(compute_dtype=np.float64, backend="numpy"), "compute_dtype"),
    (dict(policy="async"), "sync/v1/v2/v3"),
    (dict(policy="v4"), "sync/v1/v2/v3"),
])
def test_config_multidevice_rejects_unsupported(kwargs, match):
    with pytest.raises(ValueError, match=match):
        repro.CholeskyConfig(tb=32, ndev=2, **kwargs)


@_single_device
@pytest.mark.parametrize("kwargs, match", [
    # with one visible device, auto resolves ndev=2 to the numpy replay,
    # which supports neither of these
    (dict(use_pallas=True), "use_pallas"),
    (dict(compute_dtype=np.float64), "compute_dtype"),
])
def test_config_multidevice_auto_numpy_rejects(kwargs, match):
    with pytest.raises(ValueError, match=match):
        repro.CholeskyConfig(tb=32, ndev=2, **kwargs)


@_single_device
def test_multidevice_jax_backend_requires_devices():
    """0.3: backend='jax' with ndev > 1 is a *valid config* (the
    per-device executor); with too few visible devices it fails at
    compile() with an actionable error instead of at construction."""
    cfg = repro.CholeskyConfig(tb=16, policy="v3", ndev=2, backend="jax")
    assert cfg.resolved_backend() == "jax"
    with pytest.raises(RuntimeError,
                       match="needs 2 devices.*host_platform_device_count"):
        repro.plan(64, cfg).compile()
    # the shim inherits the same behaviour (pre-0.2 it silently fell back
    # to the NumPy replay; 0.2 rejected the config outright)
    a = random_spd(64, seed=0)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(RuntimeError, match="needs 2 devices"):
            repro.ooc_cholesky(a, 16, ndev=2, backend="jax")


def test_config_backend_resolution_and_hash():
    c1 = repro.CholeskyConfig(tb=32)
    assert c1.resolved_backend() == "jax"
    # multi-device auto resolution follows the visible device count
    expect = "jax" if _NDEVICES >= 2 else "numpy"
    assert repro.CholeskyConfig(tb=32, ndev=2).resolved_backend() == expect
    # value semantics: equal configs hash equal (keys one plan cache slot)
    assert c1 == repro.CholeskyConfig(tb=32) and hash(c1) == hash(
        repro.CholeskyConfig(tb=32))
    p = repro.uniform_plan(4)
    c2 = repro.CholeskyConfig(tb=32, plan=p)
    c3 = repro.CholeskyConfig(tb=32, plan=repro.uniform_plan(4))
    assert c2 == c3 and hash(c2) == hash(c3) and c2 != c1


# ---------------------------------------------------------------------------
# plan() caching + executor reuse

def test_plan_cache_returns_same_object():
    api.clear_plan_cache()
    p1 = repro.plan(96, tb=32, policy="v2")
    p2 = repro.plan(96, repro.CholeskyConfig(tb=32, policy="v2"))
    assert p1 is p2
    # solvers are fresh per compile() (per-call-site factored state)...
    s1, s2 = p1.compile(), p2.compile()
    assert s1 is not s2
    # ...but share the plan's one compiled executor
    assert s1._executor is s2._executor
    api.clear_plan_cache()
    assert repro.plan(96, tb=32, policy="v2") is not p1


def test_solvers_do_not_share_factored_state():
    """Two call sites holding solvers for the same (n, config) must not
    observe each other's factors (regression: the solver used to be
    cached globally, so factor() at site B silently re-pointed site A's
    solve())."""
    n = 96
    a1, a2 = random_spd(n, seed=1), random_spd(n, seed=2)
    s_a = repro.plan(n, tb=32, policy="v3").compile()
    s_b = repro.plan(n, tb=32, policy="v3").compile()
    s_a.factor(a1)
    s_b.factor(a2)
    b = np.ones(n)
    assert np.abs(a1 @ s_a.solve(b) - b).max() < 1e-8
    assert np.abs(a2 @ s_b.solve(b) - b).max() < 1e-8
    # a fresh solver never inherits another call site's factor
    with pytest.raises(RuntimeError, match="factor"):
        repro.plan(n, tb=32, policy="v3").compile().solve(b)


def test_executor_reuse_builds_and_jits_once():
    """The amortization contract: K same-shape factorizations through one
    OOCSolver build the schedule once and trace the jit once."""
    api.clear_plan_cache()
    n, k = 128, 4
    before = api.schedule_build_count()
    solver = repro.plan(n, tb=32, policy="v3").compile()
    ls = [solver.factor(random_spd(n, seed=s)) for s in range(k)]
    assert api.schedule_build_count() - before == 1
    assert solver.stats["jit_traces"] == 1
    assert solver.stats["factor_calls"] == k
    # replay is deterministic: same matrix -> bitwise same factor
    assert np.array_equal(ls[0], solver.factor(random_spd(n, seed=0)))
    # re-planning + recompiling the same (n, config) neither rebuilds the
    # schedule nor retraces: the fresh solver rides the cached executor
    other = repro.plan(n, tb=32, policy="v3").compile()
    other.factor(random_spd(n, seed=0))
    assert api.schedule_build_count() - before == 1
    assert other.stats["jit_traces"] == 1


def test_plan_default_plan_carries_config_ladder():
    """Regression: the f64 default plan used to hardcode ladder='tpu',
    misreporting the schedule metadata for ladder='gpu' configs."""
    pl = repro.plan(64, tb=32, policy="v3", ladder="gpu")
    assert pl.schedule.plan.ladder == repro.LADDERS["gpu"]
    assert repro.plan(64, tb=32, policy="v3").schedule.plan.ladder == \
        repro.LADDERS["tpu"]


def test_factor_materialize_false_keeps_tile_store_only():
    n = 96
    a = random_spd(n, seed=6)
    solver = repro.plan(n, tb=32, policy="v3").compile()
    assert solver.factor(a, materialize=False) is None
    b = np.ones(n)
    assert np.abs(a @ solver.solve(b) - b).max() < 1e-8
    assert solver.logdet() == pytest.approx(
        2.0 * np.sum(np.log(np.diag(np.linalg.cholesky(a)))), rel=1e-12)
    assert solver.stats["factor_calls"] == 1
    assert solver.stats["solve_calls"] == 1


def test_plan_rejects_matrix_dependent_eps():
    with pytest.raises(ValueError, match="specialize"):
        repro.plan(128, tb=32, eps_target=1e-6)


def test_specialize_freezes_plan():
    a = random_spd(128, seed=3)
    cfg = repro.CholeskyConfig(tb=32, policy="v3", eps_target=1e-6)
    frozen = cfg.specialize(a)
    assert frozen.eps_target is None and frozen.plan is not None
    expect = repro.plan_for_matrix(to_tiles(a, 32), 1e-6)
    assert frozen.plan == expect
    # already-static configs pass through untouched
    assert frozen.specialize(a) is frozen
    l = repro.plan(128, frozen).compile().factor(a)
    assert np.abs(l @ l.T - a).max() < 1e-5


# ---------------------------------------------------------------------------
# solve(): blocked triangular substitution against the tile store

@pytest.mark.parametrize("nrhs", [None, 3])
def test_solve_matches_scipy_cho_solve(nrhs):
    n, tb = 192, 48
    a = random_spd(n, seed=7)
    rng = np.random.default_rng(1)
    b = rng.standard_normal(n if nrhs is None else (n, nrhs))
    solver = repro.plan(n, tb=tb, policy="v3").compile()
    solver.factor(a)
    x = solver.solve(b)
    assert x.shape == b.shape
    ref = sla.cho_solve((np.linalg.cholesky(a), True), b)
    assert np.abs(x - ref).max() < 1e-10


def test_solve_multidevice_and_logdet():
    n = 128
    a = random_spd(n, seed=9)
    solver = repro.plan(n, tb=16, policy="v3", ndev=2).compile()
    solver.factor(a)
    b = np.ones(n)
    assert np.abs(a @ solver.solve(b) - b).max() < 1e-8
    assert solver.logdet() == pytest.approx(
        2.0 * np.sum(np.log(np.diag(np.linalg.cholesky(a)))), rel=1e-12)


def test_solve_before_factor_raises():
    api.clear_plan_cache()
    solver = repro.plan(64, tb=32, policy="v1").compile()
    with pytest.raises(RuntimeError, match="factor"):
        solver.solve(np.ones(64))


def test_factor_shape_mismatch_raises():
    solver = repro.plan(64, tb=32, policy="v3").compile()
    with pytest.raises(ValueError, match="n=64"):
        solver.factor(random_spd(96, seed=0))


def test_gaussian_loglik_solver_path_matches_dense():
    from repro.geo.likelihood import gaussian_loglik
    n = 128
    a = random_spd(n, seed=2)
    y = np.random.default_rng(0).standard_normal(n)
    solver = repro.plan(n, tb=32, policy="v3").compile()
    l = solver.factor(a)
    assert gaussian_loglik(solver, y) == pytest.approx(
        gaussian_loglik(l, y), rel=1e-12)


# ---------------------------------------------------------------------------
# shim: unified return type + equivalence with the solver path

def test_shim_returns_unified_schedule_and_matches_solver():
    a = random_spd(96, seed=4)
    with pytest.warns(DeprecationWarning):
        l, sched = repro.ooc_cholesky(a, 32, policy="v3")
    assert isinstance(sched, MultiDeviceSchedule) and sched.ndev == 1
    solver = repro.plan(96, tb=32, policy="v3").compile()
    assert np.array_equal(l, solver.factor(a))
    # degenerate schedule feeds the single-device analytics directly
    rep = repro.volume_report(sched)
    assert rep["c2g_bytes"] == sched.loads_bytes()
    r = repro.simulate(sched, repro.HW["gh200"])
    assert r.h2d_bytes == sched.loads_bytes()


def test_degenerate_schedule_round_trip():
    pl = repro.plan(96, tb=32, policy="v3")
    m = pl.schedule
    assert isinstance(m, MultiDeviceSchedule) and m.ndev == 1
    s = m.to_single()
    assert s.ops == m.streams[0]
    assert s.hits == m.hits[0] and s.loads_bytes() == m.loads_bytes()
    assert MultiDeviceSchedule.from_single(s).digest() == m.digest()
    assert m.count(OpKind.BCAST) == 0
    m4 = repro.plan(96, tb=32, policy="v3", ndev=4).schedule
    with pytest.raises(ValueError, match="ndev=4"):
        m4.to_single()
    with pytest.raises(ValueError, match="ndev=4"):
        repro.simulate(m4, repro.HW["gh200"])


def test_plan_volume_and_simulate_dispatch():
    single = repro.plan(96, tb=32, policy="v3")
    multi = repro.plan(96, tb=32, policy="v3", ndev=2)
    assert "per_device" not in single.volume()
    assert len(multi.volume()["per_device"]) == 2
    hw = repro.HW["gh200"]
    assert hasattr(multi.simulate(hw), "compute_efficiency")
    assert hasattr(single.simulate(hw), "tflops")
