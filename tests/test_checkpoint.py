"""Checkpoint manager repairs + restartable disk-tier factorization.

The manager half pins the bugfixes: re-saving an existing step (atomic
``os.replace`` over a stale dir), retention math (``keep`` newest, with
``keep=0`` rejected at construction), the multi-process save protocol
(every process writes its ``host_<p>.npz``, process 0 alone commits),
``latest_step`` ignoring ``.tmp`` leftovers, and a clear
``FileNotFoundError`` for a missing requested step.

The restart half drives :class:`repro.RestartableFactorization` over a
real on-disk :class:`repro.DiskTileStore`: a run killed at *any* point —
column boundary, mid-column (journal rollback), or twice — resumes from
the latest checkpoint and produces a factor **bit-identical** to an
uninterrupted run.  A checkpoint saved under a different schedule digest
is refused.
"""
import os
import signal

import numpy as np
import pytest

import jax

from repro.checkpoint import (CheckpointManager, RestartableFactorization,
                              TileJournal)
from repro.core.cholesky import run_schedule_numpy
from repro.core.schedule import build_schedule
from repro.core.spill import DiskTileStore
from repro.core.tiling import random_spd, to_tiles


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "slots": rng.standard_normal((3, 4, 4)),            # float64
        "scales": rng.standard_normal(5).astype(np.float32),
        "counts": np.arange(7, dtype=np.int32),
        "nested": {"bias": rng.standard_normal((2, 2))},
    }


def _zeros_like_tree(t):
    return jax.tree_util.tree_map(lambda a: np.zeros_like(a), t)


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert x.dtype == y.dtype          # dtype-preserving round-trip
        assert np.array_equal(x, y)


# ---------------------------------------------------------------------------
# Manager: round-trip, re-save, retention, multi-process, errors

def test_roundtrip_preserves_values_and_dtypes(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(1)
    m.save(4, tree, extra={"column": 4, "digest": "abc"})
    got, extra = m.restore(_zeros_like_tree(tree))
    _assert_tree_equal(got, tree)
    assert extra == {"column": 4, "digest": "abc"}
    assert m.latest_step() == 4


def test_resave_of_existing_step_overwrites(tmp_path):
    """Regression: save() used to crash with OSError when the step dir
    already existed (os.replace cannot overwrite a non-empty dir)."""
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(2, _tree(1))
    m.save(2, _tree(9))                     # resume path re-saves step 2
    got, _ = m.restore(_zeros_like_tree(_tree()), step=2)
    _assert_tree_equal(got, _tree(9))


@pytest.mark.parametrize("keep", [1, 3])
def test_retention_keeps_newest(tmp_path, keep):
    m = CheckpointManager(str(tmp_path), keep=keep)
    for step in range(5):
        m.save(step, _tree(step))
    kept = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                  if n.startswith("step_") and not n.endswith(".tmp"))
    assert kept == list(range(5 - keep, 5))
    assert m.latest_step() == 4


def test_keep_zero_rejected(tmp_path):
    """Regression: keep=0 used to garbage-collect *every* checkpoint
    (steps[:-0] == the whole list)."""
    with pytest.raises(ValueError, match="keep must be >= 1"):
        CheckpointManager(str(tmp_path), keep=0)


def test_latest_step_ignores_tmp_leftovers(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, _tree())
    os.makedirs(tmp_path / "step_00000007.tmp")   # crashed mid-save
    assert m.latest_step() == 1


def test_restore_missing_step_raises(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    m.save(1, _tree())
    with pytest.raises(FileNotFoundError, match="no checkpoint for step 5"):
        m.restore(_zeros_like_tree(_tree()), step=5)


def test_restore_empty_directory_returns_none(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    assert m.restore(_zeros_like_tree(_tree())) == (None, None)
    assert m.latest_step() is None


def test_multiprocess_save_protocol(tmp_path, monkeypatch):
    """Regression: a non-zero process used to crash creating the tmp dir
    (only proc 0 made it), and every process wrote meta.json.  Now each
    process writes its own host_<p>.npz and proc 0 alone writes the
    shared metadata and commits the rename."""
    m = CheckpointManager(str(tmp_path), keep=3)
    t0, t1 = _tree(0), _tree(1)

    monkeypatch.setattr(jax, "process_index", lambda: 1)
    m.save(3, t1, extra={"x": 1})           # non-zero proc saves FIRST
    tmp = tmp_path / "step_00000003.tmp"
    assert (tmp / "host_1.npz").exists()
    assert not (tmp / "meta.json").exists()             # proc 0's job
    assert m.latest_step() is None                      # not committed

    monkeypatch.setattr(jax, "process_index", lambda: 0)
    m.save(3, t0, extra={"x": 1})           # proc 0 commits atomically
    final = tmp_path / "step_00000003"
    assert not tmp.exists() and final.is_dir()
    assert {p.name for p in final.iterdir()} == \
        {"host_0.npz", "host_1.npz", "meta.json", "extra.json"}

    got0, _ = m.restore(_zeros_like_tree(t0), step=3)
    _assert_tree_equal(got0, t0)
    monkeypatch.setattr(jax, "process_index", lambda: 1)
    got1, _ = m.restore(_zeros_like_tree(t1), step=3)
    _assert_tree_equal(got1, t1)            # each proc reads its own file


def test_save_on_signal_requests_save(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    old = signal.getsignal(signal.SIGTERM)
    try:
        m.save_on_signal()
        assert not m.should_save_now
        signal.raise_signal(signal.SIGTERM)
        assert m.should_save_now
        m.save(0, _tree())                  # save clears the request
        assert not m.should_save_now
    finally:
        signal.signal(signal.SIGTERM, old)


# ---------------------------------------------------------------------------
# Tile journal

def test_journal_rollback_restores_first_write(tmp_path):
    store = DiskTileStore.create(str(tmp_path / "t.npy"), nt=2, tb=4)
    store.write_tile(0, 0, np.full((4, 4), 7.0))
    j = TileJournal(str(tmp_path / "j"))
    j.begin_epoch(0)
    j.journal(0, 0, store.read_tile(0, 0))
    store.write_tile(0, 0, np.full((4, 4), 1.0))
    j.journal(0, 0, store.read_tile(0, 0))  # second journal: ignored
    store.write_tile(0, 0, np.full((4, 4), 2.0))
    assert j.rollback(store, 0) == 1
    assert np.array_equal(store.read_tile(0, 0), np.full((4, 4), 7.0))


def test_journal_begin_epoch_drops_older(tmp_path):
    j = TileJournal(str(tmp_path / "j"))
    j.begin_epoch(0)
    j.journal(0, 1, np.zeros((4, 4)))
    j.begin_epoch(1)
    store = DiskTileStore.create(str(tmp_path / "t.npy"), nt=2, tb=4)
    assert j.rollback(store, 0) == 0        # epoch 0 entries dropped
    assert j.rollback(store, 1) == 0        # new epoch starts empty


# ---------------------------------------------------------------------------
# Restartable factorization: kill-and-resume is bit-identical

_N, _TB, _HSLOTS = 96, 16, 4


def _setup(tmp_path, host_slots=_HSLOTS, policy="v3"):
    a = random_spd(_N, seed=7)
    sched = build_schedule(_N // _TB, _TB, policy, host_slots=host_slots)
    store = DiskTileStore.from_matrix(str(tmp_path / "store.npy"), a, _TB)
    ref = run_schedule_numpy(to_tiles(a, _TB), sched)   # uninterrupted
    return a, sched, store, ref


def _resume(tmp_path, sched):
    """Fresh objects, as a new process after a kill would build them."""
    store = DiskTileStore.open(str(tmp_path / "store.npy"))
    manager = CheckpointManager(str(tmp_path / "ckpt"), keep=3)
    return RestartableFactorization(sched, store, manager)


def test_uninterrupted_run_matches_plain_replay(tmp_path):
    _, sched, store, ref = _setup(tmp_path)
    rf = RestartableFactorization(
        sched, store, CheckpointManager(str(tmp_path / "ckpt"), keep=3))
    assert rf.run() is True
    assert np.array_equal(rf.result_tiles(), ref)       # bit-identical
    assert rf.run() is True                             # idempotent


def test_kill_at_column_boundary_resumes_bit_identical(tmp_path):
    _, sched, store, ref = _setup(tmp_path)
    rf = RestartableFactorization(
        sched, store, CheckpointManager(str(tmp_path / "ckpt"), keep=3))
    assert rf.run(stop_after_column=2) is False         # killed
    del rf, store
    rf2 = _resume(tmp_path, sched)
    assert rf2.run() is True
    assert np.array_equal(rf2.result_tiles(), ref)


def test_mid_column_kill_exercises_journal_rollback(tmp_path):
    """A kill between checkpoints leaves the disk store mutated by
    post-checkpoint SPILLs; the undo journal must roll them back before
    the replay re-executes (tile updates are not idempotent)."""
    _, sched, store, ref = _setup(tmp_path)
    rf = RestartableFactorization(
        sched, store, CheckpointManager(str(tmp_path / "ckpt"), keep=3))
    stop = int(0.9 * len(sched.ops))        # deep mid-stream, mid-column
    assert rf.run(stop_after_ops=stop) is False
    del rf, store
    rf2 = _resume(tmp_path, sched)
    assert rf2.run() is True
    assert np.array_equal(rf2.result_tiles(), ref)


def test_double_kill_resumes_bit_identical(tmp_path):
    _, sched, store, ref = _setup(tmp_path)
    rf = RestartableFactorization(
        sched, store, CheckpointManager(str(tmp_path / "ckpt"), keep=3))
    assert rf.run(stop_after_ops=len(sched.ops) // 2) is False
    del rf, store
    rf2 = _resume(tmp_path, sched)
    assert rf2.run(stop_after_ops=20) is False          # killed again
    del rf2
    rf3 = _resume(tmp_path, sched)
    assert rf3.run() is True
    assert np.array_equal(rf3.result_tiles(), ref)


def test_resume_under_different_schedule_refused(tmp_path):
    _, sched, store, _ = _setup(tmp_path, host_slots=4)
    rf = RestartableFactorization(
        sched, store, CheckpointManager(str(tmp_path / "ckpt"), keep=3))
    assert rf.run(stop_after_column=1) is False
    other = build_schedule(_N // _TB, _TB, "v3", host_slots=5)
    store2 = DiskTileStore.open(str(tmp_path / "store.npy"))
    rf2 = RestartableFactorization(
        other, store2, CheckpointManager(str(tmp_path / "ckpt"), keep=3))
    with pytest.raises(ValueError, match="digest"):
        rf2.run()


def test_restartable_requires_spill_schedule(tmp_path):
    sched = build_schedule(4, 8, "v3")      # host_slots=0
    store = DiskTileStore.create(str(tmp_path / "t.npy"), nt=4, tb=8)
    with pytest.raises(ValueError, match="host_slots"):
        RestartableFactorization(
            sched, store, CheckpointManager(str(tmp_path / "c"), keep=1))
    spilled = build_schedule(4, 8, "v3", host_slots=2)
    with pytest.raises(ValueError, match="checkpoint_every"):
        RestartableFactorization(
            spilled, store, CheckpointManager(str(tmp_path / "c"), keep=1),
            checkpoint_every=0)
