"""Substrate: optimizer, quantized state, checkpoint manager, data
pipeline, geospatial application."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataPipeline, PipelineState
from repro.optim.adamw import OptState, adamw_init, adamw_update
from repro.optim.quantized import Q8, dequantize_q8, quantize_q8


# ---------------------------------------------------------------------------
# Optimizer

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0], jnp.float32)}
    opt = adamw_init(params)
    for _ in range(300):
        grads = {"w": 2.0 * params["w"]}
        params, opt = adamw_update(params, grads, opt, lr=5e-2,
                                   weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_q8_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((7, 300)), jnp.float32)  # odd shapes
    q = quantize_q8(x)
    assert q.q.dtype == jnp.int8
    y = dequantize_q8(q)
    assert y.shape == x.shape
    err = float(jnp.abs(x - y).max())
    assert err <= float(jnp.abs(x).max()) / 127.0 + 1e-7


def test_q8_zero_block():
    x = jnp.zeros((4, 256), jnp.float32)
    np.testing.assert_array_equal(np.asarray(dequantize_q8(quantize_q8(x))),
                                  np.zeros((4, 256)))


def test_quantized_adamw_tracks_fp32():
    rng = np.random.default_rng(1)
    w0 = jnp.asarray(rng.standard_normal(256), jnp.float32)
    pf = {"w": w0}
    pq = {"w": w0}
    of = adamw_init(pf)
    oq = adamw_init(pq, quantize=True)
    assert isinstance(oq.m["w"], Q8)
    for i in range(20):
        g = {"w": pf["w"] * 0.5 + 0.1}
        pf, of = adamw_update(pf, g, of, lr=1e-2, weight_decay=0.0)
        gq = {"w": pq["w"] * 0.5 + 0.1}
        pq, oq = adamw_update(pq, gq, oq, lr=1e-2, weight_decay=0.0,
                              quantize=True)
    # 8-bit moments drift slowly from the fp32 trajectory (no stochastic
    # rounding); what matters is staying in lockstep, not bit-equality.
    diff = float(jnp.abs(pf["w"] - pq["w"]).max())
    assert diff < 0.15, diff


def test_quantized_v_no_blowup():
    """Linear-int8 v flushed small entries to zero and exploded the
    update; root4 coding must keep every update bounded."""
    rng = np.random.default_rng(0)
    # gradient with 1e4 dynamic range inside one block
    g0 = jnp.asarray(np.concatenate([rng.standard_normal(64) * 1e-4,
                                     rng.standard_normal(64)]), jnp.float32)
    p = {"w": jnp.zeros(128, jnp.float32)}
    opt = adamw_init(p, quantize=True)
    for _ in range(10):
        p, opt = adamw_update(p, {"w": g0}, opt, lr=1e-2, weight_decay=0.0,
                              quantize=True)
    # Adam updates are bounded by ~lr per step
    assert float(jnp.abs(p["w"]).max()) < 10 * 1e-2 * 1.5


# ---------------------------------------------------------------------------
# Checkpointing

def _tree():
    return {"layer": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(10, tree, extra={"data_step": 10})
    restored, extra = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.asarray(tree["layer"]["w"]))
    assert extra == {"data_step": 10}


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    assert mgr.latest_step() == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000003", "step_00000004"]


def test_checkpoint_ignores_partial_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    # simulate a crash mid-write
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert mgr.latest_step() == 1


def test_preemption_flag(tmp_path):
    import signal
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_on_signal(signal.SIGUSR1)
    assert not mgr.should_save_now
    os.kill(os.getpid(), signal.SIGUSR1)
    assert mgr.should_save_now
    mgr.save(1, _tree())
    assert not mgr.should_save_now   # cleared by save


# ---------------------------------------------------------------------------
# Data pipeline

def test_pipeline_deterministic_and_resumable():
    p1 = DataPipeline(vocab=101, seq_len=16, global_batch=8, seed=3)
    batches = [next(p1) for _ in range(5)]
    # fresh pipeline, seek to step 3 -> identical stream
    p2 = DataPipeline(vocab=101, seq_len=16, global_batch=8, seed=3)
    p2.seek(3)
    b3 = next(p2)
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
    # state embeds in checkpoints
    st = PipelineState.from_dict(p2.state.to_dict())
    assert st.step == 4


def test_pipeline_host_slicing():
    p = DataPipeline(vocab=50, seq_len=8, global_batch=8, seed=0)
    b = next(p)
    parts = [p.host_slice(b, i, 4) for i in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([x["tokens"] for x in parts]), b["tokens"])


def test_pipeline_labels_shifted():
    p = DataPipeline(vocab=50, seq_len=8, global_batch=2, seed=0)
    b = next(p)
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)


# ---------------------------------------------------------------------------
# Geospatial application (paper §III-D)

def test_matern_spd_and_decay():
    from repro.geo.matern import matern_covariance, generate_locations
    locs = generate_locations(128, seed=1)
    for nu in (0.5, 1.5, 2.5):
        s = matern_covariance(locs, beta=0.1, nu=nu)
        assert np.linalg.eigvalsh(s).min() > 0
        assert np.all(np.diag(s) >= s.max(axis=1) - 1e-12)


def test_loglik_matches_scipy():
    from repro.geo.matern import matern_covariance, generate_locations
    from repro.geo.likelihood import gaussian_loglik
    from scipy.stats import multivariate_normal
    locs = generate_locations(64, seed=2)
    s = matern_covariance(locs, beta=0.1)
    rng = np.random.default_rng(0)
    y = rng.standard_normal(64)
    l = np.linalg.cholesky(s)
    got = gaussian_loglik(l, y)
    want = multivariate_normal(mean=np.zeros(64), cov=s).logpdf(y)
    assert abs(got - want) < 1e-8


def test_kl_divergence_decreases_with_accuracy():
    """Fig. 10: tighter eps_target -> smaller KL divergence."""
    from repro.geo.matern import (BETA_MEDIUM, generate_locations,
                                  matern_covariance)
    from repro.geo.kl import kl_divergence_mxp
    locs = generate_locations(192, seed=3)
    cov = matern_covariance(locs, beta=BETA_MEDIUM)
    kl = {eps: kl_divergence_mxp(cov, 48, eps)["abs_kl"]
          for eps in (1e-4, 1e-8)}
    assert kl[1e-8] <= kl[1e-4]
    assert kl[1e-8] < 1e-2


def test_morton_ordering_concentrates_norms():
    """Morton-ordered locations -> near-diagonal tiles dominate (the
    structure the MxP criterion exploits)."""
    from repro.geo.matern import matern_covariance, generate_locations
    from repro.core.tiling import to_tiles
    from repro.core.precision import tile_norms
    locs = generate_locations(256, seed=4)
    cov = matern_covariance(locs, beta=0.02627)
    tiles = to_tiles(cov, 64)
    norms, _ = tile_norms(tiles)
    nt = norms.shape[0]
    near = np.mean([norms[i, i] for i in range(nt)])
    far = np.mean([norms[i, j] for j in range(nt) for i in range(j + 2, nt)])
    assert near > 3 * far
