"""Distribution layer: logical-axis rules, activation constraints, the
multi-device Cholesky, HLO analyzer, and launch specs.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count`` (the main pytest process keeps
the real single-device view)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.distributed.sharding import (LOGICAL_RULES, partition_spec,
                                        shard_act)
from repro.launch import hlo
from repro.launch import specs as S
from repro.launch.mesh import make_smoke_mesh


def _run_sub(code: str, devices: int = 8):
    env = {"XLA_FLAGS":
           f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=900,
                          env=env, cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


# ---------------------------------------------------------------------------
# Logical-axis rules

def test_partition_spec_divisibility():
    out = _run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.distributed.sharding import partition_spec
        mesh = jax.make_mesh((8,), ('model',))
        # indivisible dim falls back to replicated, never errors
        assert partition_spec(('heads', None), (7, 16), mesh) == P()
        assert partition_spec(('heads', None), (16, 16), mesh) == P('model')
        print('OK')
    """)
    assert "OK" in out


def test_partition_spec_no_axis_reuse():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    spec = partition_spec(("mlp", "mlp"), (16, 16), mesh)
    # the second occurrence of an already-used mesh axis is dropped
    assert spec == P("model")


def test_shard_act_identity_outside_context():
    x = jnp.ones((4, 8, 16))
    assert shard_act(x, "hidden") is x


# ---------------------------------------------------------------------------
# Launch specs

@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_complete(arch):
    cfg = get_config(arch)
    for name, shape in SHAPES.items():
        specs = S.input_specs(cfg, shape)
        if shape.kind == "train":
            assert specs["tokens"].shape == (shape.global_batch,
                                             shape.seq_len)
            assert specs["labels"].dtype == jnp.int32
        elif shape.kind == "decode":
            assert specs["token"].shape == (shape.global_batch, 1)
        if cfg.is_encdec and shape.kind != "decode":
            assert "enc_embeds" in specs


def test_abstract_params_no_allocation():
    cfg = get_config("qwen3_14b")      # full 14B config, zero bytes
    params, axes = S.abstract_params(cfg)
    leaves = jax.tree.leaves(params)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    n = sum(np.prod(l.shape) for l in leaves)
    total, _ = cfg.param_count()
    pad = (cfg.padded_vocab - cfg.vocab) * cfg.d_model * 2
    assert abs(n - total - pad) / total < 0.02


# ---------------------------------------------------------------------------
# Multi-device (subprocess)

def test_distributed_cholesky_8dev():
    out = _run_sub("""
        import numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        from repro.core.distributed import distributed_cholesky
        mesh = jax.make_mesh((8,), ('model',))
        rng = np.random.default_rng(0)
        n, tb = 256, 16
        x = rng.standard_normal((n, n)); a = x @ x.T + n * np.eye(n)
        L = distributed_cholesky(a, tb, mesh)
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-11, err
        print('OK', err)
    """)
    assert "OK" in out


def test_tiny_pjit_train_step_2x2():
    """Full pjit train step on a 2x2 (data, model) mesh: lowering,
    sharding rules, activation constraints, optimizer update."""
    out = _run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed.sharding import (activation_sharding,
                                                params_shardings)
        from repro.launch.steps import make_train_step
        from repro.models import transformer as T
        from repro.optim.adamw import adamw_init
        cfg = get_config('qwen3_14b', smoke=True)
        mesh = jax.make_mesh((2, 2), ('data', 'model'))
        params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
        p_sh = params_shardings(axes, params, mesh)
        opt = adamw_init(params)
        rep = NamedSharding(mesh, P())
        opt_sh = type(opt)(step=rep, m=p_sh, v=p_sh)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                    cfg.vocab)
        batch = {'tokens': tokens, 'labels': jnp.roll(tokens, -1, 1)}
        b_sh = {k: NamedSharding(mesh, P('data', None)) for k in batch}
        with mesh, activation_sharding(mesh):
            step = jax.jit(make_train_step(cfg, lr=1e-3),
                           in_shardings=(p_sh, opt_sh, b_sh),
                           donate_argnums=(0, 1))
            params, opt, m = step(params, opt, batch)
        loss = float(m['loss'])
        assert np.isfinite(loss)
        print('OK', loss)
    """, devices=4)
    assert "OK" in out


def test_serve_step_sharded_cache_4dev():
    out = _run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed.sharding import (activation_sharding,
                                                params_shardings)
        from repro.launch import specs as S
        from repro.launch.steps import make_serve_step
        from repro.models import transformer as T
        cfg = get_config('qwen3_14b', smoke=True)
        mesh = jax.make_mesh((2, 2), ('data', 'model'))
        params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
        p_sh = params_shardings(axes, params, mesh)
        cache = T.init_cache(cfg, 4, 32, jnp.float32)
        cache_sh = S.cache_shardings(cfg, cache, mesh)
        tok = jnp.zeros((4, 1), jnp.int32)
        with mesh, activation_sharding(mesh):
            serve = jax.jit(make_serve_step(cfg),
                            in_shardings=(p_sh, cache_sh,
                                          NamedSharding(mesh, P('data', None)),
                                          NamedSharding(mesh, P())),
                            donate_argnums=(1,))
            logits, cache = serve(params, cache, tok, jnp.int32(0))
        assert np.isfinite(np.asarray(logits, np.float64)).all()
        print('OK')
    """, devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# HLO analyzer

def test_hlo_flops_plain_matmul():
    f = jax.jit(lambda a, b: a @ b)
    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    text = f.lower(sds, sds).compile().as_text()
    r = hlo.analyze(text)
    assert r["flops"] == 2 * 256 ** 3


def test_hlo_flops_scan_multiplied():
    def body(c, x):
        return c @ x, None
    f = jax.jit(lambda c, xs: jax.lax.scan(body, c, xs)[0])
    sds = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    xs = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    r = hlo.analyze(f.lower(sds, xs).compile().as_text())
    assert r["flops"] == 6 * 2 * 128 ** 3


def test_hlo_collectives_trip_multiplied():
    out = _run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo
        mesh = jax.make_mesh((8,), ('x',))
        sh = NamedSharding(mesh, P(None, 'x'))
        def body(c, x):
            return jax.lax.with_sharding_constraint(c @ x, sh), None
        f = jax.jit(lambda c, xs: jax.lax.scan(body, c, xs)[0],
                    in_shardings=(sh, NamedSharding(mesh, P(None, None, 'x'))),
                    out_shardings=sh)
        sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        xs = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
        r = hlo.analyze(f.lower(sds, xs).compile().as_text())
        counts = r['collectives']['counts']
        assert counts['all-gather'] == 5, counts
        print('OK')
    """)
    assert "OK" in out


def test_roofline_terms_shape():
    coll = {"bytes": {k: 0.0 for k in
                      ("all-gather", "all-reduce", "reduce-scatter",
                       "all-to-all", "collective-permute")}}
    coll["bytes"]["all-reduce"] = 1e9
    r = hlo.roofline_terms(flops=1e12, hbm_bytes=1e9, coll=coll,
                           chips=256, model_flops=2e14)
    assert r["dominant"] == "collective"      # 2e9/50e9 = 40ms dominates
    assert 0 < r["useful_fraction"] < 1
