"""Multi-device static schedules (paper §IV-D, Fig. 5/9): per-device op
streams, panel-row broadcast accounting, NumPy replay correctness, and
the simulate_multi interconnect model."""
import numpy as np
import pytest

from repro.core.analytics import (HW, simulate, simulate_multi,
                                  volume_report_multi)
from repro.core.cholesky import ooc_cholesky, run_multidevice_numpy
from repro.core.distributed import modeled_scaling, panel_broadcast_bytes
from repro.core.schedule import (OpKind, build_multidevice_schedule,
                                 build_schedule)
from repro.core.tiling import from_tiles, random_spd, to_tiles

POLICIES = ["sync", "v1", "v2", "v3"]
NDEVS = [1, 2, 4]


@pytest.mark.parametrize("policy", POLICIES)
def test_ndev1_matches_single_device_exactly(policy):
    """ndev=1 must reproduce build_schedule's byte volumes (and, in fact,
    the op stream itself) for every supported policy."""
    nt, tb = 8, 16
    s = build_schedule(nt, tb, policy)
    m = build_multidevice_schedule(nt, tb, 1, policy)
    assert m.loads_bytes() == s.loads_bytes()
    assert m.stores_bytes() == s.stores_bytes()
    assert m.count(OpKind.BCAST) == 0 and m.count(OpKind.RECV) == 0
    assert m.streams[0] == [o for o in s.ops if o.kind is not OpKind.ALLOC]


@pytest.mark.parametrize("ndev", NDEVS)
@pytest.mark.parametrize("policy", POLICIES)
def test_multidevice_executor_correct(ndev, policy):
    """Replaying all device streams against one host store must factor
    exactly (f64 plans) for 1, 2, and 4 devices."""
    nt, tb = 12, 16
    a = random_spd(nt * tb, seed=11)
    m = build_multidevice_schedule(nt, tb, ndev, policy)
    out = run_multidevice_numpy(to_tiles(a, tb), m)
    np.testing.assert_allclose(np.tril(from_tiles(out)),
                               np.linalg.cholesky(a), atol=1e-10)


@pytest.mark.parametrize("ndev", [2, 4])
@pytest.mark.parametrize("policy", POLICIES)
def test_broadcast_volume_matches_analytic(ndev, policy):
    """Sum of RECV bytes == panel_broadcast_bytes for uniform-f64 plans,
    for every policy (the broadcast is structural, not policy-driven)."""
    nt, tb = 10, 8
    m = build_multidevice_schedule(nt, tb, ndev, policy)
    assert m.bcast_bytes() == panel_broadcast_bytes(nt, tb, ndev)
    # each row-k tile is broadcast exactly once to ndev-1 receivers
    assert m.count(OpKind.RECV) == (ndev - 1) * sum(
        k + 1 for k in range(nt))
    assert m.count(OpKind.BCAST) == sum(k + 1 for k in range(nt))


def test_task_counts_partition_across_devices():
    """Every compute task appears on exactly one device stream."""
    nt, ndev = 9, 4
    m = build_multidevice_schedule(nt, 8, ndev, "v3")
    assert m.count(OpKind.POTRF) == nt
    assert m.count(OpKind.TRSM) == nt * (nt - 1) // 2
    assert m.count(OpKind.SYRK) == sum(k for k in range(nt))
    assert m.count(OpKind.GEMM) == sum(
        k * (nt - 1 - k) for k in range(nt))
    # block-cyclic ownership: stores of row m land on device m % ndev
    for d in range(ndev):
        for op in m.streams[d]:
            if op.kind is OpKind.STORE:
                assert op.i % ndev == d


def test_ooc_cholesky_ndev():
    a = random_spd(128, seed=5)
    L, msched = ooc_cholesky(a, 16, policy="v3", ndev=2)
    np.testing.assert_allclose(L, np.linalg.cholesky(a), atol=1e-10)
    assert msched.ndev == 2
    # mixed precision still converges to the requested accuracy class
    L2, _ = ooc_cholesky(a, 16, policy="v3", eps_target=1e-6, ndev=4)
    assert np.abs(L2 - np.linalg.cholesky(a)).max() < 1e-3


def test_multidevice_rejects_unsupported():
    with pytest.raises(ValueError, match="sync/v1/v2/v3"):
        build_multidevice_schedule(8, 16, 2, "async")
    with pytest.raises(ValueError, match="ndev"):
        build_multidevice_schedule(8, 16, 0, "v3")


def test_simulate_multi_matches_simulate_on_one_device():
    for policy in POLICIES:
        s = build_schedule(8, 256, policy)
        m = build_multidevice_schedule(8, 256, 1, policy)
        for hw in (HW["a100-pcie"], HW["gh200"]):
            r1 = simulate(s, hw)
            rm = simulate_multi(m, hw)
            assert rm.makespan == pytest.approx(r1.makespan, rel=1e-12)
            assert rm.devices[0].h2d_bytes == r1.h2d_bytes
            assert rm.link_bytes == 0


def test_simulate_multi_invariants():
    m = build_multidevice_schedule(12, 128, 4, "v3")
    for hw in HW.values():
        r = simulate_multi(m, hw)
        assert r.link_bytes == m.bcast_bytes()
        for d, dev in enumerate(r.devices):
            assert r.makespan >= dev.finish - 1e-12
            assert dev.h2d_bytes == m.loads_bytes(d)
            assert dev.d2h_bytes == m.stores_bytes(d)
        assert 0 < r.compute_efficiency <= 1.0 + 1e-12


def test_fig9_fast_interconnect_scales_better():
    """Paper Fig. 9: the NVLink-C2C platform keeps parallel compute
    efficiency high where the PCIe platform drowns in broadcast."""
    nt, tb = 16, 1024
    m4 = build_multidevice_schedule(nt, tb, 4, "v3")
    eff = {name: simulate_multi(m4, HW[name]).compute_efficiency
           for name in ("a100-pcie", "gh200")}
    assert eff["gh200"] > eff["a100-pcie"]
    # same compute preset, link speed as the only variable: monotone
    hw = HW["gh200"]
    e_pcie = simulate_multi(m4, hw, link_bw=HW["a100-pcie"].h2d_bw)
    e_nvl = simulate_multi(m4, hw, link_bw=HW["gh200"].h2d_bw)
    assert e_nvl.compute_efficiency > e_pcie.compute_efficiency


def test_modeled_scaling_rows():
    rows = modeled_scaling(32, 1024, ndevs=(1, 2, 4), hw_name="gh200")
    assert [r["ndev"] for r in rows] == [1, 2, 4]
    assert rows[0]["speedup"] == pytest.approx(1.0)
    assert rows[2]["speedup"] > rows[1]["speedup"] > 1.5
    assert rows[0]["bcast_bytes"] == 0


def test_volume_report_multi_consistency():
    m = build_multidevice_schedule(8, 32, 4, "v2")
    rep = volume_report_multi(m)
    assert rep["ndev"] == 4 and len(rep["per_device"]) == 4
    assert sum(d["c2g_bytes"] for d in rep["per_device"]) == rep["c2g_bytes"]
    assert sum(d["recv_bytes"] for d in rep["per_device"]) == rep["bcast_bytes"]
    # the lower triangle is stored exactly once across all devices (v2)
    assert sum(d["stores"] for d in rep["per_device"]) == 8 * 9 // 2


def test_mxp_multidevice_bcast_volume_shrinks():
    """Broadcast bytes follow the tile precision classes: a mixed plan
    must move no more than uniform f64."""
    from repro.core.precision import assign_precision
    nt = 8
    rng = np.random.default_rng(0)
    norms = np.abs(rng.standard_normal((nt, nt))) * 1e-6
    norms[np.diag_indices(nt)] = 10.0
    total = float(np.sqrt((norms ** 2).sum()))
    plan = assign_precision(norms, total, 1e-5)
    mxp = build_multidevice_schedule(nt, 16, 4, "v3", plan=plan)
    f64 = build_multidevice_schedule(nt, 16, 4, "v3")
    assert mxp.bcast_bytes() < f64.bcast_bytes()
