"""repro.serve: service correctness, batching, admission, fairness,
metrics — everything against small problems on the numpy backend so the
suite stays fast (the jax path is the same OOCSolver surface underneath,
covered by test_api/test_backend_equivalence)."""
import threading
import time

import numpy as np
import pytest
import scipy.linalg as sla

import repro
from repro.core import api
from repro.core.analytics import HardwareModel
from repro.geo.likelihood import gaussian_loglik
from repro.serve import (AdmissionController, AdmissionError, SolverService,
                         coalesce_head, plan_device_bytes, plan_device_slots,
                         split_solutions, stack_rhs)

N, TB = 64, 16
CFG = repro.CholeskyConfig(tb=TB, policy="v3", backend="numpy")


@pytest.fixture
def spd():
    return repro.random_spd(N, seed=11)


@pytest.fixture
def serial(spd):
    """Serial reference solver, factored."""
    s = repro.plan(N, CFG).compile()
    s.factor(spd, materialize=False)
    return s


def test_mixed_traffic_bit_identical_to_serial(spd, serial):
    """Concurrent mixed factor/solve/logdet traffic, batching disabled:
    every result equals the serial OOCSolver's bit for bit."""
    rng = np.random.default_rng(0)
    bs = [rng.standard_normal(N) for _ in range(12)]
    refs = [serial.solve(b) for b in bs]
    ref_lower = [serial.solve_lower(b) for b in bs]
    ld = serial.logdet()
    with SolverService(workers=3, batch_window=0.0) as svc:
        sessions = [svc.session(f"t{i}", N, CFG) for i in range(3)]
        for s in sessions:
            assert s.factor(spd) is None          # materialize=False
        futs, lfuts, dfuts = [], [], []
        for i, b in enumerate(bs):
            s = sessions[i % 3]
            futs.append(s.solve_async(b))
            lfuts.append(s.solve_lower_async(b))
            dfuts.append(s.logdet_async())
        for f, ref in zip(futs, refs):
            assert np.array_equal(f.result(timeout=60), ref)
        for f, ref in zip(lfuts, ref_lower):
            assert np.array_equal(f.result(timeout=60), ref)
        for f in dfuts:
            assert f.result(timeout=60) == ld


def test_batched_solves_coalesce_and_match(spd, serial):
    """A burst behind a busy worker coalesces into one stacked solve;
    values match the per-column serial results to 1e-10."""
    rng = np.random.default_rng(1)
    bs = [rng.standard_normal(N) for _ in range(8)]
    refs = [serial.solve(b) for b in bs]
    with SolverService(workers=1, batch_window=0.02, max_batch=32) as svc:
        s = svc.session("t", N, CFG)
        s.factor(spd)
        futs = [s.solve_async(b) for b in bs]
        for f, ref in zip(futs, refs):
            np.testing.assert_allclose(f.result(timeout=60), ref,
                                       rtol=0, atol=1e-10)
        snap = svc.metrics.snapshot()
    assert snap["batch"]["max_occupancy"] >= 2
    assert snap["batch"]["batched_solves"] >= 1


def test_solve_batch_stacked_request(spd, serial):
    rng = np.random.default_rng(2)
    B = rng.standard_normal((N, 5))
    with SolverService(workers=1) as svc:
        s = svc.session("t", N, CFG)
        s.factor(spd)
        X = s.solve_batch(B)
    c = sla.cho_factor(np.asarray(spd), lower=True)
    np.testing.assert_allclose(X, sla.cho_solve(c, B), rtol=0, atol=1e-10)


def test_factor_solve_fused(spd, serial):
    b = np.arange(N, dtype=float)
    with SolverService(workers=1) as svc:
        s = svc.session("t", N, CFG)
        x = s.factor_solve(spd, b)
        assert np.array_equal(x, serial.solve(b))
        l, x2 = s.factor_solve(spd, b, materialize=True)
        assert np.array_equal(x2, x)
        assert np.allclose(l @ l.T, np.asarray(spd), atol=1e-8)


def test_solve_before_factor_fails(spd):
    with SolverService(workers=1) as svc:
        s = svc.session("t", N, CFG)
        with pytest.raises(RuntimeError, match="no factor"):
            s.solve(np.ones(N))
        # the failure is per-request: the session still works afterwards
        s.factor(spd)
        assert s.solve(np.ones(N)).shape == (N,)


def test_rhs_validation_front_door(spd):
    with SolverService(workers=1) as svc:
        s = svc.session("t", N, CFG)
        with pytest.raises(ValueError, match="does not match"):
            s.solve_async(np.ones(N + 1))
        with pytest.raises(TypeError, match="real-valued"):
            s.solve_async(np.ones(N, dtype=complex))
        with pytest.raises(ValueError, match="does not match"):
            s.factor_async(np.ones((N, N + 1)))
        with pytest.raises(ValueError, match="stacked"):
            s.solve_batch_async(np.ones(N))


def test_sessions_share_plan_not_solver(spd):
    api.clear_plan_cache()
    before = api.schedule_build_count()
    with SolverService(workers=2) as svc:
        s1 = svc.session("a", N, CFG)
        s2 = svc.session("b", N, CFG)
        assert s1._plan is s2._plan                 # shared via plan cache
        s1.factor(spd)
        s2.factor(spd)
        assert s1._solver is not s2._solver         # pooled per session
    assert api.schedule_build_count() - before == 1


def test_session_idempotent_and_mismatch():
    with SolverService(workers=1) as svc:
        s1 = svc.session("a", N, CFG)
        assert svc.session("a", N, CFG) is s1
        with pytest.raises(ValueError, match="different config"):
            svc.session("a", N, repro.CholeskyConfig(tb=TB, policy="v2",
                                                     backend="numpy"))


def test_session_requires_resolved_config():
    with SolverService(workers=1) as svc:
        with pytest.raises(ValueError, match="fully resolved"):
            svc.session("t", N, repro.CholeskyConfig(tb=0, policy="auto"))


def test_closed_session_and_service_reject_submits(spd):
    svc = SolverService(workers=1)
    s = svc.session("t", N, CFG)
    s.factor(spd)
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.solve_async(np.ones(N))
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.session("u", N, CFG)


def _hw(mem_bytes: float) -> HardwareModel:
    return HardwareModel("test-hw", {"f64": 1e12}, 1e9, 1e9, 0.0,
                         mem_bytes=mem_bytes)


def test_admission_rejects_never_fits(spd):
    """A plan whose slot pins exceed the device outright is rejected."""
    plan = repro.plan(N, CFG)
    tiny = _hw(plan_device_bytes(plan) - 1)
    assert plan_device_slots(plan) > tiny.max_cache_slots(TB)
    with SolverService(workers=1, hw=tiny) as svc:
        s = svc.session("t", N, CFG)
        fut = s.factor_async(spd)
        with pytest.raises(AdmissionError, match="device slots"):
            fut.result(timeout=60)
        assert svc.metrics.snapshot()["rejected"] == 1


def test_admission_queues_until_release(spd):
    """Two tenants, memory for one: the second's work only runs after
    the first session closes and releases its reservation."""
    plan = repro.plan(N, CFG)
    one = _hw(int(plan_device_bytes(plan) * 1.5))
    with SolverService(workers=2, hw=one) as svc:
        s1 = svc.session("a", N, CFG)
        s2 = svc.session("b", N, CFG)
        assert s1.factor(spd) is None              # admitted + done
        fut = s2.factor_async(spd)                 # oversubscribed: queued
        time.sleep(0.05)
        assert not fut.done()
        assert svc.admission.reserved_bytes() == plan_device_bytes(plan)
        s1.close()                                 # releases reservation
        assert fut.result(timeout=60) is None      # now admitted
        s2.close()
    assert svc.admission.reserved_bytes() == 0


def test_admission_controller_unbounded():
    ctl = AdmissionController(None)
    assert ctl.unbounded
    plan = repro.plan(N, CFG)
    ctl.check_feasible(plan)                       # no-op
    assert ctl.try_reserve("k", plan)
    assert ctl.reserved_bytes() == 0


def test_round_robin_fairness(spd):
    """With one worker and two tenants' bursts queued behind a long
    request, execution alternates sessions instead of draining the
    flooder first."""
    n_gate = 320
    gate_cfg = repro.CholeskyConfig(tb=16, policy="v3", backend="numpy")
    with SolverService(workers=1, batch_window=0.0) as svc:
        s1 = svc.session("a", N, CFG)
        s2 = svc.session("b", N, CFG)
        s1.factor(spd)
        s2.factor(spd)
        # block the single worker on a bigger tenant's factor so both
        # bursts queue up behind it
        gate = svc.session("gate", n_gate, gate_cfg)
        blocker = gate.factor_async(repro.random_spd(n_gate, seed=12))
        futs = []
        for i in range(3):
            futs.append(s1.solve_async(np.ones(N)))
            futs.append(s2.solve_async(np.ones(N)))
        blocker.result(timeout=60)
        for f in futs:
            f.result(timeout=60)
    order = [r.session for r in svc.metrics._records if r.kind == "solve"]
    assert sorted(order) == ["a"] * 3 + ["b"] * 3
    assert order == ["a", "b", "a", "b", "a", "b"] or \
        order == ["b", "a", "b", "a", "b", "a"]


def test_metrics_snapshot_and_chrome_trace(spd):
    with SolverService(workers=2) as svc:
        s = svc.session("t", N, CFG)
        s.factor(spd)
        for _ in range(4):
            s.solve(np.ones(N))
        _ = s.logdet()
        snap = svc.metrics.snapshot()
    assert snap["completed"] == 6 and snap["rejected"] == 0
    assert snap["kinds"] == {"factor": 1, "solve": 4, "logdet": 1}
    assert snap["latency_s"]["p99"] >= snap["latency_s"]["p50"] > 0
    assert snap["solver"] == {"compiles": 1, "reuse": 5}
    assert snap["solves_per_s"] > 0
    trace = repro.chrome_trace(svc.metrics.timeline())
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert len(names) == 6
    assert any(n.startswith("solve:t") for n in names)


def test_gaussian_loglik_through_session(spd, serial):
    """geo.likelihood drives a served session like a local solver, for
    one observation vector and a stacked (n, k) set."""
    rng = np.random.default_rng(3)
    y1 = rng.standard_normal(N)
    Y = rng.standard_normal((N, 6))
    with SolverService(workers=2) as svc:
        s = svc.session("geo", N, CFG)
        s.factor(spd)
        assert gaussian_loglik(s, y1) == gaussian_loglik(serial, y1)
        lls = gaussian_loglik(s, Y)
    ref = np.array([gaussian_loglik(serial, Y[:, j])
                    for j in range(Y.shape[1])])
    assert lls.shape == (6,)
    np.testing.assert_allclose(lls, ref, rtol=0, atol=1e-10)


def test_worker_fault_isolation(spd):
    """A failing factor (non-square values leak through as NaN) fails
    its own future; the service and other sessions keep serving."""
    with SolverService(workers=1) as svc:
        s1 = svc.session("bad", N, CFG)
        s2 = svc.session("good", N, CFG)
        fut = s1.factor_async(-np.eye(N))          # not SPD: POTRF fails
        with pytest.raises(Exception):
            fut.result(timeout=60)
        s2.factor(spd)
        assert s2.solve(np.ones(N)).shape == (N,)


def test_stack_roundtrip_and_coalesce_rules():
    rng = np.random.default_rng(4)
    parts = [rng.standard_normal(8), rng.standard_normal((8, 3)),
             rng.standard_normal(8)]
    stacked, splits = stack_rhs(parts)
    assert stacked.shape == (8, 5)
    back = split_solutions(stacked, splits)
    for p, b in zip(parts, back):
        assert p.shape == b.shape and np.array_equal(p, b)

    class R:
        def __init__(self, kind, k=1, t_deadline=10.0):
            self.kind, self.k, self.t_deadline = kind, k, t_deadline

    # non-batchable head dispatches alone
    assert coalesce_head([R("factor"), R("solve")], 0.0, 32, 0.01) == \
        (1, None)
    # disabled batching dispatches head alone
    assert coalesce_head([R("solve"), R("solve")], 0.0, 1, 0.01) == (1, None)
    assert coalesce_head([R("solve"), R("solve")], 0.0, 32, 0.0) == (1, None)
    # growable batch inside the window is held until the deadline
    assert coalesce_head([R("solve"), R("solve")], 0.0, 32, 0.01) == \
        (0, 10.0)
    # window expired -> flush
    assert coalesce_head([R("solve"), R("solve")], 11.0, 32, 0.01) == \
        (2, None)
    # a trailing non-solve caps the run and forces immediate dispatch
    assert coalesce_head([R("solve"), R("solve"), R("factor")],
                         0.0, 32, 0.01) == (2, None)
    # max_batch caps total columns
    assert coalesce_head([R("solve", k=3), R("solve", k=3), R("solve", k=3)],
                         11.0, 4, 0.01) == (1, None)


def test_open_loop_batching_beats_baseline(spd):
    """The acceptance throughput property at test scale: identical burst,
    batched strictly faster end-to-end than one-RHS-at-a-time."""
    rng = np.random.default_rng(5)
    bs = [rng.standard_normal(N) for _ in range(64)]

    def drain(batch_window, max_batch):
        with SolverService(workers=1, batch_window=batch_window,
                           max_batch=max_batch) as svc:
            s = svc.session("t", N, CFG)
            s.factor(spd)
            t0 = time.perf_counter()
            futs = [s.solve_async(b) for b in bs]
            xs = [f.result(timeout=120) for f in futs]
            dt = time.perf_counter() - t0
            snap = svc.metrics.snapshot()
        return xs, dt, snap

    xs_base, dt_base, snap_base = drain(0.0, 1)
    xs_batch, dt_batch, snap_batch = drain(0.005, 32)
    for a, b in zip(xs_base, xs_batch):
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-10)
    assert snap_base["batch"]["max_occupancy"] <= 1
    assert snap_batch["batch"]["max_occupancy"] >= 2
    assert snap_batch["solves_per_s"] > snap_base["solves_per_s"]
