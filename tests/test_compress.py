"""Error-feedback int8 gradient compression (cross-pod wire format)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.optim.compress import compress_pod_gradients, ef_init


def test_single_pod_identity_path():
    """Outside a bound axis: quantize/dequantize only, error captured."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(300), jnp.float32)}
    ef = ef_init(g)
    out, ef2 = compress_pod_gradients(g, ef)
    err = np.asarray(g["w"] - out["w"])
    # per-block error bound: absmax/127
    assert np.abs(err).max() <= float(jnp.abs(g["w"]).max()) / 127 + 1e-7
    # the residual exactly accounts for the loss
    np.testing.assert_allclose(np.asarray(out["w"] + ef2["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """Constant gradient: with EF, the running mean of compressed grads
    converges to the true gradient (the EF guarantee)."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.standard_normal(256) * 1e-3
                               + np.where(rng.random(256) < 0.1, 1.0, 0.0),
                               jnp.float32)}
    ef = ef_init(g_true)
    acc = np.zeros(256)
    steps = 50
    for _ in range(steps):
        out, ef = compress_pod_gradients(g_true, ef)
        acc += np.asarray(out["w"])
    np.testing.assert_allclose(acc / steps, np.asarray(g_true["w"]),
                               atol=2e-3)


def test_cross_pod_psum():
    """Under shard_map with a bound 'pod' axis the payloads psum."""
    import subprocess
    import sys
    import textwrap
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = "src"
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compress import compress_pod_gradients, ef_init
        mesh = jax.make_mesh((2,), ('pod',))
        g = jnp.stack([jnp.arange(256, dtype=jnp.float32) / 64.0,
                       -jnp.arange(256, dtype=jnp.float32) / 128.0])

        def body(gl):
            gl = gl[0]
            out, ef = compress_pod_gradients({'w': gl}, ef_init({'w': gl}),
                                             axis='pod')
            return out['w'][None]

        f = shard_map(body, mesh=mesh, in_specs=P('pod'),
                      out_specs=P('pod'), check_rep=False)
        out = jax.jit(f)(g)
        want = np.asarray(g).mean(0)
        got = np.asarray(out)[0]
        assert np.abs(got - want).max() < 0.05, np.abs(got - want).max()
        print('OK')
    """)
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd="/root/repo")
    assert p.returncode == 0, p.stderr[-2000:]
    assert "OK" in p.stdout
