"""Kernel-numerics harness for the fused column-step megakernel.

The fused path (``CholeskyConfig.fuse_columns``) replaces one column
step's whole op group — SYRK wave + POTRF on the diagonal, GEMM wave +
TRSM per row, epilogue precision casts — with a single ``pallas_call``
(:func:`repro.kernels.fused_column.fused_column_step`).  Everything else
in the repo assumes those numerics are *exactly* the unfused executor's:
same accumulation order, same rounding events, TRSMs solving against the
stored (class-rounded) diagonal.  This module pins that contract:

* property sweeps (hypothesis when installed, fixed-seed sampling
  otherwise) of the raw kernel against an op-by-op unfused replay,
  across tile sizes, history depths, row counts, precision classes, and
  both kernel variants (POTRF-in-launch / solve-against-given-factor);
* the executor-level equivalence ``fuse_columns=True == False`` on whole
  factorizations, per policy and ladder;
* launch accounting: the fused path dispatches exactly ONE kernel per
  column step on the paper's policies (v2/v3);
* the flag-off path stays bit-identical with unchanged ``jit_traces``
  (the PR 9 goldens pin the op stream itself in
  tests/test_golden_schedule.py).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core.cholesky import (_jx_round, _make_kernel_fns,
                                 make_jax_executor, plan_for_matrix)
from repro.core.precision import EPS, LADDERS
from repro.core.schedule import build_schedule
from repro.core.tiling import from_tiles, random_spd, to_tiles
from repro.kernels.fused_column import (fused_column_step, launch_counts,
                                        reset_launch_counts)

CLASSES = ("f64", "f32", "bf16", "f8e4m3", "f8e4m3s")


def _ladder_for(cls_name: str):
    return next(lad for lad in LADDERS.values() if cls_name in lad)


def _tol(cls_name: str) -> float:
    # identical op order and identical rounding events mean the fused
    # and unfused results agree to accumulation round-off — except when
    # a 1-ulp accumulator difference lands on a class-quantum boundary,
    # where the epilogue may round to the adjacent representable value
    return max(1e-12, 4.0 * EPS[cls_name])


def _column_inputs(rng, r_tiles, k_hist, tb, with_diag):
    """Random column-step operands shaped like the executor's group."""
    spd = np.eye(tb) * (2.0 * tb)
    g = rng.standard_normal((tb, tb))
    spd += g @ g.T / tb
    rows = [spd if with_diag else rng.standard_normal((tb, tb))]
    rows += [rng.standard_normal((tb, tb)) for _ in range(r_tiles - 1)]
    c_stack = jnp.asarray(np.stack(rows), dtype=jnp.float64)
    hist = jnp.asarray(rng.standard_normal((r_tiles, k_hist, tb, tb)) / tb,
                       dtype=jnp.float64)
    bhist = hist[0] if with_diag else jnp.asarray(
        rng.standard_normal((k_hist, tb, tb)) / tb, dtype=jnp.float64)
    l_kk = jnp.asarray(np.linalg.cholesky(spd), dtype=jnp.float64)
    return c_stack, hist, bhist, l_kk


def _unfused_column(c_stack, hist, bhist, l_kk, cls_ids, ladder, with_diag):
    """Op-by-op replay of the group the megakernel replaces, through the
    executor's own kernel fns and ``_jx_round`` store semantics."""
    kf = _make_kernel_fns(use_pallas=False, interpret=True)
    r_tiles, k_hist = hist.shape[0], hist.shape[1]
    out = []
    if with_diag:
        c = c_stack[0]
        for kk in range(k_hist):
            c = kf["gemm"](c, hist[0, kk], bhist[kk])   # SYRK == self-GEMM
        diag = _jx_round(kf["potrf"](c), ladder[cls_ids[0]], jnp.float64)
        out.append(diag)
        start = 1
    else:
        diag = l_kk
        start = 0
    for r in range(start, r_tiles):
        c = c_stack[r]
        for kk in range(k_hist):
            c = kf["gemm"](c, hist[r, kk], bhist[kk])
        # the row solves against the *stored* (rounded) factor — exactly
        # what the unfused trace reads back after the diagonal's STORE
        x = kf["trsm"](diag, c)
        out.append(_jx_round(x, ladder[cls_ids[r]], jnp.float64))
    return jnp.stack(out)


def _check_fused_vs_unfused(tb, r_tiles, k_hist, cls_name, with_diag,
                            seed=0, compiled=False):
    rng = np.random.default_rng(seed)
    ladder = _ladder_for(cls_name)
    c_stack, hist, bhist, l_kk = _column_inputs(rng, r_tiles, k_hist, tb,
                                                with_diag)
    cls_ids = [ladder.index(cls_name)] * r_tiles
    fused_fn = fused_column_step
    if compiled:
        fused_fn = jax.jit(fused_column_step,
                           static_argnames=("ladder", "with_diag",
                                            "interpret"))
    got = np.asarray(fused_fn(c_stack, hist, bhist, l_kk,
                              jnp.asarray(cls_ids, dtype=jnp.int32),
                              ladder=ladder, with_diag=with_diag))
    want = np.asarray(_unfused_column(c_stack, hist, bhist, l_kk, cls_ids,
                                      ladder, with_diag))
    if with_diag:
        got, want = np.tril(got[0]), np.tril(want[0])  # compare factors
        scale = max(np.abs(want).max(), 1.0)
        assert np.abs(got - want).max() <= _tol(cls_name) * scale
        return
    scale = max(np.abs(want).max(), 1.0)
    assert np.abs(got - want).max() <= _tol(cls_name) * scale


# --------------------------------------------------------------------------
# property sweeps: raw kernel vs op-by-op replay
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(tb=st.sampled_from([32, 64]),
       r_tiles=st.integers(min_value=1, max_value=4),
       k_hist=st.integers(min_value=0, max_value=3),
       cls_name=st.sampled_from(CLASSES),
       with_diag=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**16))
def test_fused_equals_unfused_property(tb, r_tiles, k_hist, cls_name,
                                       with_diag, seed):
    _check_fused_vs_unfused(tb, r_tiles, k_hist, cls_name, with_diag,
                            seed=seed)


@pytest.mark.parametrize("tb", [32, 64, 128])
@pytest.mark.parametrize("cls_name", CLASSES)
def test_fused_equals_unfused_all_tb(tb, cls_name):
    """The deterministic tb sweep the ISSUE pins: every class at every
    acceptance tile size, both kernel variants."""
    _check_fused_vs_unfused(tb, 3, 2, cls_name, with_diag=True, seed=7)
    _check_fused_vs_unfused(tb, 2, 1, cls_name, with_diag=False, seed=8)


@pytest.mark.parametrize("cls_name", ["f64", "f8e4m3s"])
def test_fused_compiled_equals_eager(cls_name):
    """Jit-wrapping the launch (how the executors actually run it) is
    bitwise-identical to the eager call."""
    rng = np.random.default_rng(3)
    ladder = _ladder_for(cls_name)
    c_stack, hist, bhist, l_kk = _column_inputs(rng, 3, 2, 32, True)
    cls_ids = jnp.asarray([ladder.index(cls_name)] * 3, dtype=jnp.int32)
    kw = dict(ladder=ladder, with_diag=True)
    eager = np.asarray(fused_column_step(c_stack, hist, bhist, l_kk,
                                         cls_ids, **kw))
    jitted = np.asarray(jax.jit(fused_column_step,
                                static_argnames=("ladder", "with_diag",
                                                 "interpret"))(
        c_stack, hist, bhist, l_kk, cls_ids, **kw))
    assert np.array_equal(eager, jitted)


# --------------------------------------------------------------------------
# executor level: whole factorizations, fused vs unfused
# --------------------------------------------------------------------------

def _matern(n):
    from repro.geo.matern import generate_locations, matern_covariance
    locs = generate_locations(n, seed=1)
    return matern_covariance(locs) + 0.05 * np.eye(n)


@pytest.mark.parametrize("policy", ["v2", "v3", "v4"])
@pytest.mark.parametrize("ladder", ["tpu", "tpu-scaled"])
def test_executor_fused_equals_unfused(policy, ladder):
    nt, tb = 6, 16
    a = _matern(nt * tb)
    tiles = to_tiles(a, tb)
    plan = plan_for_matrix(tiles, 1e-7, ladder=ladder)
    sched = build_schedule(nt, tb, policy, plan=plan)
    lf = np.asarray(make_jax_executor(sched, fuse_columns=True)(
        jnp.asarray(tiles)))
    lu = np.asarray(make_jax_executor(sched, fuse_columns=False)(
        jnp.asarray(tiles)))
    diff = np.abs(lf - lu).max() / np.abs(lu).max()
    assert diff < 1e-12, (policy, ladder, diff)


def test_launch_count_one_per_column_step():
    """The acceptance criterion: on the paper's policies the fused path
    dispatches exactly one megakernel per column step (nt launches for
    an nt-tile factorization) with zero per-tile-op kernels."""
    nt, tb = 6, 16
    tiles = to_tiles(random_spd(nt * tb, seed=5), tb)
    for policy in ("v2", "v3"):
        sched = build_schedule(nt, tb, policy)
        exe = make_jax_executor(sched, fuse_columns=True)
        reset_launch_counts()
        exe(jnp.asarray(tiles))
        counts = launch_counts()
        assert counts["fused_column"] == nt, (policy, counts)
        assert counts["tile_op"] == 0, (policy, counts)


def test_unfused_counts_per_tile_ops():
    nt, tb = 4, 8
    tiles = to_tiles(random_spd(nt * tb, seed=5), tb)
    sched = build_schedule(nt, tb, "v3")
    exe = make_jax_executor(sched, fuse_columns=False)
    reset_launch_counts()
    exe(jnp.asarray(tiles))
    counts = launch_counts()
    assert counts["fused_column"] == 0, counts
    # one dispatch per compute op: nt potrf + sum of trsm/syrk/gemm
    n_compute = nt * (nt + 1) * (nt + 2) // 6  # tile ops of an nt grid
    assert counts["tile_op"] == n_compute, (counts, n_compute)


# --------------------------------------------------------------------------
# flag-off lockdown: default path untouched
# --------------------------------------------------------------------------

def test_flag_off_bitwise_and_traces():
    """``fuse_columns=False`` (and the config default) runs the exact
    PR 9 executor: bit-identical factors across repeated calls, one jit
    trace, and zero fused launches."""
    import repro
    n, tb = 96, 16
    a = random_spd(n, seed=13)
    cfg = repro.CholeskyConfig(tb=tb, policy="v3", backend="jax")
    assert cfg.fuse_columns is False
    solver = repro.plan(n, cfg).compile()
    reset_launch_counts()
    l1 = solver.factor(a)
    assert launch_counts()["fused_column"] == 0
    traces = solver.stats["jit_traces"]
    l2 = solver.factor(a)
    assert solver.stats["jit_traces"] == traces
    assert np.array_equal(l1, l2)
    assert np.abs(l1 - np.linalg.cholesky(a)).max() < 1e-10


def test_config_fused_end_to_end():
    """The flag threads from CholeskyConfig through plan/compile to the
    fused executor and matches the unfused factor."""
    import repro
    n, tb = 96, 16
    a = random_spd(n, seed=17)
    base = repro.CholeskyConfig(tb=tb, policy="v3", backend="jax")
    fused = repro.CholeskyConfig(tb=tb, policy="v3", backend="jax",
                                 fuse_columns=True)
    l_base = repro.plan(n, base).compile().factor(a)
    reset_launch_counts()
    l_fused = repro.plan(n, fused).compile().factor(a)
    assert launch_counts()["fused_column"] == n // tb
    assert np.abs(l_fused - l_base).max() / np.abs(l_base).max() < 1e-12


def test_fuse_columns_requires_jax_backend():
    import repro
    with pytest.raises(ValueError, match="fuse_columns"):
        repro.CholeskyConfig(tb=16, backend="numpy", fuse_columns=True)
