"""Docs gate (tier-1): the fenced Python blocks in README + docs/ run,
and every intra-repo link resolves — via tools/check_docs.py, the same
script the CI docs leg invokes."""
import importlib.util
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", REPO / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_blocks_and_links():
    """The real gate: executes every runnable block, resolves every
    link.  Runs in a subprocess so doc snippets cannot leak jax/x64
    state into the test process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env.setdefault("JAX_PLATFORMS", "cpu")
    p = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True, text=True, timeout=900, env=env,
        cwd=str(REPO))
    assert p.returncode == 0, p.stdout + p.stderr
    assert "0 error(s)" in p.stdout


def test_checker_catches_broken_link(tmp_path):
    """The link checker is live, not vacuous: a fabricated page with a
    dangling link and a bad anchor is flagged."""
    mod = _load_checker()
    page = tmp_path / "page.md"
    page.write_text("# Title\n\nsee [gone](missing.md) and "
                    "[bad](page.md#no-such-heading)\n")
    errors = []
    n = mod.check_links([page], errors)
    assert n == 2 and len(errors) == 2
    assert "missing.md" in errors[0] and "no-such-heading" in errors[1]


def test_checker_catches_failing_block(tmp_path):
    mod = _load_checker()
    page = tmp_path / "page.md"
    page.write_text("```python\nraise RuntimeError('doc rot')\n```\n\n"
                    "```python\n# doctest: skip-run\nthis only compiles "
                    "= if it parses\n```\n")
    errors = []
    mod.check_code([page], errors)
    assert len(errors) == 2          # the failing block + the syntax error
    assert "doc rot" in errors[0] and "syntax error" in errors[1]


def test_doc_pages_exist_and_are_indexed():
    """README links every docs/ page (the cross-linking satellite)."""
    readme = (REPO / "README.md").read_text()
    pages = sorted((REPO / "docs").glob("*.md"))
    assert len(pages) >= 4
    for page in pages:
        assert f"docs/{page.name}" in readme, page.name
