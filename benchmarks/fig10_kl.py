"""Fig. 10: KL divergence of the MxP likelihood vs FP64, three
correlation regimes x accuracy thresholds x matrix sizes."""
import numpy as np

from repro.geo.kl import kl_divergence_mxp
from repro.geo.matern import (BETA_MEDIUM, BETA_STRONG, BETA_WEAK,
                              generate_locations, matern_covariance)


def run(out):
    out("== Fig. 10: KL divergence, MxP vs FP64 likelihood ==")
    tb = 64
    for name, beta in (("weak", BETA_WEAK), ("medium", BETA_MEDIUM),
                       ("strong", BETA_STRONG)):
        out(f"correlation {name} (beta={beta}):")
        for n in (256, 512, 768):
            locs = generate_locations(n, seed=1)
            cov = matern_covariance(locs, beta=beta)
            cells = []
            for eps in (1e-5, 1e-6, 1e-8):
                r = kl_divergence_mxp(cov, tb, eps)
                cells.append(f"eps={eps:7.0e}: KL={r['abs_kl']:9.3e}")
            out(f"  n={n:5d}  " + "   ".join(cells))
        # accuracy ordering (paper: tighter eps -> smaller divergence)
        locs = generate_locations(512, seed=1)
        cov = matern_covariance(locs, beta=beta)
        kl5 = kl_divergence_mxp(cov, tb, 1e-5)["abs_kl"]
        kl8 = kl_divergence_mxp(cov, tb, 1e-8)["abs_kl"]
        assert kl8 <= kl5 * 1.5 + 1e-12, (kl5, kl8)
    out("")
