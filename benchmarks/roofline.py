"""Roofline table: read the dry-run artifacts and print §Roofline."""
import json
import os

from repro.configs import ARCHS, SHAPES

_DEFAULT = "/root/repo/experiments/dryrun_final"
if not os.path.isdir(_DEFAULT):          # fall back to the baseline sweep
    _DEFAULT = "/root/repo/experiments/dryrun"
DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", _DEFAULT)


def load_records(mesh="single"):
    recs = {}
    if not os.path.isdir(DRYRUN_DIR):
        return recs
    for arch in ARCHS:
        for shape in SHAPES:
            path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
            if os.path.exists(path):
                with open(path) as f:
                    recs[(arch, shape)] = json.load(f)
    return recs


def run(out):
    out("== Roofline terms per (arch x shape), single-pod 16x16 mesh ==")
    recs = load_records("single")
    if not recs:
        out("  (no dry-run artifacts found; run "
            "python -m repro.launch.dryrun --all first)")
        out("")
        return
    out(f"  {'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
        f"{'collect':>9s} {'bound':9s} {'useful':>7s}")
    for (arch, shape), r in sorted(recs.items()):
        if r["status"] == "skipped":
            out(f"  {arch:24s} {shape:12s} {'—':>9s} {'—':>9s} {'—':>9s} "
                f"{'skipped':9s}     n/a   ({r['reason'][:40]})")
            continue
        if r["status"] != "ok":
            out(f"  {arch:24s} {shape:12s}  FAILED")
            continue
        rf = r["roofline"]
        out(f"  {arch:24s} {shape:12s} {rf['t_compute_s']:9.4f} "
            f"{rf['t_memory_s']:9.4f} {rf['t_collective_s']:9.4f} "
            f"{rf['dominant']:9s} {rf.get('useful_fraction', 0):7.3f}")
    n_ok = sum(r["status"] == "ok" for r in recs.values())
    n_skip = sum(r["status"] == "skipped" for r in recs.values())
    out(f"  -- {n_ok} ok, {n_skip} skipped (documented), "
        f"{len(recs) - n_ok - n_skip} failed --")
    out("")
