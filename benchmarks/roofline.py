"""Kernel launch accounting + per-class tile-op roofline
(``BENCH_kernels.json``).

Three views of the numerical hot path the executors dispatch:

* **launch counts** — one factorization executed unfused (one kernel
  per tile op) and fused (``fuse_columns=True``: one megakernel per
  column step), counted through
  :func:`repro.kernels.fused_column.launch_counts`.  The fused path's
  acceptance criterion — exactly 1 launch per column step on the
  paper's policies — is asserted here, so the JSON artifact doubles as
  a regression gate.
* **fused-vs-unfused wall clock** — the same schedule run both ways on
  the live backend (interpret-mode Pallas on CPU CI; the same code
  path compiles on TPU).
* **per-class tile-op roofline** — measured kernel rates per precision
  class (:func:`repro.tune.calibrate._measure_kernels`, the executors'
  own kernel fns) next to the arithmetic intensity of a tile GEMM at
  that class's storage bytes: ``intensity = flops / bytes_moved``, a
  tile GEMM moving three operand tiles in and one result out at
  ``BYTES[class] * tb^2`` each.
"""
import time

import jax.numpy as jnp

from repro.core.cholesky import make_jax_executor
from repro.core.precision import BYTES, LADDERS
from repro.core.schedule import build_schedule
from repro.core.tiling import random_spd, to_tiles
from repro.kernels.fused_column import launch_counts, reset_launch_counts
from repro.tune.calibrate import _TASK_FLOP_COUNT, _measure_kernels

NT, TB = 6, 32
CLASSES = ("f64", "f32", "bf16", "f8e4m3", "f8e4m3s")

# payload tiles a single tile op moves (operands in + result out)
_TILES_MOVED = {"gemm": 4, "syrk": 3, "trsm": 3, "potrf": 2}


def _time_executor(exe, tiles, repeats=3):
    exe(tiles).block_until_ready()        # trace + compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        exe(tiles).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(out):
    out("== kernels: launch accounting + per-class tile-op roofline ==")
    tiles = jnp.asarray(to_tiles(random_spd(NT * TB, seed=1), TB))
    sched = build_schedule(NT, TB, "v3")
    n_compute = NT * (NT + 1) * (NT + 2) // 6   # tile ops of an NT grid

    launches, walls = {}, {}
    for fused in (False, True):
        exe = make_jax_executor(sched, fuse_columns=fused)
        reset_launch_counts()
        exe(tiles).block_until_ready()    # one counted factorization
        launches[fused] = launch_counts()
        walls[fused] = _time_executor(exe, tiles)

    # acceptance gate: 1 megakernel per column step, zero per-tile-op
    # kernels on the fused path; exactly one kernel per tile op unfused
    assert launches[True]["fused_column"] == NT, launches
    assert launches[True]["tile_op"] == 0, launches
    assert launches[False]["tile_op"] == n_compute, launches
    assert launches[False]["fused_column"] == 0, launches

    out(f"  v3 nt={NT} tb={TB}: unfused {launches[False]['tile_op']} "
        f"tile-op launches ({walls[False]*1e3:.1f} ms)  |  fused "
        f"{launches[True]['fused_column']} column-step launches "
        f"({walls[True]*1e3:.1f} ms)  -> "
        f"{launches[False]['tile_op'] / NT:.1f}x fewer dispatches/step")

    out(f"  {'class':8s} {'gemm GF/s':>10s} {'potrf GF/s':>11s} "
        f"{'intensity':>10s}  (tile GEMM flop/byte)")
    rates = _measure_kernels(TB, CLASSES, 1)
    roofline = {}
    for cls_name in CLASSES:
        moved = _TILES_MOVED["gemm"] * BYTES[cls_name] * TB * TB
        intensity = _TASK_FLOP_COUNT["gemm"](TB) / moved
        roofline[cls_name] = {
            "bytes_per_tile": BYTES[cls_name] * TB * TB,
            "gemm_intensity_flop_per_byte": intensity,
            "rates_flops": {t: rates[t][cls_name] for t in rates},
        }
        out(f"  {cls_name:8s} {rates['gemm'][cls_name]/1e9:10.2f} "
            f"{rates['potrf'][cls_name]/1e9:11.2f} {intensity:10.1f}")
    out("")

    return {
        "nt": NT, "tb": TB, "policy": "v3",
        "launches": {
            "unfused": launches[False],
            "fused": launches[True],
            "per_column_step_fused":
                launches[True]["fused_column"] / NT,
            "compute_ops": n_compute,
        },
        "wall_s": {"unfused": walls[False], "fused": walls[True]},
        "fused_won_wall_clock": walls[True] < walls[False],
        "roofline": roofline,
        "ladders": {name: list(lad) for name, lad in LADDERS.items()},
    }
