"""§Perf (Cholesky core): V3 vs beyond-paper V4 in the OOC regime.

The paper's V3 is optimal when compute dominates (FP64 on any link, or
MxP behind NVLink-C2C).  On the TPU v5e target the host link is 32 GB/s
while MxP compute runs at up to 394 TFLOP/s — the link becomes the
bottleneck and V3's ~1 load/GEMM tail shows.  V4 blocks the external
update (h rows x w panel columns), amortizing loads (h+w)/(h*w) per
GEMM.  All numbers from the exact schedule + three-engine model, via
cached :func:`repro.plan` configs.
"""
import numpy as np

import repro
from repro.core.analytics import HW
from repro.core.precision import assign_precision


def _geo_plan(nt, seed=0, eps=1e-5):
    rng = np.random.default_rng(seed)
    norms = np.abs(rng.standard_normal((nt, nt)))
    for j in range(nt):
        for i in range(j, nt):
            norms[i, j] *= (1e-3) ** min(abs(i - j), 4)
    norms[np.diag_indices(nt)] = 10.0
    return assign_precision(norms, float(np.sqrt((norms ** 2).sum())), eps)


def run(out):
    out("== §Perf Cholesky: V3 (paper) vs V4 (beyond-paper 2D-blocked) ==")
    nt, tb, slots = 32, 1024, 48
    n = nt * tb
    plan = _geo_plan(nt)
    out(f"matrix {n}x{n}, tile {tb}, cache {slots} slots "
        f"({slots*8*tb*tb/1e9:.1f} GB device window)")

    rows = [
        ("v3 fp64", repro.CholeskyConfig(tb=tb, policy="v3",
                                         cache_slots=slots)),
        ("v4(6,4) fp64", repro.CholeskyConfig(tb=tb, policy="v4",
                                              cache_slots=slots,
                                              block=(6, 4))),
        ("v3 MxP", repro.CholeskyConfig(tb=tb, policy="v3",
                                        cache_slots=slots, plan=plan)),
        ("v4(6,4) MxP", repro.CholeskyConfig(tb=tb, policy="v4",
                                             cache_slots=slots, plan=plan,
                                             block=(6, 4))),
        ("v4(10,6) MxP @128", repro.CholeskyConfig(tb=tb, policy="v4",
                                                   cache_slots=128, plan=plan,
                                                   block=(10, 6))),
    ]
    for hw_name in ("tpu-v5e", "a100-pcie", "gh200"):
        hw = HW[hw_name]
        out(f"--- {hw_name} ---")
        for name, cfg in rows:
            pl = repro.plan(n, cfg)
            r = pl.simulate(hw)
            v = pl.volume()
            out(f"  {name:18s} C2G {v['c2g_bytes']/1e9:6.2f} GB  "
                f"makespan {r.makespan*1e3:7.0f} ms  {r.tflops:6.1f} TF/s "
                f"(cmp {r.compute_busy*1e3:6.0f} / h2d {r.h2d_busy*1e3:6.0f})")

    # headline assertions (the recorded §Perf results)
    hw = HW["tpu-v5e"]
    t_v3 = repro.plan(n, rows[2][1]).simulate(hw).makespan
    t_v4 = repro.plan(n, rows[4][1]).simulate(hw).makespan
    out(f"v5e MxP: V4 speedup over V3 = {t_v3/t_v4:.2f}x "
        f"(link-bound -> near compute floor)")
    assert t_v4 < t_v3 * 0.55
    # fp64 on v5e is compute-bound: V4 must not regress
    t3f = repro.plan(n, rows[0][1]).simulate(hw).makespan
    t4f = repro.plan(n, rows[1][1]).simulate(hw).makespan
    assert t4f <= t3f * 1.02
    out("")
