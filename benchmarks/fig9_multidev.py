"""Fig. 9: multi-device scaling of the 1D block-cyclic Cholesky.

Measured, two runtimes on forced host devices (subprocess; correctness
asserted against LAPACK):

* the *static-schedule executor* on 1/2/4 devices — per-device op
  streams replayed by ``make_multidevice_jax_executor`` through the
  public planner API (``CholeskyConfig(ndev=..., backend='jax')``),
  executed BCAST/RECV bytes cross-checked against the schedule; this is
  the run the modeled numbers below describe op for op;
* the shard_map einsum reference baseline (``distributed_cholesky``) on
  1/2/4/8 devices.

Modeled: event simulation of the same static op streams
(`build_multidevice_schedule` + `simulate_multi`) on the paper's
platforms — per-device H2D/D2H/compute engines plus the shared
interconnect carrying the panel-row broadcast.  The qualitative Fig. 9
claim is the interconnect story: the faster link (NVLink-C2C on GH200)
keeps parallel compute efficiency high where the PCIe-class platforms
drown in broadcast traffic.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

from repro.core.analytics import HW
from repro.core.distributed import modeled_scaling, panel_broadcast_bytes

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
_SRC = _REPO_ROOT / "src"


def _run_timed(code: str, devices: int) -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(_SRC)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env,
                       cwd=str(_REPO_ROOT))
    assert p.returncode == 0, p.stderr[-2000:]
    return float(p.stdout.split("TIME")[1])


def _measure(devices: int, n: int, tb: int) -> float:
    """Shard_map einsum reference baseline (core/distributed.py)."""
    return _run_timed(f"""
        import time, numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        from repro.core.distributed import distributed_cholesky
        mesh = jax.make_mesh(({devices},), ('model',))
        rng = np.random.default_rng(0)
        x = rng.standard_normal(({n}, {n})); a = x @ x.T + {n}*np.eye({n})
        distributed_cholesky(a, {tb}, mesh)          # warm-up/compile
        t0 = time.time()
        L = distributed_cholesky(a, {tb}, mesh)
        dt = time.time() - t0
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-10, err
        print('TIME', dt)
    """, devices)


def _measure_static(devices: int, n: int, tb: int) -> float:
    """Static-schedule executor through the planner API: per-device
    jitted op streams + device-to-device panel broadcast, executed
    transfer volume cross-checked against the schedule."""
    return _run_timed(f"""
        import time, numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import crosscheck_executed_volume
        rng = np.random.default_rng(0)
        x = rng.standard_normal(({n}, {n})); a = x @ x.T + {n}*np.eye({n})
        cfg = repro.CholeskyConfig(tb={tb}, policy='v3', ndev={devices},
                                   backend='jax' if {devices} > 1 else 'auto')
        solver = repro.plan({n}, cfg).compile()
        solver.factor(a)                             # warm-up/compile
        t0 = time.time()
        L = solver.factor(a)
        dt = time.time() - t0
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-10, err
        if {devices} > 1:
            cc = crosscheck_executed_volume(solver.schedule,
                                            solver.transfer_stats())
            assert cc['match'], cc['mismatches']
        print('TIME', dt)
    """, devices)


def run(out):
    out("== Fig. 9: multi-device scaling (1D block-cyclic) ==")
    n, tb = 512, 32
    out(f"[measured, host devices] matrix {n}x{n}, tile {tb} "
        f"(CPU wall-clock; correctness asserted)")
    out("  static-schedule executor (per-device op streams, V3; "
        "executed bcast bytes == schedule):")
    for d in (1, 2, 4):
        dt = _measure_static(d, n, tb)
        out(f"    {d} device(s): {dt*1e3:8.1f} ms")
    out("  shard_map einsum reference baseline:")
    for d in (1, 2, 4, 8):
        dt = _measure(d, n, tb)
        out(f"    {d} device(s): {dt*1e3:8.1f} ms")

    nt, tbm = 32, 1024
    out(f"[modeled] static per-device op streams, f64 V3, "
        f"n={nt*tbm} tb={tbm} (simulate_multi; exact schedule replay):")
    eff4 = {}
    for hw_name in ("a100-pcie", "gh200"):
        hw = HW[hw_name]
        out(f"  {hw_name} (link {hw.h2d_bw/1e9:.0f} GB/s):")
        for row in modeled_scaling(nt, tbm, ndevs=(1, 2, 4),
                                   hw_name=hw_name):
            out(f"    {row['ndev']} device(s): makespan {row['makespan']:7.3f}s"
                f"  {row['tflops']:6.1f} TFlop/s"
                f"  speedup {row['speedup']:4.2f}"
                f"  compute-eff {row['compute_efficiency']*100:5.1f}%"
                f"  bcast {row['bcast_bytes']/1e9:6.2f} GB")
            if row["ndev"] == 4:
                eff4[hw_name] = row
    g4, a4 = eff4["gh200"], eff4["a100-pcie"]
    out(f"  => 4-device compute efficiency: gh200 "
        f"{g4['compute_efficiency']*100:.1f}% vs a100-pcie "
        f"{a4['compute_efficiency']*100:.1f}% — the faster interconnect "
        f"keeps the scaling slope (paper Fig. 9)")

    out("[analytic] panel-broadcast volume (matches the schedules exactly):")
    for p in (2, 4):
        out(f"  {p} device(s): {panel_broadcast_bytes(nt, tbm, p)/1e9:.2f} GB")
    out("")
