"""Fig. 9: multi-device scaling of the 1D block-cyclic Cholesky.

Measured: the shard_map left-looking factorization on 1/2/4/8 host
devices (subprocess; correctness asserted against LAPACK).  Modeled:
panel-broadcast collective volume vs compute across device counts on the
paper's platforms (the scaling-slope argument of Fig. 9).
"""
import subprocess
import sys
import textwrap
import time

from repro.core.analytics import HW
from repro.core.distributed import panel_broadcast_bytes


def _measure(devices: int, n: int, tb: int) -> float:
    code = textwrap.dedent(f"""
        import time, numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        from repro.core.distributed import distributed_cholesky
        mesh = jax.make_mesh(({devices},), ('model',))
        rng = np.random.default_rng(0)
        x = rng.standard_normal(({n}, {n})); a = x @ x.T + {n}*np.eye({n})
        distributed_cholesky(a, {tb}, mesh)          # warm-up/compile
        t0 = time.time()
        L = distributed_cholesky(a, {tb}, mesh)
        dt = time.time() - t0
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-10, err
        print('TIME', dt)
    """)
    import os
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900, env=env, cwd="/root/repo")
    assert p.returncode == 0, p.stderr[-2000:]
    return float(p.stdout.split("TIME")[1])


def run(out):
    out("== Fig. 9: multi-device scaling (1D block-cyclic, shard_map) ==")
    n, tb = 512, 32
    out(f"[measured, host devices] matrix {n}x{n}, tile {tb} "
        f"(CPU wall-clock; correctness asserted)")
    for d in (1, 2, 4, 8):
        dt = _measure(d, n, tb)
        out(f"  {d} device(s): {dt*1e3:8.1f} ms")

    out("[modeled] panel-broadcast volume vs compute, f64, n=131072 "
        f"tb=1024:")
    nt = 128
    flops = (nt * 1024) ** 3 / 3
    for hw_name in ("a100-pcie", "gh200", "tpu-v5e"):
        hw = HW[hw_name]
        out(f"  {hw_name}:")
        for p in (1, 2, 4):
            coll = panel_broadcast_bytes(nt, 1024, p)
            t_comp = flops / p / hw.flops["f64"]
            t_coll = coll / p / hw.h2d_bw
            eff = t_comp / (t_comp + t_coll)
            out(f"    {p} GPU(s): compute {t_comp:6.1f}s  "
                f"bcast {t_coll:6.2f}s  parallel efficiency {eff*100:5.1f}%")
    out("")
