"""Fig. 9: multi-device scaling of the block-cyclic Cholesky, 1D vs 2D.

Measured, two runtimes on forced host devices (subprocess; correctness
asserted against LAPACK):

* the *static-schedule executor* on 1/2/4 devices — per-device op
  streams replayed by ``make_multidevice_jax_executor`` through the
  public planner API (``CholeskyConfig(ndev=..., backend='jax')``),
  executed BCAST/RECV bytes cross-checked against the schedule; this is
  the run the modeled numbers below describe op for op.  At 4 devices
  both the paper's 1D tile-row layout and the 2D ``(2, 2)`` grid run,
  and their *executed* interconnect bytes are reported side by side
  (the 2D grid must move strictly less — the PR 5 acceptance bar,
  recorded in ``BENCH_fig9.json``);
* the shard_map einsum reference baseline (``distributed_cholesky``) on
  1/2/4/8 devices.

Modeled: event simulation of the same static op streams
(`build_multidevice_schedule` + `simulate_multi`) on the paper's
platforms — per-device H2D/D2H/compute engines plus the shared
interconnect carrying the scoped broadcasts.  The qualitative Fig. 9
claim is the interconnect story: the faster link (NVLink-C2C on GH200)
keeps parallel compute efficiency high where the PCIe-class platforms
drown in broadcast traffic — the 2D grid shrinks the broadcast itself,
and lookahead pipelining (PR 6) closes the 2D compute-bound gap by
overlapping the next panels with the trailing update, so the modeled
``(2, 2)`` geometry beats ``(4, 1)`` on *both* the link-bound and
compute-bound models (docs/multidevice.md walks through the geometry).

Every geometry x lookahead x hardware-preset efficiency lands in
``benchmarks/out/BENCH_fig9.json`` — written by :func:`run` itself, so
the record exists even outside the ``benchmarks.run`` driver — which is
the cross-PR trajectory for the 0.48 -> parity movement on gh200.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

from repro.core.analytics import HW, crosscheck_executed_volume, simulate_multi
from repro.core.distributed import (grid_broadcast_bytes, modeled_scaling,
                                    panel_broadcast_bytes)
from repro.core.schedule import build_multidevice_schedule

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
_SRC = _REPO_ROOT / "src"
_OUT_JSON = _REPO_ROOT / "benchmarks" / "out" / "BENCH_fig9.json"


def _run_timed_raw(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(_SRC)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env,
                       cwd=str(_REPO_ROOT))
    assert p.returncode == 0, p.stderr[-2000:]
    return p.stdout


def _run_timed(code: str, devices: int) -> float:
    return float(_run_timed_raw(code, devices).split("TIME")[1])


def _measure(devices: int, n: int, tb: int) -> float:
    """Shard_map einsum reference baseline (core/distributed.py)."""
    return _run_timed(f"""
        import time, numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        from repro.core.distributed import distributed_cholesky
        mesh = jax.make_mesh(({devices},), ('model',))
        rng = np.random.default_rng(0)
        x = rng.standard_normal(({n}, {n})); a = x @ x.T + {n}*np.eye({n})
        distributed_cholesky(a, {tb}, mesh)          # warm-up/compile
        t0 = time.time()
        L = distributed_cholesky(a, {tb}, mesh)
        dt = time.time() - t0
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-10, err
        print('TIME', dt)
    """, devices)


def _measure_static(devices: int, n: int, tb: int, grid=None,
                    lookahead=None) -> tuple[float, dict]:
    """Static-schedule executor through the planner API: per-device
    jitted op streams + device-to-device scoped broadcasts, executed
    transfer volume cross-checked against the schedule.  Returns
    ``(seconds, executed transfer stats)``."""
    out = _run_timed_raw(f"""
        import json, time, numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import crosscheck_executed_volume
        rng = np.random.default_rng(0)
        x = rng.standard_normal(({n}, {n})); a = x @ x.T + {n}*np.eye({n})
        cfg = repro.CholeskyConfig(tb={tb}, policy='v3', ndev={devices},
                                   grid={grid!r}, lookahead={lookahead!r},
                                   backend='jax' if {devices} > 1 else 'auto')
        solver = repro.plan({n}, cfg).compile()
        solver.factor(a)                             # warm-up/compile
        t0 = time.time()
        L = solver.factor(a)
        dt = time.time() - t0
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-10, err
        stats = {{}}
        if {devices} > 1:
            cc = crosscheck_executed_volume(solver.schedule,
                                            solver.transfer_stats())
            assert cc['match'], cc['mismatches']
            stats = solver.transfer_stats()
        print('TIME', dt)
        print('STATS', json.dumps(stats))
    """, devices)
    dt = float(out.split("TIME")[1].split("\n")[0])
    stats = json.loads(out.split("STATS")[1].strip())
    return dt, stats


def run(out):
    data = {}
    out("== Fig. 9: multi-device scaling (block-cyclic, 1D + 2D grids) ==")
    n, tb = 512, 32
    out(f"[measured, host devices] matrix {n}x{n}, tile {tb} "
        f"(CPU wall-clock; correctness asserted)")
    out("  static-schedule executor (per-device op streams, V3; "
        "executed bcast bytes == schedule):")
    data["measured_static"] = []
    for d in (1, 2, 4):
        dt, stats = _measure_static(d, n, tb)
        out(f"    {d} device(s): {dt*1e3:8.1f} ms")
        data["measured_static"].append(
            {"ndev": d, "seconds": dt, "executed": stats})
    out("  shard_map einsum reference baseline:")
    for d in (1, 2, 4, 8):
        dt = _measure(d, n, tb)
        out(f"    {d} device(s): {dt*1e3:8.1f} ms")

    # --- 1D vs 2D ownership at ndev=4, NT=8 (the acceptance geometry),
    # --- plus the pipelined (2, 2) at lookahead=1: executed == scheduled
    # --- == simulated bytes asserted for every case, lookahead included
    nt8 = 8
    tb8 = n // nt8
    out(f"[measured, 4 host devices] 1D (4,1) vs 2D (2,2) ownership, "
        f"n={n} tb={tb8} (NT={nt8}); executed == scheduled == simulated, "
        f"asserted:")
    grids = {}
    for grid, la in (((4, 1), 0), ((2, 2), 0), ((2, 2), 1)):
        dt, stats = _measure_static(4, n, tb8, grid=grid,
                                    lookahead=la or None)
        msched = build_multidevice_schedule(nt8, tb8, 4, "v3", grid=grid,
                                            lookahead=la)
        scheduled = msched.bcast_bytes()
        cc = crosscheck_executed_volume(msched, stats, hw=HW["a100-pcie"])
        assert cc["match"], (grid, la, cc["mismatches"])
        sims = {hw: simulate_multi(msched, HW[hw]).makespan
                for hw in ("a100-pcie", "gh200")}
        key = "x".join(map(str, grid)) + (f"_la{la}" if la else "")
        grids[key] = {
            "grid": list(grid), "lookahead": la, "seconds": dt,
            "scheduled_bcast_bytes": scheduled,
            "executed_bcast_bytes": stats["recv_bytes"],
            "simulated_link_bytes": cc["expected"]["simulated_link_bytes"],
            "executed": stats,
            "modeled_makespan_s": sims,
        }
        out(f"    grid {grid} la={la}: {dt*1e3:8.1f} ms   bcast "
            f"{scheduled/1e6:6.2f} MB scheduled == "
            f"{stats['recv_bytes']/1e6:6.2f} MB executed   "
            f"(modeled a100-pcie {sims['a100-pcie']*1e3:.2f} ms)")
    r1d, r2d = grids["4x1"], grids["2x2"]
    assert r2d["executed_bcast_bytes"] < r1d["executed_bcast_bytes"]
    assert r2d["scheduled_bcast_bytes"] < r1d["scheduled_bcast_bytes"]
    # the pipeline moves the same bytes as the plain 2D grid, earlier
    assert (grids["2x2_la1"]["executed_bcast_bytes"]
            == r2d["executed_bcast_bytes"])
    out(f"    => 2D moves {r2d['executed_bcast_bytes']/1e6:.2f} MB vs 1D "
        f"{r1d['executed_bcast_bytes']/1e6:.2f} MB over the interconnect "
        f"({r1d['executed_bcast_bytes']/r2d['executed_bcast_bytes']:.2f}x "
        f"less; O(sqrt P) ownership, docs/multidevice.md), and "
        f"lookahead=1 moves them earlier without adding any")
    data["ndev4_nt8_1d_vs_2d"] = grids

    nt, tbm = 32, 1024
    out(f"[modeled] static per-device op streams, f64 V3, "
        f"n={nt*tbm} tb={tbm} (simulate_multi; exact schedule replay), "
        f"every hardware preset x geometry x lookahead:")
    eff4 = {}
    data["modeled"] = {}
    for hw_name in sorted(HW):
        hw = HW[hw_name]
        out(f"  {hw_name} (link {hw.h2d_bw/1e9:.0f} GB/s):")
        rows = modeled_scaling(nt, tbm, ndevs=(1, 2, 4), hw_name=hw_name)
        t1 = rows[0]["makespan"]
        # per-geometry pipeline sweep at ndev=4, reusing the 1-device
        # baseline already in rows[0]: (4,1) la=0 duplicates rows[2] but
        # keeps the geometry record self-contained
        geometries = []
        for grid in ((4, 1), (2, 2)):
            for la in (0, 1, 2):
                m = build_multidevice_schedule(nt, tbm, 4, "v3", grid=grid,
                                               lookahead=la)
                r = simulate_multi(m, hw)
                geometries.append({
                    "ndev": 4, "grid": list(grid), "lookahead": la,
                    "hw": hw_name, "policy": "v3",
                    "makespan": r.makespan, "tflops": r.tflops,
                    "speedup": t1 / r.makespan,
                    "efficiency": t1 / (4 * r.makespan),
                    "compute_efficiency": r.compute_efficiency,
                    "bcast_bytes": m.bcast_bytes(),
                    "link_busy": r.link_busy,
                })
        data["modeled"][hw_name] = {"scaling": rows,
                                    "geometries": geometries}
        for row in rows:
            out(f"    {row['ndev']} device(s) {str(tuple(row['grid'])):7s}:"
                f" makespan {row['makespan']:7.3f}s"
                f"  {row['tflops']:6.1f} TFlop/s"
                f"  speedup {row['speedup']:4.2f}"
                f"  compute-eff {row['compute_efficiency']*100:5.1f}%"
                f"  bcast {row['bcast_bytes']/1e9:6.2f} GB")
        for row in geometries:
            out(f"    4 device(s) {str(tuple(row['grid'])):7s} la="
                f"{row['lookahead']}: makespan {row['makespan']:7.3f}s"
                f"  speedup {row['speedup']:4.2f}"
                f"  eff {row['efficiency']*100:5.1f}%"
                f"  bcast {row['bcast_bytes']/1e9:6.2f} GB")
        best = {g: min((r for r in geometries if tuple(r["grid"]) == g),
                       key=lambda r: r["makespan"])
                for g in ((4, 1), (2, 2))}
        eff4[hw_name] = best
        out(f"    best (2,2) {best[(2, 2)]['makespan']:.3f}s (la="
            f"{best[(2, 2)]['lookahead']}) vs best (4,1) "
            f"{best[(4, 1)]['makespan']:.3f}s (la="
            f"{best[(4, 1)]['lookahead']})")
    # the PR 6 acceptance bar: pipelined (2, 2) beats (4, 1) on BOTH the
    # link-bound and the compute-bound model (pre-lookahead, gh200 ran
    # (2, 2) at 0.48 efficiency vs (4, 1) at 0.74)
    data["win_2d"] = {}
    for hw_name in ("a100-pcie", "gh200"):
        b22, b41 = eff4[hw_name][(2, 2)], eff4[hw_name][(4, 1)]
        assert b22["makespan"] < b41["makespan"], (hw_name, b22, b41)
        data["win_2d"][hw_name] = {
            "best_2x2": b22, "best_4x1": b41,
            "speedup_2x2_over_4x1": b41["makespan"] / b22["makespan"],
        }
        out(f"  => {hw_name}: pipelined (2,2) beats (4,1) by "
            f"{b41['makespan'] / b22['makespan']:.2f}x "
            f"(la={b22['lookahead']})")
    out("  => the (2, 2) grid moves fewer broadcast bytes *and*, with "
        "lookahead pipelining the panel/broadcast critical path behind "
        "the other grid column's trailing update, now also wins makespan "
        "on the compute-bound model — the tuner's lookahead dimension "
        "scores this per hardware model (docs/multidevice.md)")

    out("[analytic] broadcast volume (matches the schedules exactly):")
    for p in (2, 4):
        out(f"  {p} device(s) 1D: "
            f"{panel_broadcast_bytes(nt, tbm, p)/1e9:.2f} GB")
    out(f"  4 device(s) (2,2): "
        f"{grid_broadcast_bytes(nt, tbm, (2, 2))/1e9:.2f} GB")
    out("")
    # always leave the machine-readable record behind, even when invoked
    # outside benchmarks.run (whose fuller record overwrites this one)
    _OUT_JSON.parent.mkdir(parents=True, exist_ok=True)
    with open(_OUT_JSON, "w") as f:
        json.dump({"bench": "fig9", "ok": True, "data": data}, f,
                  indent=1, sort_keys=True, default=str)
    out(f"wrote {_OUT_JSON}")
    return data


if __name__ == "__main__":
    run(print)
