"""Fig. 9: multi-device scaling of the block-cyclic Cholesky, 1D vs 2D.

Measured, two runtimes on forced host devices (subprocess; correctness
asserted against LAPACK):

* the *static-schedule executor* on 1/2/4 devices — per-device op
  streams replayed by ``make_multidevice_jax_executor`` through the
  public planner API (``CholeskyConfig(ndev=..., backend='jax')``),
  executed BCAST/RECV bytes cross-checked against the schedule; this is
  the run the modeled numbers below describe op for op.  At 4 devices
  both the paper's 1D tile-row layout and the 2D ``(2, 2)`` grid run,
  and their *executed* interconnect bytes are reported side by side
  (the 2D grid must move strictly less — the PR 5 acceptance bar,
  recorded in ``BENCH_fig9.json``);
* the shard_map einsum reference baseline (``distributed_cholesky``) on
  1/2/4/8 devices.

Modeled: event simulation of the same static op streams
(`build_multidevice_schedule` + `simulate_multi`) on the paper's
platforms — per-device H2D/D2H/compute engines plus the shared
interconnect carrying the scoped broadcasts.  The qualitative Fig. 9
claim is the interconnect story: the faster link (NVLink-C2C on GH200)
keeps parallel compute efficiency high where the PCIe-class platforms
drown in broadcast traffic — and the 2D grid attacks the same bottleneck
from the schedule side by shrinking the broadcast itself
(docs/multidevice.md walks through the ownership geometry).
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

from repro.core.analytics import HW, simulate_multi
from repro.core.distributed import (grid_broadcast_bytes, modeled_scaling,
                                    panel_broadcast_bytes)
from repro.core.schedule import build_multidevice_schedule

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
_SRC = _REPO_ROOT / "src"


def _run_timed_raw(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(_SRC)] + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=900, env=env,
                       cwd=str(_REPO_ROOT))
    assert p.returncode == 0, p.stderr[-2000:]
    return p.stdout


def _run_timed(code: str, devices: int) -> float:
    return float(_run_timed_raw(code, devices).split("TIME")[1])


def _measure(devices: int, n: int, tb: int) -> float:
    """Shard_map einsum reference baseline (core/distributed.py)."""
    return _run_timed(f"""
        import time, numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        from repro.core.distributed import distributed_cholesky
        mesh = jax.make_mesh(({devices},), ('model',))
        rng = np.random.default_rng(0)
        x = rng.standard_normal(({n}, {n})); a = x @ x.T + {n}*np.eye({n})
        distributed_cholesky(a, {tb}, mesh)          # warm-up/compile
        t0 = time.time()
        L = distributed_cholesky(a, {tb}, mesh)
        dt = time.time() - t0
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-10, err
        print('TIME', dt)
    """, devices)


def _measure_static(devices: int, n: int, tb: int,
                    grid=None) -> tuple[float, dict]:
    """Static-schedule executor through the planner API: per-device
    jitted op streams + device-to-device scoped broadcasts, executed
    transfer volume cross-checked against the schedule.  Returns
    ``(seconds, executed transfer stats)``."""
    out = _run_timed_raw(f"""
        import json, time, numpy as np, jax
        jax.config.update('jax_enable_x64', True)
        import repro
        from repro.core.analytics import crosscheck_executed_volume
        rng = np.random.default_rng(0)
        x = rng.standard_normal(({n}, {n})); a = x @ x.T + {n}*np.eye({n})
        cfg = repro.CholeskyConfig(tb={tb}, policy='v3', ndev={devices},
                                   grid={grid!r},
                                   backend='jax' if {devices} > 1 else 'auto')
        solver = repro.plan({n}, cfg).compile()
        solver.factor(a)                             # warm-up/compile
        t0 = time.time()
        L = solver.factor(a)
        dt = time.time() - t0
        err = np.abs(L - np.linalg.cholesky(a)).max()
        assert err < 1e-10, err
        stats = {{}}
        if {devices} > 1:
            cc = crosscheck_executed_volume(solver.schedule,
                                            solver.transfer_stats())
            assert cc['match'], cc['mismatches']
            stats = solver.transfer_stats()
        print('TIME', dt)
        print('STATS', json.dumps(stats))
    """, devices)
    dt = float(out.split("TIME")[1].split("\n")[0])
    stats = json.loads(out.split("STATS")[1].strip())
    return dt, stats


def run(out):
    data = {}
    out("== Fig. 9: multi-device scaling (block-cyclic, 1D + 2D grids) ==")
    n, tb = 512, 32
    out(f"[measured, host devices] matrix {n}x{n}, tile {tb} "
        f"(CPU wall-clock; correctness asserted)")
    out("  static-schedule executor (per-device op streams, V3; "
        "executed bcast bytes == schedule):")
    data["measured_static"] = []
    for d in (1, 2, 4):
        dt, stats = _measure_static(d, n, tb)
        out(f"    {d} device(s): {dt*1e3:8.1f} ms")
        data["measured_static"].append(
            {"ndev": d, "seconds": dt, "executed": stats})
    out("  shard_map einsum reference baseline:")
    for d in (1, 2, 4, 8):
        dt = _measure(d, n, tb)
        out(f"    {d} device(s): {dt*1e3:8.1f} ms")

    # --- 1D vs 2D ownership at ndev=4, NT=8 (the acceptance geometry) ---
    nt8 = 8
    tb8 = n // nt8
    out(f"[measured, 4 host devices] 1D (4,1) vs 2D (2,2) ownership, "
        f"n={n} tb={tb8} (NT={nt8}); executed == scheduled, asserted:")
    grids = {}
    for grid in ((4, 1), (2, 2)):
        dt, stats = _measure_static(4, n, tb8, grid=grid)
        msched = build_multidevice_schedule(nt8, tb8, 4, "v3", grid=grid)
        scheduled = msched.bcast_bytes()
        assert stats["recv_bytes"] == scheduled, (grid, stats, scheduled)
        sims = {hw: simulate_multi(msched, HW[hw]).makespan
                for hw in ("a100-pcie", "gh200")}
        grids["x".join(map(str, grid))] = {
            "grid": list(grid), "seconds": dt,
            "scheduled_bcast_bytes": scheduled,
            "executed_bcast_bytes": stats["recv_bytes"],
            "executed": stats,
            "modeled_makespan_s": sims,
        }
        out(f"    grid {grid}: {dt*1e3:8.1f} ms   bcast "
            f"{scheduled/1e6:6.2f} MB scheduled == "
            f"{stats['recv_bytes']/1e6:6.2f} MB executed   "
            f"(modeled a100-pcie {sims['a100-pcie']*1e3:.2f} ms)")
    r1d, r2d = grids["4x1"], grids["2x2"]
    assert r2d["executed_bcast_bytes"] < r1d["executed_bcast_bytes"]
    assert r2d["scheduled_bcast_bytes"] < r1d["scheduled_bcast_bytes"]
    out(f"    => 2D moves {r2d['executed_bcast_bytes']/1e6:.2f} MB vs 1D "
        f"{r1d['executed_bcast_bytes']/1e6:.2f} MB over the interconnect "
        f"({r1d['executed_bcast_bytes']/r2d['executed_bcast_bytes']:.2f}x "
        f"less; O(sqrt P) ownership, docs/multidevice.md)")
    data["ndev4_nt8_1d_vs_2d"] = grids

    nt, tbm = 32, 1024
    out(f"[modeled] static per-device op streams, f64 V3, "
        f"n={nt*tbm} tb={tbm} (simulate_multi; exact schedule replay):")
    eff4 = {}
    data["modeled"] = {}
    for hw_name in ("a100-pcie", "gh200"):
        hw = HW[hw_name]
        out(f"  {hw_name} (link {hw.h2d_bw/1e9:.0f} GB/s):")
        rows = modeled_scaling(nt, tbm, ndevs=(1, 2, 4), hw_name=hw_name)
        # the (2, 2) grid row, reusing the 1-device baseline already in
        # rows[0] instead of re-simulating it
        m22 = build_multidevice_schedule(nt, tbm, 4, "v3", grid=(2, 2))
        r22 = simulate_multi(m22, hw)
        t1 = rows[0]["makespan"]
        rows.append({
            "ndev": 4, "grid": [2, 2], "hw": hw_name, "policy": "v3",
            "makespan": r22.makespan, "tflops": r22.tflops,
            "speedup": t1 / r22.makespan,
            "efficiency": t1 / (4 * r22.makespan),
            "compute_efficiency": r22.compute_efficiency,
            "bcast_bytes": m22.bcast_bytes(),
            "link_busy": r22.link_busy,
        })
        data["modeled"][hw_name] = rows
        for row in rows:
            out(f"    {row['ndev']} device(s) {str(tuple(row['grid'])):7s}:"
                f" makespan {row['makespan']:7.3f}s"
                f"  {row['tflops']:6.1f} TFlop/s"
                f"  speedup {row['speedup']:4.2f}"
                f"  compute-eff {row['compute_efficiency']*100:5.1f}%"
                f"  bcast {row['bcast_bytes']/1e9:6.2f} GB")
            if row["ndev"] == 4 and row["grid"] == [4, 1]:
                eff4[hw_name] = row
    g4, a4 = eff4["gh200"], eff4["a100-pcie"]
    out(f"  => 4-device compute efficiency: gh200 "
        f"{g4['compute_efficiency']*100:.1f}% vs a100-pcie "
        f"{a4['compute_efficiency']*100:.1f}% — the faster interconnect "
        f"keeps the scaling slope (paper Fig. 9).  The (2, 2) grid "
        f"always moves fewer broadcast bytes; whether that wins makespan "
        f"depends on where the bottleneck is (link-bound: yes; "
        f"compute-bound: the column step engages only one grid column "
        f"of devices) — exactly the trade the tuner's grid dimension "
        f"scores per hardware model (docs/multidevice.md)")

    out("[analytic] broadcast volume (matches the schedules exactly):")
    for p in (2, 4):
        out(f"  {p} device(s) 1D: "
            f"{panel_broadcast_bytes(nt, tbm, p)/1e9:.2f} GB")
    out(f"  4 device(s) (2,2): "
        f"{grid_broadcast_bytes(nt, tbm, (2, 2))/1e9:.2f} GB")
    out("")
    return data
