"""Benchmark harness: one module per paper figure/table.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig6 fig10 # subset
"""
import sys
import time

from . import (fig6_versions, fig8_volume, fig9_multidev, fig10_kl,
               fig11_mxp_perf, fig12_mxp_volume, fig13_traces,
               perf_cholesky, roofline)

BENCHES = {
    "fig6": fig6_versions,
    "fig8": fig8_volume,
    "fig9": fig9_multidev,
    "fig10": fig10_kl,
    "fig11": fig11_mxp_perf,
    "fig12": fig12_mxp_volume,
    "fig13": fig13_traces,
    "perf_cholesky": perf_cholesky,
    "roofline": roofline,
}


def main():
    names = sys.argv[1:] or list(BENCHES)
    failures = []
    for name in names:
        mod = BENCHES[name]
        t0 = time.time()
        try:
            mod.run(print)
            print(f"[{name}] OK in {time.time()-t0:.1f}s\n", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"[{name}] FAILED: {e}\n", flush=True)
    if failures:
        sys.exit(1)
    print(f"== all {len(names)} benchmarks passed ==")


if __name__ == "__main__":
    main()
