"""Canonical benchmark runner: one module per paper figure/table, plus the
tuner trajectory — every run also emits machine-readable JSON so the perf
history is recorded across PRs.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig6 tune  # subset

Each bench module's ``run(out)`` may return a JSON-serializable dict of
its headline numbers (makespans, tflops, byte volumes, tuned-vs-default
ratios).  The runner writes one ``out/BENCH_<name>.json`` per bench —
``{"bench", "ok", "seconds", "repro_version", "data"}`` — and an
aggregate ``out/BENCH_summary.json``; diffing those files between
commits is the perf trajectory.
"""
import json
import pathlib
import sys
import time

from . import (bench_serve, bench_spill, bench_tune, fig6_versions,
               fig8_volume, fig9_multidev, fig10_kl, fig11_mxp_perf,
               fig12_mxp_volume, fig13_traces, perf_cholesky, roofline)

BENCHES = {
    "fig6": fig6_versions,
    "fig8": fig8_volume,
    "fig9": fig9_multidev,
    "fig10": fig10_kl,
    "fig11": fig11_mxp_perf,
    "fig12": fig12_mxp_volume,
    "fig13": fig13_traces,
    "perf_cholesky": perf_cholesky,
    "kernels": roofline,
    "tune": bench_tune,
    "serve": bench_serve,
    "spill": bench_spill,
}

OUT_DIR = pathlib.Path(__file__).resolve().parent / "out"


def _write(name: str, record: dict) -> pathlib.Path:
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True, default=str)
    return path


def main():
    import repro
    names = sys.argv[1:] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        sys.exit(f"unknown bench(es) {unknown}; expected {list(BENCHES)}")
    failures = []
    summary = {}
    for name in names:
        mod = BENCHES[name]
        t0 = time.time()
        record = {"bench": name, "repro_version": repro.__version__}
        try:
            record["data"] = mod.run(print)
            record["ok"] = True
            print(f"[{name}] OK in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            record["ok"] = False
            record["error"] = f"{type(e).__name__}: {e}"
            print(f"[{name}] FAILED: {e}", flush=True)
        record["seconds"] = round(time.time() - t0, 3)
        path = _write(name, record)
        summary[name] = {k: record[k] for k in ("ok", "seconds")}
        print(f"[{name}] wrote {path}\n", flush=True)
    _write("summary", {"bench": "summary",
                       "repro_version": repro.__version__,
                       "benches": summary})
    if failures:
        sys.exit(1)
    print(f"== all {len(names)} benchmarks passed ==")


if __name__ == "__main__":
    main()
