"""Fig. 8: exact C2G/G2C data-movement volume per policy.

These are exact replays of the static schedule, not estimates; the
V3 < V2 < V1 < async ordering and the half-matrix G2C property are
asserted as part of the benchmark.  Volumes come straight off the cached
plans of the planner API (no executor is ever built).
"""
import repro

POLICIES = ["sync", "async", "v1", "v2", "v3"]


def run(out):
    out("== Fig. 8: data-movement volume (exact, from the schedule) ==")
    tb = 512
    data = {}
    for nt in (16, 32):
        n = nt * tb
        out(f"matrix {n}x{n} (f64 {8*n*n/1e9:.1f} GB), tile {tb}:")
        out(f"  {'policy':8s} {'C2G GB':>9s} {'G2C GB':>9s} "
            f"{'total GB':>9s} {'loads':>7s} {'hits':>6s}")
        vols = {}
        data[n] = {}
        for p in POLICIES:
            r = repro.plan(n, tb=tb, policy=p).volume()
            vols[p] = r["c2g_bytes"]
            data[n][p] = {k: r[k] for k in
                          ("c2g_bytes", "g2c_bytes", "total_bytes",
                           "loads", "cache_hits")}
            out(f"  {p:8s} {r['c2g_bytes']/1e9:9.2f} "
                f"{r['g2c_bytes']/1e9:9.2f} {r['total_bytes']/1e9:9.2f} "
                f"{r['loads']:7d} {r['cache_hits']:6d}")
            if p in ("v1", "v2", "v3"):
                assert r["g2c_bytes"] == 8 * tb * tb * nt * (nt + 1) // 2, \
                    "V* must copy back only the triangular part (Fig. 8)"
        assert vols["v3"] <= vols["v2"] <= vols["v1"] < vols["async"]
        out(f"  async/V3 volume ratio: {vols['async']/vols['v3']:.2f}x")
    out("")
    return {"tb": tb, "volumes_by_n": data}
