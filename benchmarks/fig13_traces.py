"""Fig. 7/13: engine traces — overlap quality across policies and
correlation levels (100k-class matrix, GH200 model).

Each simulated timeline is printed as an ASCII trace and exported as
chrome://tracing JSON (``benchmarks/out/fig13_<label>.trace.json``; open
at chrome://tracing or https://ui.perfetto.dev) — one track per engine,
one complete event per op span.
"""
import pathlib

import numpy as np

import repro
from repro.core.analytics import HW, ascii_trace, chrome_trace
from repro.core.precision import assign_precision

OUT_DIR = pathlib.Path(__file__).resolve().parent / "out"


def _plan(nt, decay, eps=1e-5, seed=0):
    rng = np.random.default_rng(seed)
    norms = np.abs(rng.standard_normal((nt, nt))) + 0.5
    for j in range(nt):
        for i in range(j, nt):
            norms[i, j] *= decay ** min(abs(i - j), 6)
    norms[np.diag_indices(nt)] = 10.0
    return assign_precision(norms, float(np.sqrt((norms ** 2).sum())), eps)


def _export(label, r, out):
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"fig13_{label}.trace.json"
    chrome_trace(r, path)
    out(f"   chrome trace -> {path}")


def run(out):
    out("== Fig. 7/13: engine traces (o=C2G  #=compute  g=G2C) ==")
    nt, tb = 24, 1024
    n = nt * tb
    hw = HW["gh200"]
    data = {"n": n, "tb": tb, "hw": "gh200", "policies": {}}
    out(f"[Fig. 7] {n}x{n} FP64, GH200:")
    for policy in ("sync", "v3"):
        r = repro.plan(n, tb=tb, policy=policy).simulate(
            hw, record_timeline=True)
        out(f"-- {policy} ({r.makespan*1e3:.0f} ms) --")
        out(ascii_trace(r))
        _export(policy, r, out)
        data["policies"][policy] = {"makespan_s": r.makespan,
                                    "tflops": r.tflops,
                                    "h2d_bytes": r.h2d_bytes,
                                    "d2h_bytes": r.d2h_bytes}
    out(f"[Fig. 13] V3 MxP at three correlation levels (eps=1e-5):")
    for name, decay in (("weak", 1e-3), ("medium", 1e-2), ("strong", 2e-1)):
        pl = repro.plan(n, repro.CholeskyConfig(tb=tb, policy="v3",
                                                plan=_plan(nt, decay)))
        r = pl.simulate(hw, record_timeline=True)
        out(f"-- {name} ({r.makespan*1e3:.0f} ms, "
            f"{ {k: v for k, v in pl.schedule.plan.histogram().items() if v} }) --")
        out(ascii_trace(r))
        _export(f"mxp_{name}", r, out)
        data["policies"][f"mxp_{name}"] = {"makespan_s": r.makespan,
                                           "tflops": r.tflops}
    # the paper's takeaway: compute time shrinks with weaker correlation
    t = {}
    for name, decay in (("weak", 1e-3), ("strong", 2e-1)):
        cfg = repro.CholeskyConfig(tb=tb, policy="v3", plan=_plan(nt, decay))
        t[name] = repro.plan(n, cfg).simulate(hw).compute_busy
    assert t["weak"] < t["strong"]

    # pipelined-panel trace (PR 6): the per-device d{d}:pipe lanes color
    # lookahead-panel work distinctly from the trailing update, so the
    # overlap the emitter buys is visible at chrome://tracing
    from repro.core.analytics import simulate_multi
    from repro.core.schedule import build_multidevice_schedule
    m = build_multidevice_schedule(nt, tb, 4, "v3", grid=(2, 2),
                                   lookahead=2)
    r = simulate_multi(m, hw, record_timeline=True)
    tr = chrome_trace(r, OUT_DIR / "fig13_pipeline_2x2_la2.trace.json")
    pipe = [e for e in tr["traceEvents"] if e.get("cat", "").endswith(":pipe")]
    ahead = sum(1 for e in pipe if e["name"].startswith("ahead:"))
    assert ahead and len(pipe) > ahead     # both phases present + colored
    out(f"[pipeline] (2,2) lookahead=2, 4 devices "
        f"({r.makespan*1e3:.0f} ms): {ahead} lookahead-panel spans vs "
        f"{len(pipe) - ahead} trailing-update spans on the d*:pipe lanes")
    _export("pipeline_2x2_la2", r, out)
    data["pipeline"] = {"makespan_s": r.makespan, "lookahead": 2,
                        "grid": [2, 2], "ahead_spans": ahead,
                        "trail_spans": len(pipe) - ahead}

    # measured counterpart (PR 9, repro.obs): trace a real factorization
    # on the live backend, export it in the same lane vocabulary, and
    # close the model-vs-measured loop — drift report against the
    # datasheet model, then a trace-refined model that must predict the
    # same trace strictly better
    from repro.obs import TraceRecorder, chrome_trace_measured, drift_report
    from repro.tune import refine_from_trace
    nm, tbm = 576, 96
    rng = np.random.default_rng(7)
    am = rng.standard_normal((nm, nm))
    am = am @ am.T + nm * np.eye(nm)
    plm = repro.plan(nm, tb=tbm, policy="v3")
    rec = TraceRecorder()
    plm.compile().factor(am, trace=rec)
    nops = len(plm.single_schedule().ops)
    assert len(rec.spans) == nops, (len(rec.spans), nops)
    mpath = OUT_DIR / "fig13_measured.trace.json"
    chrome_trace_measured(rec, mpath)
    out(f"   measured chrome trace -> {mpath}")
    rep = drift_report(rec, plm.simulate(hw, record_timeline=True))
    refined = refine_from_trace(rec, base=hw)
    rep_ref = drift_report(rec, plm.simulate(refined, record_timeline=True))
    assert rep_ref.total_abs_error < rep.total_abs_error
    out(f"[measured] {nm}x{nm} tb={tbm} on the live backend: "
        f"{len(rec.spans)} spans == {nops} ops, "
        f"makespan {rec.makespan_s()*1e3:.0f} ms; drift vs {hw.name} "
        f"x{rep.makespan_ratio:.1f}, refined abs error "
        f"{rep_ref.total_abs_error:.3f}s < {rep.total_abs_error:.3f}s; "
        f"predicted overlap eff {rep.predicted_overlap_efficiency}")
    data["measured"] = {
        "n": nm, "tb": tbm,
        "spans": len(rec.spans), "ops": nops,
        "makespan_s": rec.makespan_s(),
        "makespan_ratio_vs_model": rep.makespan_ratio,
        "total_abs_error_s": rep.total_abs_error,
        "refined_total_abs_error_s": rep_ref.total_abs_error,
        "predicted_overlap_efficiency": rep.predicted_overlap_efficiency,
        # per-op fencing serializes copy and compute, so measured
        # overlap is ~0 by construction (docs/observability.md)
        "measured_overlap_efficiency": rep.measured_overlap_efficiency,
    }
    out("")
    return data
