"""Fig. 12: data-movement volume of MxP schedules vs accuracy level."""
import repro
from repro.core.tiling import to_tiles
from repro.geo.matern import (BETA_MEDIUM, BETA_STRONG, BETA_WEAK,
                              generate_locations, matern_covariance)


def run(out):
    out("== Fig. 12: MxP data-movement volume vs accuracy ==")
    n, tb = 2048, 256
    locs = generate_locations(n, seed=2)
    f64 = repro.plan(n, tb=tb, policy="v3").volume()
    vol64 = f64["total_bytes"]
    for name, beta in (("weak", BETA_WEAK), ("medium", BETA_MEDIUM),
                       ("strong", BETA_STRONG)):
        cov = matern_covariance(locs, beta=beta)
        tiles = to_tiles(cov, tb)
        cells = [f"fp64 {vol64/1e6:7.1f} MB"]
        vols = {}
        for eps in (1e-5, 1e-6, 1e-8):
            plan = repro.plan_for_matrix(tiles, eps)
            cfg = repro.CholeskyConfig(tb=tb, policy="v3", plan=plan)
            v = repro.plan(n, cfg).volume()["total_bytes"]
            vols[eps] = v
            hist = {k: c for k, c in plan.histogram().items() if c}
            cells.append(f"eps={eps:.0e} {v/1e6:7.1f} MB {hist}")
        out(f"correlation {name}: " + "\n    ".join(cells))
        assert vols[1e-5] <= vols[1e-8] <= vol64, \
            "volume must grow with accuracy and stay below fp64"
    out("")
