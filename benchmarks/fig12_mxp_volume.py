"""Fig. 12: data-movement volume of MxP schedules vs accuracy level."""
from repro.core.cholesky import plan_for_matrix
from repro.core.schedule import build_schedule
from repro.core.tiling import to_tiles
from repro.geo.matern import (BETA_MEDIUM, BETA_STRONG, BETA_WEAK,
                              generate_locations, matern_covariance)


def run(out):
    out("== Fig. 12: MxP data-movement volume vs accuracy ==")
    n, tb = 2048, 256
    locs = generate_locations(n, seed=2)
    for name, beta in (("weak", BETA_WEAK), ("medium", BETA_MEDIUM),
                       ("strong", BETA_STRONG)):
        cov = matern_covariance(locs, beta=beta)
        tiles = to_tiles(cov, tb)
        f64 = build_schedule(n // tb, tb, "v3")
        vol64 = f64.loads_bytes() + f64.stores_bytes()
        cells = [f"fp64 {vol64/1e6:7.1f} MB"]
        vols = {}
        for eps in (1e-5, 1e-6, 1e-8):
            plan = plan_for_matrix(tiles, eps)
            s = build_schedule(n // tb, tb, "v3", plan=plan)
            v = s.loads_bytes() + s.stores_bytes()
            vols[eps] = v
            hist = {k: c for k, c in plan.histogram().items() if c}
            cells.append(f"eps={eps:.0e} {v/1e6:7.1f} MB {hist}")
        out(f"correlation {name}: " + "\n    ".join(cells))
        assert vols[1e-5] <= vols[1e-8] <= vol64, \
            "volume must grow with accuracy and stay below fp64"
    out("")
