"""Serving throughput: sustained solves/sec + tail latency under load.

The first benchmark in the repo whose metric is *throughput of a request
stream*, not one factorization's makespan — the regime the ROADMAP's
"millions of users" north star cares about.  The workload is the
paper's motivating application: geospatial Matérn MLE tenants, each
holding a factored covariance and fanning out correlated
likelihood-style solves (``repro.geo.likelihood`` drives the session
handles directly).

Two phases:

* **MLE traffic** — ``TENANTS`` concurrent sessions factor their own
  Matérn covariance through the shared plan cache, then evaluate
  stacked observation log-likelihoods; checks the served numbers equal
  the serial solver's.
* **Open-loop load** — a fixed burst of single-RHS solve requests per
  tenant is pushed through (a) a batching service and (b) the identical
  service with batching disabled (the one-RHS-at-a-time baseline).
  Open loop: arrivals are scripted up front, never gated on
  completions, so queueing delay lands in the latency percentiles
  instead of silently throttling the offered load.  Asserts the batched
  service coalesced at least one multi-RHS solve and sustained strictly
  more solves/sec than the baseline under the same load.

Emits p50/p99/mean latency, solves/sec, batch occupancy, plan-cache and
solver-reuse counters into ``benchmarks/out/BENCH_serve.json`` (via
``benchmarks.run serve``).
"""
import threading

import numpy as np

import repro
from repro.geo.likelihood import gaussian_loglik
from repro.geo.matern import generate_locations, matern_covariance
from repro.serve import SolverService

N = 192          # per-tenant problem size (nt=6 at tb=32: OOC-shaped,
TB = 32          #   but small enough for the CI gate)
TENANTS = 4
SOLVES_PER_TENANT = 120
WORKERS = 2
OBS_STACK = 8    # stacked observations per likelihood evaluation


def _covariances():
    covs = []
    for t in range(TENANTS):
        locs = generate_locations(N, seed=t)
        covs.append(matern_covariance(locs, beta=0.1, nu=0.5))
    return covs


def _config():
    # the numpy backend keeps the gate portable; the serve layer is
    # backend-agnostic (workers call the same OOCSolver surface)
    return repro.CholeskyConfig(tb=TB, policy="v3", backend="numpy")


def _mle_phase(out, covs, rng):
    """Concurrent tenants evaluating stacked observation log-likelihoods
    through served sessions; cross-checked against serial solvers."""
    cfg = _config()
    ys = [rng.standard_normal((N, OBS_STACK)) for _ in range(TENANTS)]

    serial = []
    for t in range(TENANTS):
        sv = repro.plan(N, cfg).compile()
        sv.factor(covs[t], materialize=False)
        serial.append(gaussian_loglik(sv, ys[t]))

    with SolverService(workers=WORKERS) as svc:
        sessions = [svc.session(f"tenant{t}", N, cfg)
                    for t in range(TENANTS)]
        errs = []

        def tenant(t):
            try:
                s = sessions[t]
                s.factor(covs[t])
                ll = gaussian_loglik(s, ys[t])     # session duck-types
                if not np.allclose(ll, serial[t], rtol=0, atol=1e-9):
                    raise AssertionError(
                        f"tenant {t} loglik mismatch: {ll} vs {serial[t]}")
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=tenant, args=(t,))
                   for t in range(TENANTS)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errs:
            raise errs[0]
        snap = svc.metrics.snapshot()
    out(f"[mle] {TENANTS} tenants x {OBS_STACK} stacked obs: "
        f"plan-cache hits={snap['plan_cache']['hits']} "
        f"misses={snap['plan_cache']['misses']}, "
        f"solver compiles={snap['solver']['compiles']}")
    return {"tenants": TENANTS, "obs_stack": OBS_STACK,
            "plan_cache": snap["plan_cache"],
            "solver": snap["solver"]}


def _load_phase(out, covs, rng, batch_window, max_batch, label):
    """Open-loop burst: every request scripted up front, submitted
    without waiting on completions; drain and report."""
    cfg = _config()
    rhss = [[rng.standard_normal(N) for _ in range(SOLVES_PER_TENANT)]
            for _ in range(TENANTS)]
    with SolverService(workers=WORKERS, batch_window=batch_window,
                       max_batch=max_batch) as svc:
        sessions = [svc.session(f"tenant{t}", N, cfg)
                    for t in range(TENANTS)]
        for t, s in enumerate(sessions):
            s.factor(covs[t])
        futs = [s.solve_async(b)
                for t, s in enumerate(sessions) for b in rhss[t]]
        for f in futs:
            f.result(timeout=300)
        snap = svc.metrics.snapshot()
    out(f"[{label}] {len(futs)} solves: {snap['solves_per_s']:.0f}/s, "
        f"p50 {snap['latency_s']['p50']*1e3:.1f} ms, "
        f"p99 {snap['latency_s']['p99']*1e3:.1f} ms, "
        f"max batch occupancy {snap['batch']['max_occupancy']}")
    return snap


def run(out):
    out("== serve: open-loop factor/solve serving throughput ==")
    rng = np.random.default_rng(7)
    covs = _covariances()
    repro.clear_plan_cache()

    mle = _mle_phase(out, covs, rng)
    baseline = _load_phase(out, covs, rng, batch_window=0.0, max_batch=1,
                           label="1-rhs baseline")
    batched = _load_phase(out, covs, rng, batch_window=0.004, max_batch=32,
                          label="batched")

    assert batched["batch"]["max_occupancy"] >= 2, \
        "no multi-RHS batch occurred under the open-loop load"
    assert batched["solves_per_s"] > baseline["solves_per_s"], (
        f"batched serving ({batched['solves_per_s']:.0f} solves/s) did not "
        f"beat the one-RHS-at-a-time baseline "
        f"({baseline['solves_per_s']:.0f} solves/s)")
    speedup = batched["solves_per_s"] / max(baseline["solves_per_s"], 1e-12)
    out(f"[serve] batching speedup {speedup:.2f}x "
        f"({baseline['solves_per_s']:.0f} -> "
        f"{batched['solves_per_s']:.0f} solves/s)")
    return {
        "n": N, "tb": TB, "tenants": TENANTS, "workers": WORKERS,
        "solves_per_tenant": SOLVES_PER_TENANT,
        "mle": mle,
        "baseline": baseline,
        "batched": batched,
        "batching_speedup": speedup,
    }
