"""Fig. 6: single-device Cholesky throughput, policy ladder vs in-core.

Two views:
  * measured — wall-clock GFlop/s of the compiled OOC solver vs XLA's
    in-core ``jnp.linalg.cholesky`` on this host (small N; CPU CI).  The
    solver is compiled once per policy and replayed, so the timed call
    measures pure execution — the amortize-once/replay-many point of the
    planner API.
  * modeled  — the three-engine simulator on the paper's platforms and
    the TPU v5e target across matrix sizes (the Fig. 6 curves).
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

import repro
from repro.core.analytics import HW
from repro.core.tiling import random_spd

POLICIES = ["sync", "async", "v1", "v2", "v3"]


def run(out):
    out("== Fig. 6: single-device FP64 Cholesky, policy ladder ==")
    # ---- measured on this host ----
    n, tb = 768, 128
    a = random_spd(n, seed=0)
    flops = n ** 3 / 3
    t0 = time.time()
    ref = np.linalg.cholesky(a)
    t_lapack = time.time() - t0
    x = jnp.asarray(a)
    jnp.linalg.cholesky(x).block_until_ready()
    t0 = time.time()
    jnp.linalg.cholesky(x).block_until_ready()
    t_xla = time.time() - t0
    out(f"[measured n={n}] LAPACK {flops/t_lapack/1e9:6.2f} GFlop/s   "
        f"XLA in-core {flops/t_xla/1e9:6.2f} GFlop/s")
    data = {"measured_n": n, "measured_gflops": {
        "lapack": flops / t_lapack / 1e9, "xla_incore": flops / t_xla / 1e9},
        "modeled_tflops": {}}
    for p in POLICIES:
        solver = repro.plan(n, tb=tb, policy=p).compile()
        solver.factor(a)                 # warm: builds schedule + jits once
        t0 = time.time()
        l = solver.factor(a)             # replay of the compiled executor
        dt = time.time() - t0
        err = np.abs(l - ref).max()
        data["measured_gflops"][p] = flops / dt / 1e9
        out(f"[measured n={n}] {p:6s} {flops/dt/1e9:6.2f} GFlop/s "
            f"(err {err:.1e})")

    # ---- modeled across sizes / platforms ----
    # 80 GB device memory (the paper's A100/H100/GH200 SKU) as the slot
    # budget; 160k matrices are genuinely out-of-core (205 GB > 80 GB).
    tb_m = 1024
    slots = int(80e9 / (8 * tb_m * tb_m))          # ~9500 tiles
    sizes = (64, 128, 160)
    plans = {}
    for nt in sizes:
        for p in POLICIES:
            plans[(nt, p)] = repro.plan(
                nt * tb_m, tb=tb_m, policy=p,
                cache_slots=min(slots, 2 * nt * nt))
    for hw_name in ("a100-pcie", "h100-pcie", "gh200", "tpu-v5e"):
        hw = HW[hw_name]
        out(f"[modeled {hw_name}] matrix-size sweep (80GB window), TFlop/s:")
        hdr = "   n\\policy " + "".join(f"{p:>9s}" for p in POLICIES)
        out(hdr)
        data["modeled_tflops"][hw_name] = {}
        for nt in sizes:
            vals = [plans[(nt, p)].simulate(hw).tflops for p in POLICIES]
            data["modeled_tflops"][hw_name][nt * tb_m] = dict(
                zip(POLICIES, vals))
            out(f"   {nt*tb_m:7d}  " + "".join(f"{v:9.1f}" for v in vals))
    out("")
    return data
