"""Fig. 6: single-device Cholesky throughput, policy ladder vs in-core.

Two views:
  * measured — wall-clock GFlop/s of the jit'd OOC executor vs XLA's
    in-core ``jnp.linalg.cholesky`` on this host (small N; CPU CI),
  * modeled  — the three-engine simulator on the paper's platforms and
    the TPU v5e target across matrix sizes (the Fig. 6 curves).
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.analytics import HW, simulate
from repro.core.cholesky import ooc_cholesky
from repro.core.schedule import build_schedule
from repro.core.tiling import random_spd

POLICIES = ["sync", "async", "v1", "v2", "v3"]


def run(out):
    out("== Fig. 6: single-device FP64 Cholesky, policy ladder ==")
    # ---- measured on this host ----
    n, tb = 768, 128
    a = random_spd(n, seed=0)
    flops = n ** 3 / 3
    t0 = time.time()
    ref = np.linalg.cholesky(a)
    t_lapack = time.time() - t0
    x = jnp.asarray(a)
    jnp.linalg.cholesky(x).block_until_ready()
    t0 = time.time()
    jnp.linalg.cholesky(x).block_until_ready()
    t_xla = time.time() - t0
    out(f"[measured n={n}] LAPACK {flops/t_lapack/1e9:6.2f} GFlop/s   "
        f"XLA in-core {flops/t_xla/1e9:6.2f} GFlop/s")
    for p in POLICIES:
        l, _ = ooc_cholesky(a, tb, policy=p, backend="jax")  # warm trace
        t0 = time.time()
        l, _ = ooc_cholesky(a, tb, policy=p, backend="jax")
        dt = time.time() - t0
        err = np.abs(l - ref).max()
        out(f"[measured n={n}] {p:6s} {flops/dt/1e9:6.2f} GFlop/s "
            f"(err {err:.1e})")

    # ---- modeled across sizes / platforms ----
    # 80 GB device memory (the paper's A100/H100/GH200 SKU) as the slot
    # budget; 160k matrices are genuinely out-of-core (205 GB > 80 GB).
    tb_m = 1024
    slots = int(80e9 / (8 * tb_m * tb_m))          # ~9500 tiles
    sizes = (64, 128, 160)
    scheds = {}
    for nt in sizes:
        for p in POLICIES:
            scheds[(nt, p)] = build_schedule(
                nt, tb_m, p, cache_slots=min(slots, 2 * nt * nt))
    for hw_name in ("a100-pcie", "h100-pcie", "gh200", "tpu-v5e"):
        hw = HW[hw_name]
        out(f"[modeled {hw_name}] matrix-size sweep (80GB window), TFlop/s:")
        hdr = "   n\\policy " + "".join(f"{p:>9s}" for p in POLICIES)
        out(hdr)
        for nt in sizes:
            vals = [simulate(scheds[(nt, p)], hw).tflops for p in POLICIES]
            out(f"   {nt*tb_m:7d}  " + "".join(f"{v:9.1f}" for v in vals))
    out("")
