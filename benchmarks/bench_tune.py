"""Tuner trajectory: autotuned schedule vs the hand-picked default on
every hardware preset, at a genuinely out-of-core size per machine.

For each preset the matrix is sized to overflow ``mem_bytes`` (the OOC
regime where the memory cap forces real policy/cache selection), the
search ranks every feasible ``(tb, policy, cache_slots)``, and the
winner is compared against :func:`repro.tune.default_config` — the V3 /
nt~32 / builder-default-slots configuration the benchmarks used before
the tuner existed.  The emitted speedup column is the number later perf
PRs (segment fusion, eager broadcast, 2D ownership) move.

A calibrated measured model of the CI host is exercised too (tiny tb so
it stays fast): same search path, ``source="measured"``.
"""
import repro
from repro import tune
from repro.core.analytics import HW

# the representative column step _measure_fused times: R=4 rows, K=2
# history columns -> 8 tile GEMMs + 1 POTRF + 3 TRSMs
_STEP_R, _STEP_K = 4, 2


def _fused_vs_unfused(model, tb: int) -> dict:
    """Per-class verdict of the calibrated model: would one fused
    column-step launch beat the same step dispatched op by op?

    Both sides use *this model's* measured rates; the unfused side also
    pays the measured launch overhead once per tile op (the dispatch
    cost the megakernel amortizes into a single launch)."""
    fused_rates = (model.kernel_flops or {}).get("fused_column", {})
    per_class = {}
    n_gemm = _STEP_R * _STEP_K
    n_trsm = _STEP_R - 1
    flops = {"gemm": 2.0 * tb**3, "trsm": float(tb**3),
             "potrf": tb**3 / 3.0}
    total = n_gemm * flops["gemm"] + flops["potrf"] + n_trsm * flops["trsm"]
    for cls_name, fr in fused_rates.items():
        t_fused = total / fr + model.launch_overhead
        t_unfused = (n_gemm * flops["gemm"] / model.task_rate("gemm", cls_name)
                     + flops["potrf"] / model.task_rate("potrf", cls_name)
                     + n_trsm * flops["trsm"] / model.task_rate("trsm",
                                                                cls_name)
                     + (n_gemm + n_trsm + 1) * model.launch_overhead)
        per_class[cls_name] = {
            "fused_s": t_fused, "unfused_s": t_unfused,
            "won": t_fused < t_unfused,
        }
    won = [v["won"] for v in per_class.values()]
    return {
        "tb": tb, "per_class": per_class,
        # headline: the fused path wins on this backend if it beats the
        # op-by-op dispatch for the majority of measured classes
        "fused_won": bool(won) and sum(won) * 2 >= len(won),
    }


def _ooc_n(mem_bytes: float) -> int:
    """Smallest power-of-two-ish n whose f64 matrix is ~2x device memory
    (power of two keeps the divisor grid rich for the tb search)."""
    n = 1 << 12
    while 8 * n * n < 2 * mem_bytes:
        n <<= 1
    return n


def run(out):
    out("== tune: autotuned schedule vs hand-picked default (OOC sizes) ==")
    rows = []
    for name, hw in HW.items():
        n = _ooc_n(hw.mem_bytes)
        result = tune.tune(n, hw=hw, use_db=False)
        dflt = tune.default_config(n)
        d = tune.score_config(n, dflt, hw)   # as the builders would run it
        b = result.best
        speedup = d.makespan / b.makespan
        rows.append({
            "hw": name, "n": n, "matrix_gb": 8 * n * n / 1e9,
            "mem_gb": hw.mem_bytes / 1e9,
            "tuned": b.row(), "default": d.row(),
            "speedup_vs_default": speedup,
        })
        c = b.config
        out(f"[{name:9s}] n={n} ({8*n*n/1e9:.0f} GB vs {hw.mem_bytes/1e9:.0f}"
            f" GB device): tuned tb={c.tb} {c.policy} slots={c.cache_slots}"
            f" -> {b.makespan:.2f}s ({b.tflops:.1f} TF/s)   default"
            f" tb={dflt.tb} v3 -> {d.makespan:.2f}s   speedup {speedup:.3f}x")
        assert b.makespan <= d.makespan * (1 + 1e-9), \
            f"tuned config slower than default on {name}"
        assert tune.is_feasible(n, c, hw)

    # the calibrated path: measured model of this host drives the same
    # search (CPU CI smoke — tiny tb keeps the micro-benchmarks fast)
    model = tune.calibrate(tb=64, repeats=1, transfer_sizes_mb=(1, 4))
    n = _ooc_n(model.mem_bytes)
    result = tune.tune(n, hw=model, use_db=False)
    b = result.best
    fused = _fused_vs_unfused(model, tb=64)
    out(f"[measured ] {model.name} (fp={model.fingerprint}, "
        f"{model.mem_bytes/1e9:.0f} GB): n={n} tuned tb={b.config.tb} "
        f"{b.config.policy} slots={b.config.cache_slots} -> "
        f"{b.makespan:.2f}s   fused megakernel "
        f"{'wins' if fused['fused_won'] else 'loses'} on "
        f"{sum(v['won'] for v in fused['per_class'].values())}/"
        f"{len(fused['per_class'])} classes")
    out("")
    return {
        "presets": rows,
        "measured": {
            "hw_name": model.name,
            "fingerprint": model.fingerprint,
            "source": model.source,
            "mem_gb": model.mem_bytes / 1e9,
            "n": n,
            "tuned": b.row(),
            "fused_vs_unfused": fused,
        },
    }
