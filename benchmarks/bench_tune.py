"""Tuner trajectory: autotuned schedule vs the hand-picked default on
every hardware preset, at a genuinely out-of-core size per machine.

For each preset the matrix is sized to overflow ``mem_bytes`` (the OOC
regime where the memory cap forces real policy/cache selection), the
search ranks every feasible ``(tb, policy, cache_slots)``, and the
winner is compared against :func:`repro.tune.default_config` — the V3 /
nt~32 / builder-default-slots configuration the benchmarks used before
the tuner existed.  The emitted speedup column is the number later perf
PRs (segment fusion, eager broadcast, 2D ownership) move.

A calibrated measured model of the CI host is exercised too (tiny tb so
it stays fast): same search path, ``source="measured"``.
"""
import repro
from repro import tune
from repro.core.analytics import HW


def _ooc_n(mem_bytes: float) -> int:
    """Smallest power-of-two-ish n whose f64 matrix is ~2x device memory
    (power of two keeps the divisor grid rich for the tb search)."""
    n = 1 << 12
    while 8 * n * n < 2 * mem_bytes:
        n <<= 1
    return n


def run(out):
    out("== tune: autotuned schedule vs hand-picked default (OOC sizes) ==")
    rows = []
    for name, hw in HW.items():
        n = _ooc_n(hw.mem_bytes)
        result = tune.tune(n, hw=hw, use_db=False)
        dflt = tune.default_config(n)
        d = tune.score_config(n, dflt, hw)   # as the builders would run it
        b = result.best
        speedup = d.makespan / b.makespan
        rows.append({
            "hw": name, "n": n, "matrix_gb": 8 * n * n / 1e9,
            "mem_gb": hw.mem_bytes / 1e9,
            "tuned": b.row(), "default": d.row(),
            "speedup_vs_default": speedup,
        })
        c = b.config
        out(f"[{name:9s}] n={n} ({8*n*n/1e9:.0f} GB vs {hw.mem_bytes/1e9:.0f}"
            f" GB device): tuned tb={c.tb} {c.policy} slots={c.cache_slots}"
            f" -> {b.makespan:.2f}s ({b.tflops:.1f} TF/s)   default"
            f" tb={dflt.tb} v3 -> {d.makespan:.2f}s   speedup {speedup:.3f}x")
        assert b.makespan <= d.makespan * (1 + 1e-9), \
            f"tuned config slower than default on {name}"
        assert tune.is_feasible(n, c, hw)

    # the calibrated path: measured model of this host drives the same
    # search (CPU CI smoke — tiny tb keeps the micro-benchmarks fast)
    model = tune.calibrate(tb=64, repeats=1, transfer_sizes_mb=(1, 4))
    n = _ooc_n(model.mem_bytes)
    result = tune.tune(n, hw=model, use_db=False)
    b = result.best
    out(f"[measured ] {model.name} (fp={model.fingerprint}, "
        f"{model.mem_bytes/1e9:.0f} GB): n={n} tuned tb={b.config.tb} "
        f"{b.config.policy} slots={b.config.cache_slots} -> "
        f"{b.makespan:.2f}s")
    out("")
    return {
        "presets": rows,
        "measured": {
            "hw_name": model.name,
            "fingerprint": model.fingerprint,
            "source": model.source,
            "mem_gb": model.mem_bytes / 1e9,
            "n": n,
            "tuned": b.row(),
        },
    }
