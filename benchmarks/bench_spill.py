"""Disk tier cost: spill overhead, host-budget sweep, restart tax.

Three headline numbers for the third memory tier:

* **Spill overhead** — wall-clock of the same factorization host-resident
  vs through a tmpdir :class:`repro.DiskTileStore` at a tight
  ``host_slots`` budget, with the executed FETCH/SPILL byte volumes
  (crosschecked against the schedule — the static-stream contract).
* **Budget sweep** — disk traffic as a function of ``host_slots``: more
  slabs, fewer evictions; the knee is what ``tune.search`` finds when
  host memory forces the tier on.
* **Restart tax** — kill a run mid-stream, resume from the checkpoint,
  and report the resumed fraction replayed; asserts the resumed factor
  is bit-identical to the uninterrupted one.

Emits ``benchmarks/out/BENCH_spill.json`` via ``benchmarks.run spill``.
"""
import tempfile
import time

import numpy as np

import repro
from repro import CheckpointManager, DiskTileStore, RestartableFactorization
from repro.core.cholesky import run_schedule_numpy, run_schedule_spill
from repro.core.tiling import random_spd, to_tiles

N = 384          # nt=12 at tb=32: 144 tiles against an 8-slab host tier
TB = 32
HOST_SLOTS = 8
POLICY = "v3"


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def run(out):
    nt = N // TB
    a = random_spd(N, seed=0)
    tiles = to_tiles(a, TB)
    plain = repro.build_schedule(nt, TB, POLICY)
    sp = repro.build_schedule(nt, TB, POLICY, host_slots=HOST_SLOTS)

    ref, t_plain = _timed(lambda: run_schedule_numpy(tiles, plain))
    with tempfile.TemporaryDirectory() as d:
        store = DiskTileStore.from_matrix(d + "/a.npy", a, TB)
        host, t_spill = _timed(lambda: run_schedule_spill(store, sp))
        assert np.array_equal(store.to_tiles(), ref)        # pure bookkeeping
        assert host.fetched_bytes == sp.fetch_bytes()
        assert host.spilled_bytes == sp.spill_bytes()
    out(f"n={N} tb={TB} host_slots={HOST_SLOTS}: "
        f"host-resident {t_plain:.3f}s, disk tier {t_spill:.3f}s "
        f"({t_spill / t_plain:.2f}x), fetched {host.fetched_bytes >> 20} MiB, "
        f"spilled {host.spilled_bytes >> 20} MiB")

    sweep = {}
    for hs in (nt + 2, 2 * nt, 4 * nt):
        s = repro.build_schedule(nt, TB, POLICY, host_slots=hs)
        sweep[hs] = {"fetch_bytes": s.fetch_bytes(),
                     "spill_bytes": s.spill_bytes()}
        out(f"  host_slots={hs:3d}: fetch {s.fetch_bytes() >> 20} MiB, "
            f"spill {s.spill_bytes() >> 20} MiB")
    hs_list = sorted(sweep)
    assert sweep[hs_list[0]]["fetch_bytes"] >= sweep[hs_list[-1]]["fetch_bytes"]

    with tempfile.TemporaryDirectory() as d:
        store = DiskTileStore.from_matrix(d + "/a.npy", a, TB)
        rf = RestartableFactorization(
            sp, store, CheckpointManager(d + "/ckpt", keep=2))
        kill_at = int(0.6 * len(sp.ops))
        assert rf.run(stop_after_ops=kill_at) is False
        del rf, store
        store2 = DiskTileStore.open(d + "/a.npy")
        rf2 = RestartableFactorization(
            sp, store2, CheckpointManager(d + "/ckpt", keep=2))
        _, t_resume = _timed(rf2.run)
        assert np.array_equal(rf2.result_tiles(), ref)      # bit-identical
    out(f"  kill at op {kill_at}/{len(sp.ops)}, resume {t_resume:.3f}s, "
        f"factor bit-identical to uninterrupted run")

    return {
        "n": N, "tb": TB, "host_slots": HOST_SLOTS, "policy": POLICY,
        "t_host_resident": round(t_plain, 4),
        "t_disk_tier": round(t_spill, 4),
        "overhead_x": round(t_spill / t_plain, 3),
        "fetch_bytes": host.fetched_bytes,
        "spill_bytes": host.spilled_bytes,
        "budget_sweep": {str(k): v for k, v in sweep.items()},
        "resume": {"kill_at_op": kill_at, "total_ops": len(sp.ops),
                   "t_resume": round(t_resume, 4), "bit_identical": True},
    }
