"""Fig. 11: MxP factorization throughput vs accuracy threshold.

Two parts:
  * plan fidelity — per-tile precision histograms from REAL Matern
    covariances (n=2048) at the paper's three correlation levels;
  * performance — modeled GH200 / TPU v5e throughput at paper scale
    (65k x 65k, tile 1024) using decay-matched synthetic norm fields
    (the full covariance at 65k is 34 GB — tile norms are what the
    criterion consumes, and they decay exponentially with block
    distance for Morton-ordered exponential kernels).

Headline: weak-correlation MxP >= 2.5x over FP64-only on GH200
(paper: ~3x).
"""
import numpy as np

import repro
from repro.core.analytics import HW
from repro.core.precision import assign_precision
from repro.core.tiling import to_tiles
from repro.geo.matern import (BETA_MEDIUM, BETA_STRONG, BETA_WEAK,
                              generate_locations, matern_covariance)

# block-distance decay of tile norms per correlation regime (matched to
# the real-Matern histograms printed alongside)
REGIMES = [("weak", BETA_WEAK, 1e-3), ("medium", BETA_MEDIUM, 1e-2),
           ("strong", BETA_STRONG, 2e-1)]


def _decay_plan(nt, decay, eps, seed=0):
    rng = np.random.default_rng(seed)
    norms = np.abs(rng.standard_normal((nt, nt))) + 0.5
    for j in range(nt):
        for i in range(j, nt):
            norms[i, j] *= decay ** min(abs(i - j), 6)
    norms[np.diag_indices(nt)] = 10.0
    return assign_precision(norms, float(np.sqrt((norms ** 2).sum())), eps)


def run(out):
    out("== Fig. 11: MxP performance vs accuracy (modeled) ==")
    # ---- plan fidelity on real Matern (n=2048, tb=256) ----
    locs = generate_locations(2048, seed=2)
    for name, beta, _ in REGIMES:
        cov = matern_covariance(locs, beta=beta)
        tiles = to_tiles(cov, 256)
        hists = []
        for eps in (1e-5, 1e-8):
            p = repro.plan_for_matrix(tiles, eps)
            hists.append(f"eps={eps:.0e} "
                         f"{ {k: v for k, v in p.histogram().items() if v} }")
        out(f"[real matern n=2048] {name:7s}: " + " | ".join(hists))

    # ---- performance at paper scale (65k, tile 1024) ----
    nt, tb = 64, 1024
    n = nt * tb
    flops = n ** 3 / 3
    f64 = repro.plan(n, tb=tb, policy="v3")
    speedup_weak = None
    for name, beta, decay in REGIMES:
        out(f"correlation {name} (decay-matched plan):")
        for hw_name in ("gh200", "tpu-v5e"):
            hw = HW[hw_name]
            t64 = f64.simulate(hw).makespan
            cells = [f"fp64 {flops/t64/1e12:6.1f} TF/s"]
            for eps in (1e-5, 1e-6, 1e-8):
                cfg = repro.CholeskyConfig(tb=tb, policy="v3",
                                           plan=_decay_plan(nt, decay, eps))
                t = repro.plan(n, cfg).simulate(hw).makespan
                cells.append(f"eps={eps:.0e} {flops/t/1e12:6.1f} TF/s "
                             f"({t64/t:4.2f}x)")
                if (name, hw_name, eps) == ("weak", "gh200", 1e-5):
                    speedup_weak = t64 / t
            out(f"  {hw_name:8s} " + " | ".join(cells))
    assert speedup_weak is not None and speedup_weak > 2.5, \
        f"MxP speedup {speedup_weak} too small vs paper's ~3x"
    out(f"headline: weak-correlation GH200 MxP speedup "
        f"{speedup_weak:.2f}x (paper: ~3x; the event model books no "
        f"up/down-cast overhead and perfect overlap, so it upper-bounds "
        f"the paper's measured 3x — the strong/1e-8 cell reproducing "
        f"1.00x matches the paper's regression observation)")
    out("")
