from .sharding import (LOGICAL_RULES, partition_spec, params_shardings,
                       batch_spec)

__all__ = ["LOGICAL_RULES", "partition_spec", "params_shardings", "batch_spec"]
