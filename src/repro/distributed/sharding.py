"""Logical-axis sharding rules (MaxText-style).

Model code annotates every parameter dimension with a *logical* axis name
(see models/layers.py); this module maps logical names to mesh axes.  One
rule table serves every architecture; configs may override entries.

Default mapping on the production mesh ("pod", "data", "model"):

  embed  -> "data"    FSDP: parameters/optimizer state sharded over DP ranks
  vocab  -> "model"   TP: embedding + logits sharded over tensor ranks
  heads  -> "model"   TP over attention heads
  kv     -> "model"   TP over kv heads (falls back to replicated if indivisible)
  mlp    -> "model"   TP over FFN hidden
  inner  -> "model"   TP over SSM inner dim
  expert -> "model"   EP: experts over tensor ranks
  lora   -> None      MLA compressed streams are small; replicate
  stack  -> None      scan axis, never sharded

The "pod" axis extends data parallelism across pods (DP hierarchy:
gradient all-reduce inside a pod first, then across pods over DCN).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES: dict = {
    "embed": "data",
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "inner": "model",
    "expert": "model",
    "lora": None,
    "conv": None,
    "stack": None,
    None: None,
}


def _mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def partition_spec(axes: tuple, shape: tuple, mesh: Mesh,
                   rules: dict | None = None) -> P:
    """Map one parameter's logical axes -> PartitionSpec, dropping any mesh
    axis that does not divide the corresponding dimension (e.g. kv=1 heads
    on a 16-way tensor mesh -> replicated)."""
    rules = rules or LOGICAL_RULES
    sizes = _mesh_axis_sizes(mesh)
    used = set()
    out = []
    for ax_name, dim in zip(axes, shape):
        mesh_ax = rules.get(ax_name)
        if mesh_ax is None or mesh_ax in used or mesh_ax not in sizes:
            out.append(None)
            continue
        if dim % sizes[mesh_ax] != 0:
            out.append(None)
            continue
        out.append(mesh_ax)
        used.add(mesh_ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def params_shardings(axes_tree, params_tree, mesh: Mesh,
                     rules: dict | None = None):
    """NamedSharding tree matching a params tree."""
    def one(ax, p):
        spec = partition_spec(tuple(ax), p.shape, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, params_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_spec(mesh: Mesh, seq_sharded: bool = False) -> P:
    """Spec for [batch, seq, ...] activations: batch over DP axes (pod+data);
    optionally shard the sequence dim over "data" (SP, long-context)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp[0] if len(dp) == 1 else dp
    if seq_sharded:
        return P(None, "data")
    return P(dp)


# ---------------------------------------------------------------------------
# Activation sharding constraints (trace-time, context-scoped)
#
# XLA's sharding propagation can replicate large intermediates (e.g. the
# [B,S,V] logits) when the forward graph gives it freedom; these explicit
# anchors pin the standard layout: batch over DP, vocab/experts over
# "model".  Model code calls ``shard_act`` unconditionally; outside an
# ``activation_sharding`` context (tests, CPU CI) it is the identity.

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


def residual_barrier(x):
    """Optional bf16 pin on the residual stream.

    XLA (CPU pipeline, at least) hoists the bf16->f32 convert feeding the
    next rms_norm ABOVE the tensor-parallel all-reduce of the block
    output, doubling every TP collective.  An optimization barrier after
    the residual add keeps the all-reduce in bf16.  Enabled via
    activation_sharding(bf16_all_reduce=True).
    """
    ctx = _ACT_CTX.get()
    if ctx is None or not ctx.get("bf16_ar"):
        return x
    return jax.lax.optimization_barrier(x)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, seq_sharded: bool = False,
                        attn_seq_parallel: bool = False,
                        residual_seq_parallel: bool = False,
                        bf16_all_reduce: bool = False):
    """attn_seq_parallel: shard the *query sequence* of attention over the
    "model" axis (context parallelism).  Rescues tensor parallelism when
    the head count does not divide the TP degree (qwen3 40H, llava 56H,
    gemma3 4H on a 16-way axis): without it attention replicates 16x.

    residual_seq_parallel: Megatron-style SP — the residual stream
    [B,S,D] is sharded (DP, "model", -) between blocks, so the remat
    stack and norm traffic shrink by the TP degree and the TP pair
    all-reduces become reduce-scatter + all-gather."""
    tok = _ACT_CTX.set({"mesh": mesh, "seq": seq_sharded,
                        "attn_sp": attn_seq_parallel,
                        "sp": residual_seq_parallel,
                        "bf16_ar": bf16_all_reduce})
    try:
        yield
    finally:
        _ACT_CTX.reset(tok)


def _div(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return False
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        if a not in mesh.axis_names:
            return False
        size *= mesh.shape[a]
    return dim % size == 0


def moe_group_count(tokens: int) -> int:
    """Number of dispatch groups for the grouped MoE: one per "data" rank
    (each group's sort/capacity/scatter is then shard-local — without
    this the global argsort forces XLA to all-reduce the full [E,C,d]
    buffer per layer).  1 outside a mesh context / when indivisible.
    REPRO_MOE_GROUPS=1 forces the paper-baseline global dispatch."""
    import os
    forced = os.environ.get("REPRO_MOE_GROUPS")
    if forced:
        g = int(forced)
        return g if tokens % g == 0 else 1
    ctx = _ACT_CTX.get()
    if ctx is None:
        return 1
    g = ctx["mesh"].shape.get("data", 1)
    return g if tokens % g == 0 else 1


def shard_act(x, kind: str):
    """Constraint for a standard activation layout; identity outside ctx.

    kinds: "hidden" [B,S,D] - batch over DP (seq over "data" if seq_sharded)
           "logits" [B,S,V] - batch over DP, vocab over "model"
           "moe"    [E,C,D] - experts over "model" (EP), capacity over "data"
           "moe_tokens"/"moe_buf" - grouped dispatch (see moe_group_count)
           "attn_q" [B,S,H,hd] - context-parallel queries (opt-in)
    """
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh: Mesh = ctx["mesh"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp[0] if len(dp) == 1 else dp
    if kind == "hidden":
        if ctx["seq"] and _div(x.shape[1], mesh, "data"):
            spec = P("pod" if _div(x.shape[0], mesh, "pod") else None,
                     "data", None)
        elif ctx.get("sp") and _div(x.shape[1], mesh, "model"):
            spec = P(dp if _div(x.shape[0], mesh, dp) else None,
                     "model", None)
        else:
            spec = P(dp if _div(x.shape[0], mesh, dp) else None, None, None)
    elif kind == "logits":
        spec = P(dp if _div(x.shape[0], mesh, dp) else None, None,
                 "model" if _div(x.shape[-1], mesh, "model") else None)
    elif kind == "moe":
        spec = P("model" if _div(x.shape[0], mesh, "model") else None,
                 "data" if _div(x.shape[1], mesh, "data") else None, None)
    elif kind == "moe_tokens":       # [G, T_local, d] grouped token stream
        spec = P("data" if _div(x.shape[0], mesh, "data") else None,
                 None, None)
    elif kind == "moe_buf":          # [G, E, C, d] grouped expert buffer
        spec = P("data" if _div(x.shape[0], mesh, "data") else None,
                 "model" if _div(x.shape[1], mesh, "model") else None,
                 None, None)
    elif kind == "attn_q":
        # [B, S, H, hd] query block: batch over DP, seq over "model" (SP)
        if not ctx.get("attn_sp") or not _div(x.shape[1], mesh, "model"):
            return x
        spec = P(dp if _div(x.shape[0], mesh, dp) else None, "model",
                 None, None)
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def cache_spec(mesh: Mesh, batch: int, seq_sharded: bool) -> P:
    """KV-cache spec: [B, S, kv, hd]. decode_32k shards batch over DP;
    long_500k (B=1) shards the sequence over "data" instead (flash-decode
    style merged partial attention is inserted by SPMD)."""
    if seq_sharded:
        return P(None, "data", "model")
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = dp[0] if len(dp) == 1 else dp
    return P(dp, None, "model")
