"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices back the production meshes; ``.lower().compile()``
must succeed and yields ``memory_analysis()`` / ``cost_analysis()`` plus
the collective schedule for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all          # every cell, subprocess each
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# isort: split

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, shapes_for
from repro.distributed.sharding import activation_sharding
from repro.launch import hlo as hlo_mod
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _sharded_bytes(tree, shardings) -> int:
    """Analytic per-device bytes of a sharded pytree."""
    total = 0
    for leaf, sh in zip(
            jax.tree.leaves(tree),
            jax.tree.leaves(shardings,
                            is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = leaf.dtype.itemsize
        for d in leaf.shape:
            n *= d
        spec = sh.spec
        div = 1
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            for a in axes:
                div *= sh.mesh.shape[a]
        total += n // div
    return total


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               accum_steps: int = 1, opts: dict | None = None):
    """Build + lower + compile one cell; return the result record."""
    opts = opts or {}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic():
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped",
                "reason": "full-attention arch; long_500k needs sub-quadratic"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()

    quantized_opt = bool(opts.get("q8opt", False))
    params_abs, p_sh, opt_abs, opt_sh = S.train_state_shardings(
        cfg, mesh, quantized_opt=quantized_opt)
    batch_abs = S.input_specs(cfg, shape)
    batch_sh = S.batch_shardings(cfg, shape, mesh)
    rep = NamedSharding(mesh, P())

    seq_sharded_acts = bool(opts.get("seq_sharded", shape.name == "long_500k"))
    # context-parallel attention by default when the head count does not
    # divide the TP degree (otherwise attention replicates TP-fold);
    # §Perf iteration 1 on qwen3 — measured 8.4x
    auto_attn_sp = (cfg.num_heads % mesh.shape["model"] != 0
                    and shape.kind != "decode")
    with mesh, activation_sharding(
            mesh, seq_sharded=seq_sharded_acts,
            attn_seq_parallel=bool(opts.get("attn_sp", auto_attn_sp)),
            residual_seq_parallel=bool(opts.get("sp", False)),
            bf16_all_reduce=bool(opts.get("bf16_ar", False))):
        if shape.kind == "train":
            step = make_train_step(cfg, accum_steps=accum_steps,
                                   quantized_opt=quantized_opt)
            metrics_sh = {"loss": rep, "grad_norm": rep}
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, opt_sh, batch_sh),
                out_shardings=(p_sh, opt_sh, metrics_sh),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            state_bytes = (_sharded_bytes(params_abs, p_sh)
                           + _sharded_bytes(opt_abs.m, p_sh)
                           + _sharded_bytes(opt_abs.v, p_sh))
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            out_sh = NamedSharding(
                mesh, P(None,
                        "model" if cfg.padded_vocab % mesh.shape["model"] == 0
                        else None))
            jitted = jax.jit(step, in_shardings=(p_sh, batch_sh),
                             out_shardings=out_sh)
            lowered = jitted.lower(params_abs, batch_abs)
            state_bytes = _sharded_bytes(params_abs, p_sh)
        else:  # decode
            seq_sharded = shape.name == "long_500k"
            cache_abs = S.abstract_cache(cfg, shape.global_batch,
                                         shape.seq_len,
                                         jnp.dtype(cfg.dtype))
            cache_sh = S.cache_shardings(cfg, cache_abs, mesh,
                                         seq_sharded=seq_sharded)
            logits_sh = S.logits_sharding(cfg, shape.global_batch, mesh)
            serve = make_serve_step(cfg)
            args = [params_abs, cache_abs, batch_abs["token"],
                    batch_abs["pos"]]
            in_sh = [p_sh, cache_sh, batch_sh["token"], batch_sh["pos"]]
            if cfg.is_encdec:
                args.append(batch_abs["enc_out"])
                in_sh.append(batch_sh["enc_out"])
                fn = lambda p, c, t, pos, enc: serve(p, c, t, pos, enc_out=enc)
            else:
                fn = lambda p, c, t, pos: serve(p, c, t, pos)
            jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                             out_shardings=(logits_sh, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(*args)
            state_bytes = (_sharded_bytes(params_abs, p_sh)
                           + _sharded_bytes(cache_abs, cache_sh))

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # ---- analysis ----
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(ma, k)
        }
    except Exception:
        pass

    text = compiled.as_text()
    hlo_stats = hlo_mod.analyze(text)
    coll = hlo_stats["collectives"]

    total, active = cfg.param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        model_flops = 6 * active * tokens
    elif shape.kind == "prefill":
        model_flops = 2 * active * tokens
    else:
        model_flops = 2 * active * shape.global_batch

    roof = hlo_mod.roofline_terms(
        flops=hlo_stats["flops"],
        hbm_bytes=hlo_stats["hbm_bytes"],
        coll=coll, chips=chips, model_flops=model_flops)

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops": hlo_stats["flops"],
        "hlo_hbm_bytes": hlo_stats["hbm_bytes"],
        "cost_analysis": cost,
        "memory_analysis": mem,
        "collectives": coll,
        "roofline": roof,
        "state_bytes_per_device": state_bytes,
        "params_total": total, "params_active": active,
        "accum_steps": accum_steps,
        "opts": opts,
    }


def run_cell(arch, shape_name, mesh_kind, out_dir, accum_steps=1,
             opts=None, tag=""):
    rec = lower_cell(arch, shape_name, mesh_kind == "multi",
                     accum_steps=accum_steps, opts=opts)
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir,
                        f"{arch}__{shape_name}__{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def run_all(out_dir, meshes=("single", "multi"), timeout=3600,
            only_missing=False):
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name in SHAPES:
            for mesh_kind in meshes:
                cells.append((arch, shape_name, mesh_kind))
    results = []
    for arch, shape_name, mesh_kind in cells:
        path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
        if only_missing and os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            results.append(rec)
            print(f"[cached] {arch} {shape_name} {mesh_kind}: {rec['status']}")
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape_name, "--mesh", mesh_kind,
               "--out", out_dir]
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=timeout)
            ok = proc.returncode == 0
            err = proc.stderr[-2000:] if not ok else ""
        except subprocess.TimeoutExpired:
            ok, err = False, "timeout"
        if ok and os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
        else:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "status": "failed", "error": err}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
        results.append(rec)
        print(f"[{time.time()-t0:6.1f}s] {arch} {shape_name} {mesh_kind}: "
              f"{rec['status']}")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed, "
          f"of {len(results)} cells ==")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--attn-sp", action="store_true",
                    help="context-parallel attention (queries over 'model')")
    ap.add_argument("--sp", action="store_true",
                    help="Megatron-style sequence-parallel residual stream")
    ap.add_argument("--bf16-ar", action="store_true",
                    help="pin residual to bf16 (TP all-reduces in bf16)")
    ap.add_argument("--q8opt", action="store_true",
                    help="int8 (block-scaled) optimizer moments")
    ap.add_argument("--tag", default="",
                    help="suffix for the output JSON (perf iterations)")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()
    if args.all:
        results = run_all(args.out, only_missing=args.only_missing)
        sys.exit(1 if any(r["status"] == "failed" for r in results) else 0)
    opts = {}
    if args.attn_sp:
        opts["attn_sp"] = True
    if args.sp:
        opts["sp"] = True
    if args.bf16_ar:
        opts["bf16_ar"] = True
    if args.q8opt:
        opts["q8opt"] = True
    rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                   accum_steps=args.accum_steps, opts=opts, tag=args.tag)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("cost_analysis",)}, indent=1)[:4000])
    if rec["status"] == "ok":
        print("memory_analysis:", rec["memory_analysis"])
        print("cost flops: %.3e  bytes: %.3e" % (
            rec["cost_analysis"].get("flops", 0),
            rec["cost_analysis"].get("bytes accessed", 0)))
    sys.exit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
