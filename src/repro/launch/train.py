"""Training driver: data pipeline -> pjit train step -> checkpoints.

Runs anywhere: on the CPU CI it trains reduced configs on a 1-device mesh;
on a pod the same code path shards over ("data", "model").  Fault
tolerance: atomic checkpoints every ``save_every`` steps, SIGTERM installs
a checkpoint-now request, restart resumes params + optimizer + data
stream position.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --smoke \
      --steps 200 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataPipeline
from repro.distributed.sharding import activation_sharding, params_shardings
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.optim.adamw import adamw_init


def train(arch: str = "qwen3-14b", smoke: bool = True, steps: int = 100,
          batch: int = 8, seq: int = 64, lr: float = 1e-3,
          ckpt_dir: str | None = None, save_every: int = 50,
          mesh=None, quantized_opt: bool = False, accum_steps: int = 1,
          log_every: int = 10, seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    if mesh is None:
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))

    pipe = DataPipeline(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                        seed=seed)
    params, axes = T.init_model(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params, quantize=quantized_opt)

    p_sh = params_shardings(axes, params, mesh)
    rep = NamedSharding(mesh, P())
    opt_sh = (jax.tree.map(lambda _: rep, opt) if quantized_opt
              else type(opt)(step=rep, m=p_sh, v=p_sh))
    b_sh = {"tokens": NamedSharding(mesh, P("data", None)),
            "labels": NamedSharding(mesh, P("data", None))}

    mgr = None
    start_step = 0
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3)
        mgr.save_on_signal()
        latest = mgr.latest_step()
        if latest is not None:
            (params, opt), extra = mgr.restore((params, opt))
            params = jax.tree.map(jnp.asarray, params)
            opt = jax.tree.map(jnp.asarray, opt)
            start_step = int(extra["step"]) if extra else latest
            pipe.seek(start_step)
            print(f"[train] resumed from step {start_step}")

    step_fn = make_train_step(cfg, lr=lr, accum_steps=accum_steps,
                              quantized_opt=quantized_opt)
    losses = []
    with mesh, activation_sharding(mesh):
        jitted = jax.jit(step_fn, in_shardings=(p_sh, opt_sh, b_sh),
                         out_shardings=(p_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        t0 = time.time()
        for i in range(start_step, steps):
            batch_np = next(pipe)
            dev_batch = {
                "tokens": jnp.asarray(batch_np["tokens"]),
                "labels": jnp.asarray(batch_np["labels"]),
            }
            params, opt, metrics = jitted(params, opt, dev_batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if i % log_every == 0 or i == steps - 1:
                dt = time.time() - t0
                print(f"[train] step {i:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"{dt:.1f}s", flush=True)
            if mgr and (i % save_every == save_every - 1
                        or mgr.should_save_now):
                mgr.save(i + 1, (params, opt),
                         extra={"step": i + 1,
                                "pipeline": pipe.state.to_dict()})
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (pod-scale!)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--quantized-opt", action="store_true")
    args = ap.parse_args()
    _, losses = train(arch=args.arch, smoke=not args.full, steps=args.steps,
                      batch=args.batch, seq=args.seq, lr=args.lr,
                      ckpt_dir=args.ckpt_dir, save_every=args.save_every,
                      accum_steps=args.accum_steps,
                      quantized_opt=args.quantized_opt)
    print(f"[train] done: first loss {losses[0]:.4f} -> "
          f"last loss {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
