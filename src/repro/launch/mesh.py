"""Production mesh construction.

The target is a TPU v5e pod: 16x16 = 256 chips single-pod, and a 2-pod
512-chip job with a leading "pod" axis (DCN data parallelism across pods,
ICI inside a pod).  Defined as a FUNCTION so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}, have {len(devices)}; "
            "the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return jax.make_mesh(shape, axes, devices=devices)


def make_smoke_mesh(shape=(1,), axes=("data",)) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU CI)."""
    import numpy as np
    devs = np.array(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axes)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that carry data parallelism ("pod" spans pods over DCN)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
