"""Step functions: train (CE loss + AdamW), prefill, decode.

Factories close over the static config; the returned functions are pure
pytree->pytree maps suitable for ``jax.jit(...).lower().compile()`` (the
dry-run) and for real execution (examples/train_lm.py).

Gradient accumulation: ``accum_steps > 1`` scans over microbatches with
f32 grad accumulators — the standard memory/throughput knob at scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.adamw import adamw_update


def _model_kwargs(cfg: ModelConfig, batch: dict) -> dict:
    kw = {}
    if "frontend_embeds" in batch:
        kw["frontend_embeds"] = batch["frontend_embeds"]
    if "enc_embeds" in batch:
        kw["enc_embeds"] = batch["enc_embeds"]
    return kw


def loss_fn(params, cfg: ModelConfig, batch: dict):
    """Mean next-token cross-entropy (f32 softmax over the sharded vocab)."""
    h = T.forward(params, cfg, batch["tokens"], **_model_kwargs(cfg, batch))
    logits = T.logits_from_hidden(params, cfg, h).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
    return -jnp.mean(ll)


def make_train_step(cfg: ModelConfig, lr: float = 3e-4,
                    weight_decay: float = 0.01, accum_steps: int = 1,
                    quantized_opt: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, cfg, batch)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                l, g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / accum_steps,
                    acc, g)
                return acc, l

            grads, losses = jax.lax.scan(body, zeros, micro)
            loss = jnp.mean(losses)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        params, opt_state = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay,
            quantize=quantized_opt)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> last-position logits [B, vocab]."""

    def prefill_step(params, batch):
        h = T.forward(params, cfg, batch["tokens"],
                      **_model_kwargs(cfg, batch))
        return T.logits_from_hidden(params, cfg, h[:, -1:, :])[:, 0]

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """(params, cache, token, pos[, enc_out]) -> (logits, new_cache).

    One decode step: appends the token's KV at ``pos`` and attends over the
    seq_len-long cache (the decode_32k / long_500k cells).
    """

    def serve_step(params, cache, token, pos, enc_out=None):
        logits, cache = T.decode_step(params, cfg, token, cache, pos,
                                      enc_out=enc_out)
        return logits, cache

    return serve_step
