"""Post-SPMD HLO analysis: scan-aware FLOP / byte / collective accounting.

``compiled.cost_analysis()`` reports ONE iteration of every ``while`` loop
(scanned layer stacks, chunked attention), so it can undercount by the
layer count.  This module parses the optimized (partitioned) HLO text
instead and walks the call graph with loop-trip multipliers (XLA annotates
``known_trip_count`` in ``backend_config``):

  * flops       — 2 * prod(result) * prod(contracted lhs dims) per dot,
                  multiplied along the call chain (fusion bodies included).
  * hbm bytes   — per *kernel boundary* op (fusion internals excluded):
                  operands read + result written.
  * collectives — result-buffer bytes per kind, trip-multiplied; wire
                  bytes via ring formulas.

Shapes in a partitioned module are per-device, so all numbers are
per-chip.  Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12       # bf16 MXU, per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "c64": 8, "c128": 16, "u4": 1, "s4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

# ops that don't represent real HBM traffic at a kernel boundary
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional", "call", "get-dimension-size", "reshape",
    "optimization-barrier", "rng-bit-generator", "rng",
}


def _bytes_of_type(s: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of_type(s: str) -> list[int]:
    m = _TYPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    result_type: str
    opcode: str
    operands: list
    line: str
    is_root: bool = False


@dataclasses.dataclass
class _Comp:
    name: str
    ops: list
    symbols: dict            # op name -> result type string


def _balanced(s: str, start: int = 0) -> int:
    """Index just past the paren group opening at ``start`` ('(' there)."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_op_line(line: str):
    s = line.strip()
    is_root = s.startswith("ROOT ")
    if is_root:
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):           # tuple result type (may contain
        end = _balanced(rest)          # /*index=k*/ comments)
        rtype, rest2 = rest[:end], rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        rtype, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    mo = _OPCODE_RE.match(rest2)
    if not mo:
        return None
    opcode = mo.group(1)
    ostart = rest2.find("(")
    oend = _balanced(rest2, ostart)
    operands = _OPERAND_RE.findall(rest2[ostart:oend])
    return _Op(name, rtype, opcode, operands, line, is_root)


def parse_hlo(text: str) -> dict:
    """text -> {comp_name: _Comp}; the computation named ENTRY is entry."""
    comps: dict[str, _Comp] = {}
    entry = None
    cur: _Comp | None = None
    for line in text.splitlines():
        if cur is None or not line.startswith("  "):
            mh = _COMP_RE.match(line)
            if mh and ("->" in line):
                cur = _Comp(mh.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        op = _parse_op_line(line)
        if op is None:
            continue
        cur.ops.append(op)
        cur.symbols[op.name] = op.result_type
    return {"comps": comps, "entry": entry}


def _call_multipliers(parsed: dict) -> tuple[dict, set]:
    """comp -> execution count multiplier; set of fusion-internal comps."""
    comps = parsed["comps"]
    mult = {name: 0.0 for name in comps}
    fused: set[str] = set()
    entry = parsed["entry"]
    if entry is None:
        return mult, fused
    mult[entry] = 1.0
    # process in topological-ish order: repeat until fixpoint (call graphs
    # are DAGs; a few passes suffice)
    for _ in range(len(comps)):
        changed = False
        for name, comp in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for op in comp.ops:
                if op.opcode == "while":
                    body = _BODY_RE.search(op.line)
                    cond = _COND_RE.search(op.line)
                    trip = _TRIP_RE.search(op.line)
                    n = float(trip.group(1)) if trip else 1.0
                    for ref, k in ((body, n), (cond, n + 1)):
                        if ref and mult.get(ref.group(1), 0.0) < m * k:
                            mult[ref.group(1)] = m * k
                            changed = True
                else:
                    for ref in _CALLS_RE.finditer(op.line):
                        target = ref.group(1)
                        if op.opcode in ("fusion", "reduce", "scatter",
                                         "sort", "map", "reduce-window",
                                         "select-and-scatter", "reduce-scatter",
                                         "all-reduce"):
                            fused.add(target)
                        if mult.get(target, 0.0) < m:
                            mult[target] = m
                            changed = True
        if not changed:
            break
    return mult, fused


def _dot_flops(op: _Op, comp: _Comp) -> float:
    out = 1.0
    for d in _dims_of_type(op.result_type):
        out *= d
    mc = _CONTRACT_RE.search(op.line)
    k = 1.0
    if mc and op.operands:
        lhs_type = comp.symbols.get(op.operands[0], "")
        dims = _dims_of_type(lhs_type)
        for idx in (int(x) for x in mc.group(1).split(",") if x):
            if idx < len(dims):
                k *= dims[idx]
    return 2.0 * out * k


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_SLICING_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_bytes(op: _Op, comp: _Comp, comps: dict) -> int:
    """HBM traffic of one fusion kernel.

    Refinements over naive result+operands:
      * an operand consumed only through dynamic-slice/slice/gather is
        charged at the slice size (remat-stack reads, embedding gathers);
      * an operand that is only the in-place buffer of a
        dynamic-update-slice is charged zero (aliased carry update);
      * a root dynamic-update-slice writes only the updated slice.
    """
    m = _CALLS_RE.search(op.line)
    fcomp = comps.get(m.group(1)) if m else None
    if fcomp is None:
        b = _bytes_of_type(op.result_type)
        for o in op.operands:
            b += _bytes_of_type(comp.symbols.get(o, ""))
        return b
    defs = {x.name: x for x in fcomp.ops}
    _WRAPPERS = ("convert", "bitcast", "copy")

    def _resolve(name: str):
        """Follow elementwise wrapper chains (on TPU these fuse for free;
        the CPU backend materializes whole-buffer converts around carry
        updates — an artifact we must not charge to the TPU roofline)."""
        d = defs.get(name)
        seen = 0
        while d is not None and d.opcode in _WRAPPERS and d.operands \
                and seen < 4:
            d = defs.get(d.operands[0])
            seen += 1
        return d

    # ---- write side ----
    root = next((x for x in fcomp.ops if x.is_root), None)

    def _dus_write(d: _Op) -> int:
        return 2 * _bytes_of_type(fcomp.symbols.get(d.operands[1], "")) \
            if len(d.operands) > 1 else 0

    rroot = _resolve(root.name) if root is not None else None
    if rroot is not None and rroot.opcode == "dynamic-update-slice":
        wb = _dus_write(rroot)
    elif root is not None and root.opcode == "tuple":
        wb = 0
        for o in root.operands:
            d = _resolve(o)
            if d is not None and d.opcode == "dynamic-update-slice":
                wb += _dus_write(d)
            else:
                wb += _bytes_of_type(fcomp.symbols.get(o, ""))
    else:
        wb = _bytes_of_type(op.result_type)
    # ---- read side ----
    consumers: dict[str, list] = {}
    for x in fcomp.ops:
        for o in x.operands:
            consumers.setdefault(o, []).append(x)

    def _is_buffer_feed(pname: str, c: _Op, depth: int = 0) -> bool:
        """True if consumer chain uses the param only as DUS operand-0
        (possibly through convert/bitcast/copy wrappers)."""
        if c.opcode == "dynamic-update-slice":
            return bool(c.operands) and c.operands[0] == pname
        if c.opcode in _WRAPPERS and depth < 4:
            nxt = consumers.get(c.name, [])
            return bool(nxt) and all(
                _is_buffer_feed(c.name, n, depth + 1) for n in nxt)
        return False

    rb = 0
    for x in fcomp.ops:
        if x.opcode != "parameter":
            continue
        cons = consumers.get(x.name, [])
        if cons and all(c.opcode in _SLICING_OPS for c in cons):
            rb += sum(_bytes_of_type(c.result_type) for c in cons)
        elif cons and all(_is_buffer_feed(x.name, c) for c in cons):
            rb += 0   # aliased in-place carry buffer
        else:
            rb += _bytes_of_type(x.result_type)
    return wb + rb


def analyze(text: str) -> dict:
    """Scan-aware per-chip {flops, hbm_bytes, collectives} of one module."""
    parsed = parse_hlo(text)
    mult, fused = _call_multipliers(parsed)
    flops = 0.0
    hbm = 0.0
    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0.0 for k in _COLLECTIVES}
    for name, comp in parsed["comps"].items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        boundary = name not in fused
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp)
            base = op.opcode
            for c in _COLLECTIVES:
                if base == c or base == c + "-start":
                    nbytes = _bytes_of_type(op.result_type)
                    coll_bytes[c] += m * nbytes
                    coll_counts[c] += m
                    break
            if boundary and op.opcode not in _FREE_OPS \
                    and not op.opcode.endswith("-done") \
                    and not any(op.opcode.startswith(c) for c in _COLLECTIVES):
                if op.opcode == "fusion":
                    b = _fusion_bytes(op, comp, parsed["comps"])
                elif op.opcode == "dynamic-update-slice":
                    # in-place: read + write only the updated slice
                    upd = (comp.symbols.get(op.operands[1], "")
                           if len(op.operands) > 1 else "")
                    b = 2 * _bytes_of_type(upd)
                elif op.opcode == "dynamic-slice":
                    b = 2 * _bytes_of_type(op.result_type)
                else:
                    b = _bytes_of_type(op.result_type)
                    for o in op.operands:
                        b += _bytes_of_type(comp.symbols.get(o, ""))
                hbm += m * b
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collectives": {
            "bytes": coll_bytes, "counts": coll_counts,
            "total_bytes": sum(coll_bytes.values()),
            "total_count": sum(coll_counts.values()),
        },
    }


def collective_bytes(text: str) -> dict:
    return analyze(text)["collectives"]


# ring-algorithm wire multipliers (bytes crossing a device's links as a
# multiple of the per-device result buffer; (P-1)/P ~ 1 at P >= 16)
_WIRE_MULT = {
    "all-gather": 1.0,        # receives the full gathered buffer
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def wire_bytes(coll: dict) -> float:
    return sum(_WIRE_MULT[k] * v for k, v in coll["bytes"].items())


def roofline_terms(flops: float, hbm_bytes: float, coll: dict,
                   chips: int = 1, model_flops: float | None = None) -> dict:
    """Three per-chip roofline terms in seconds + the dominant one."""
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm_bytes / HBM_BW
    t_coll = wire_bytes(coll) / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)),
        key=lambda kv: kv[1])[0]
    out = {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": max(t_compute, t_memory, t_coll),
    }
    if model_flops is not None:
        out["model_flops"] = model_flops
        out["useful_fraction"] = (
            model_flops / (flops * chips) if flops else 0.0)
    return out
