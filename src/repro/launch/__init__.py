"""Launcher: production mesh, abstract input specs, train/serve steps,
multi-pod dry-run and roofline derivation."""
