"""Serving driver: batched prefill + decode with a sharded KV cache.

The prefill path teacher-forces the prompt through ``forward`` and then
replays it into the decode cache token by token (cheap at smoke scale;
the dry-run's decode cells measure the steady-state serve_step, which is
what dominates at 32k/500k context).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import transformer as T


def generate(arch: str = "gemma3-1b", smoke: bool = True,
             batch: int = 4, prompt_len: int = 16, gen_len: int = 16,
             seed: int = 0, greedy: bool = True):
    cfg = get_config(arch, smoke=smoke)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(seed))
    max_len = prompt_len + gen_len
    dtype = jnp.dtype(cfg.dtype)
    cache = T.init_cache(cfg, batch, max_len, dtype)
    prompts = jax.random.randint(jax.random.PRNGKey(seed + 1),
                                 (batch, prompt_len), 0, cfg.vocab)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    # prefill by replay (fills the cache deterministically)
    logits = None
    for pos in range(prompt_len):
        logits, cache = serve(params, cache, prompts[:, pos:pos + 1],
                              jnp.int32(pos))
    tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)

    out = [tok]
    t0 = time.time()
    for pos in range(prompt_len, max_len - 1):
        logits, cache = serve(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits[..., :cfg.vocab], axis=-1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    tokens = jnp.concatenate(out, axis=1)
    tput = batch * (gen_len - 1) / max(dt, 1e-9)
    return np.asarray(tokens), tput


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()
    toks, tput = generate(args.arch, batch=args.batch,
                          prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"[serve] generated {toks.shape} tokens, "
          f"{tput:.1f} tok/s (batched, CPU smoke)")
    print(toks[:2, :12])


if __name__ == "__main__":
    main()
