"""Abstract input specs + sharding assignment for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation); the
``*_shardings`` helpers map the param / optimizer / cache trees onto the
production mesh via the logical-axis rules.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.distributed.sharding import params_shardings, partition_spec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.adamw import adamw_init

from .mesh import dp_axes


def _dp(mesh: Mesh, dim: int):
    """DP axis-spec entry for a batch dimension, dropped if indivisible."""
    axes = dp_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if not axes or dim % size != 0:
        return None
    return axes[0] if len(axes) == 1 else axes


# ---------------------------------------------------------------------------
# Abstract trees (no allocation)

def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct params tree, logical-axes tree)."""
    return T.init_model_abstract(cfg)


def abstract_opt_state(params_abs, quantized: bool = False):
    return jax.eval_shape(lambda p: adamw_init(p, quantize=quantized),
                          params_abs)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len, dtype))


def enc_len(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Encoder memory length for enc-dec archs (audio frames, stub)."""
    return min(shape.seq_len, 4096)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for the step function's data arguments."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
        }
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.frontend:
            out["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_tokens, cfg.d_model), act)
        if cfg.is_encdec:
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (b, enc_len(cfg, shape), cfg.d_model), act)
        return out
    # decode: one new token against a seq_len KV cache
    out = {
        "token": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.is_encdec:
        out["enc_out"] = jax.ShapeDtypeStruct(
            (b, enc_len(cfg, shape), cfg.d_model), act)
    return out


# ---------------------------------------------------------------------------
# Shardings

def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> dict:
    """NamedSharding per input_specs entry (batch dim over DP)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, sds in specs.items():
        if sds.ndim == 0:
            out[name] = NamedSharding(mesh, P())
        else:
            dp = _dp(mesh, sds.shape[0])
            out[name] = NamedSharding(
                mesh, P(dp, *([None] * (sds.ndim - 1))))
    return out


def train_state_shardings(cfg: ModelConfig, mesh: Mesh,
                          quantized_opt: bool = False):
    """(params sharding tree, opt-state sharding tree)."""
    params_abs, axes = abstract_params(cfg)
    p_sh = params_shardings(axes, params_abs, mesh)
    opt_abs = abstract_opt_state(params_abs, quantized=quantized_opt)
    rep = NamedSharding(mesh, P())
    if quantized_opt:
        # Q8 leaves (int8 payload + per-block scale): payload mirrors the
        # param sharding; the scale mirrors it too on all but the last
        # dim (kept when the blocked length still divides) — replicated
        # scales at 340B cost 21 GB/chip and force gather storms.
        from repro.optim.quantized import Q8

        def mom_sh(q8_leaf, p_leaf_sh):
            if not isinstance(q8_leaf, Q8):
                return p_leaf_sh
            spec = list(p_leaf_sh.spec)
            spec += [None] * (q8_leaf.scale.ndim - len(spec))
            spec = spec[:q8_leaf.scale.ndim]
            last = q8_leaf.scale.shape[-1]
            ax = spec[-1] if spec else None
            if ax is not None:
                size = 1
                for a in (ax if isinstance(ax, tuple) else (ax,)):
                    size *= mesh.shape[a]
                if last % size != 0:
                    spec[-1] = None
            return Q8(q=p_leaf_sh,
                      scale=NamedSharding(mesh, P(*spec)))

        m_sh = jax.tree.map(mom_sh, opt_abs.m, p_sh,
                            is_leaf=lambda x: isinstance(x, Q8))
        v_sh = jax.tree.map(mom_sh, opt_abs.v, p_sh,
                            is_leaf=lambda x: isinstance(x, Q8))
        opt_sh = type(opt_abs)(step=rep, m=m_sh, v=v_sh)
    else:
        opt_sh = type(opt_abs)(step=rep, m=p_sh, v=p_sh)
    return params_abs, p_sh, opt_abs, opt_sh


def _model_div(mesh: Mesh, dim: int):
    return "model" if dim % mesh.shape["model"] == 0 else None


def cache_shardings(cfg: ModelConfig, cache_abs, mesh: Mesh,
                    seq_sharded: bool = False):
    """Sharding tree for a decode cache.

    KV caches [B,T,kv,hd]: batch over DP, kv heads over "model";
    for long-context (B=1) the sequence dim shards over "data" instead
    (flash-decode: SPMD inserts the partial-softmax merge).
    SSM states [B,nh,hd,n]: batch over DP, heads over "model".
    Stacked (scanned) layers carry a leading n_groups dim (never sharded).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abs)
    out = []
    for path, leaf in flat:
        keys = [getattr(pp, "key", getattr(pp, "idx", None)) for pp in path]
        name = next((k for k in reversed(keys) if isinstance(k, str)), "")
        stacked = "stack" in keys
        shape = leaf.shape[1:] if stacked else leaf.shape
        if name in ("k", "v"):                       # [B, T, kv, hd]
            kv_ax = _model_div(mesh, shape[2])
            # kv heads indivisible by TP -> shard the sequence over "model"
            # instead (flash-decode: SPMD merges the partial softmax)
            seq_ax = ("data" if seq_sharded else
                      ("model" if kv_ax is None
                       and shape[1] % mesh.shape["model"] == 0 else None))
            spec = [_dp(mesh, shape[0]), seq_ax, kv_ax, None]
        elif name in ("c_kv", "k_rope"):             # [B, T, r]
            # MLA: every (sharded) q head needs the full compressed stream;
            # shard the sequence over "model" (partial-softmax merge)
            seq_ax = ("data" if seq_sharded else
                      ("model" if shape[1] % mesh.shape["model"] == 0
                       else None))
            spec = [_dp(mesh, shape[0]), seq_ax, None]
        elif name == "conv":                         # [B, K-1, ch]
            spec = [_dp(mesh, shape[0]), None, _model_div(mesh, shape[2])]
        elif name == "state":                        # [B, nh, hd, n]
            spec = [_dp(mesh, shape[0]), _model_div(mesh, shape[1]),
                    None, None]
        else:
            spec = [None] * len(shape)
        if seq_sharded and spec[0] is not None and "data" in spec[1:]:
            spec[0] = None if "pod" not in mesh.axis_names else "pod"
        if stacked:
            spec = [None] + spec
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def logits_sharding(cfg: ModelConfig, batch: int, mesh: Mesh):
    return NamedSharding(mesh, P(_dp(mesh, batch), None,
                                 _model_div(mesh, cfg.padded_vocab)))
