"""Pallas TPU kernel: mixed-precision GEMM update  C <- C - A @ B^T.

This is the hot kernel of the factorization (GEMM is ~n^3/3 of the work)
and the place where the paper's four-precision scheme meets the hardware:
A and B keep their *storage* precision (fp8-e4m3 / bf16 / f32) so the MXU
runs at the narrow-operand rate, while the accumulator is always f32.

Tiling: grid (M/bm, N/bn, K/bk) with the K dimension innermost; a VMEM
scratch accumulator carries partial sums across the K steps (standard TPU
matmul pattern — the HBM->VMEM traffic per operand block is amortized over
the whole K loop).  Block sizes default to 128 to match the 128x128 MXU
systolic array; both operands are [rows, K]-major so the B block is
transposed inside VMEM (free — feeds the MXU's stationary side).

SYRK (C - A A^T) reuses this kernel with B = A.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mxp_gemm_kernel(a_ref, b_ref, c_ref, o_ref, acc_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    a = a_ref[...]
    b = b_ref[...]
    acc_ref[...] -= jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mxp_gemm_update(c: jax.Array, a: jax.Array, b: jax.Array,
                    bm: int = 128, bn: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """C - A @ B^T with f32 accumulation.  a: [M,K], b: [N,K], c: [M,N]."""
    m, k = a.shape
    n, kb = b.shape
    assert k == kb and c.shape == (m, n), (a.shape, b.shape, c.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    kernel = functools.partial(_mxp_gemm_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, k_steps),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # A
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),   # B
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),    # C in
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b, c)
