"""Flash attention for TPU (Pallas): online-softmax over KV blocks.

Grid (BH, NQ, NK): each (batch*q-head, q-block) pair streams KV blocks
through VMEM, carrying the running (max, sum, acc) in scratch — scores
never materialize beyond [bq, bk].  GQA is handled in the k/v BlockSpec
index maps (kv head = q head // group), so kv blocks are fetched once
per group from HBM, not replicated by the caller.

MXU alignment: bq/bk default 512/512 and head_dim should be a multiple
of 128 (the assigned archs use 128/192/256).  f32 accumulation
throughout; inputs may be bf16.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                   # [bk, hd]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        i = pl.program_id(1)
        qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kj <= qi, s, NEG_INF)

    m_prev = m_ref[...]                                # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])                    # [bq, bk]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    v = v_ref[0].astype(jnp.float32)                   # [bk, hd]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 512,
                    bk: int = 512, interpret: bool = True):
    """q: [BH, S, hd]; k/v: [BKV, T, hd] with BH = BKV * group.

    Returns [BH, S, hd].  S % bq == 0 and T % bk == 0 (pad upstream).
    """
    bh, s, hd = q.shape
    bkv, t, _ = k.shape
    assert bh % bkv == 0, (bh, bkv)
    g = bh // bkv
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nq, nk = s // bq, t // bk
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running sum
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


def flash_gqa(q, k, v, *, causal: bool = True, interpret: bool = True,
              bq: int = 512, bk: int = 512):
    """Convenience wrapper for model-layout tensors.

    q: [B, S, H, hd]; k/v: [B, T, KV, hd] -> [B, S, H, hd].
    Heads are grouped kv-major (head h uses kv head h // (H // KV)),
    matching ``repro.models.attention._sdpa``.
    """
    b, s, h, hd = q.shape
    t, kv = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, t, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, t, hd)
    out = flash_attention(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                          interpret=interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
