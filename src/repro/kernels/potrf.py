"""Pallas TPU kernel: single-tile Cholesky factorization (POTRF).

The whole tile lives in VMEM (one grid cell — a Cholesky tile is at most
256x256xf32 = 256 KiB, far under the ~16 MiB VMEM budget).  The kernel runs
the column-recursive algorithm: column ``j`` is formed with one masked
matvec against the already-factored panel, which the Mosaic compiler maps
to VPU lanes; the O(n^2) matvec per column is dominated by the O(n^3) SYRK/
GEMM traffic that surrounds POTRF in the factorization (surface-to-volume,
paper §I), so MXU-blocking the interior of POTRF is deliberately not done.

dtypes: f32/bf16 storage, f32 compute.  (f64 tiles take the stock XLA path
— the TPU has no native f64 MXU; see DESIGN.md §2.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _potrf_kernel(a_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    a = 0.5 * (a + a.T)
    n = a.shape[0]
    rows = jax.lax.iota(jnp.int32, n)

    def col(j, l):
        # v = A[:, j] - L @ L[j, :]^T ; columns >= j of L are still zero.
        v = a[:, j] - l @ l[j, :]
        d = jnp.sqrt(v[j])
        colv = jnp.where(rows >= j, v / d, jnp.zeros_like(v))
        return l.at[:, j].set(colv)

    l = jax.lax.fori_loop(0, n, col, jnp.zeros_like(a))
    o_ref[...] = l.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def potrf(a: jax.Array, interpret: bool = True) -> jax.Array:
    n = a.shape[0]
    return pl.pallas_call(
        _potrf_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        in_specs=[pl.BlockSpec((n, n), lambda: (0, 0))],
        out_specs=pl.BlockSpec((n, n), lambda: (0, 0)),
        interpret=interpret,
    )(a)
