"""Pallas TPU kernel: symmetric rank-k update  C <- C - A @ A^T  (SYRK).

Dedicated kernel rather than GEMM-with-B=A so the grid can skip the
strictly-upper blocks: only blocks with i >= j are computed (the factor is
lower-triangular; the paper stores/moves only the lower triangle — Fig. 8).
The upper blocks are filled with the mirrored transpose afterwards by the
wrapper when a full tile is required.

Grid (M/bm, M/bm, K/bk), K innermost, VMEM f32 scratch accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _syrk_kernel(a_ref, a2_ref, c_ref, o_ref, acc_ref, *, k_steps):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(jnp.float32)

    @pl.when(i >= j)
    def _update():
        acc_ref[...] -= jax.lax.dot_general(
            a_ref[...], a2_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def syrk_update(c: jax.Array, a: jax.Array, bm: int = 128, bk: int = 128,
                interpret: bool = True) -> jax.Array:
    """Lower-triangle C - A @ A^T; upper blocks of C pass through untouched
    in the block-skip region (callers that need symmetry mirror afterwards)."""
    m, k = a.shape
    assert c.shape == (m, m)
    bm, bk = min(bm, m), min(bk, k)
    assert m % bm == 0 and k % bk == 0
    k_steps = k // bk
    kernel = functools.partial(_syrk_kernel, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, m // bm, k_steps),
        out_shape=jax.ShapeDtypeStruct((m, m), c.dtype),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),   # A row block
            pl.BlockSpec((bm, bk), lambda i, j, kk: (j, kk)),   # A col block
            pl.BlockSpec((bm, bm), lambda i, j, kk: (i, j)),    # C in
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
        interpret=interpret,
    )(a, a, c)
