"""Pallas TPU megakernel: one launch per fused column step.

The unfused executor dispatches one kernel per tile op — for column ``k``
of a left-looking tile Cholesky that is ``k`` SYRKs + 1 POTRF on the
diagonal and, per owned row ``m > k``, ``k`` GEMMs + 1 TRSM: ``O(nt * k)``
launches whose HBM->VMEM traffic re-reads the same panel-history tiles
over and over.  This kernel runs the whole column step in a *single*
``pallas_call``:

* grid ``(R, K)`` — ``R`` output tiles (row 0 is the diagonal when
  ``with_diag``), ``K`` accumulation steps.  The TPU grid executes
  sequentially row-major, so row 0 (the POTRF) completes before any TRSM
  row consumes its factor from VMEM scratch.
* same-shape tile GEMMs are batched across rows: step ``(r, kk)`` is
  ``acc_r -= hist[r, kk] @ bhist[kk]^T`` with the B operand (the diagonal
  row's history) broadcast across the ``r`` axis — for ``r = 0`` and
  ``hist[0] = bhist`` that is exactly the SYRK.
* the tile being updated stays resident in a VMEM accumulator across all
  ``K`` steps; the triangular solve / factorization runs in the same
  launch on the final step (``pl.when``), against the VMEM-resident
  factor — no HBM round-trip between the update wave and the solve.
* the per-tile precision down-cast runs *in the epilogue*: each output
  row carries a class id, and scaled-FP8 rows additionally track their
  amax at store time and fold the power-of-two scale into the cast
  (see ``repro.core.precision.fp8_scale`` and docs/kernels.md).

Launch accounting: the executors and benchmarks count kernel dispatches
through :func:`launch_counts` — every call here bumps ``fused_column``
(one per column step), every wrapper in :mod:`repro.kernels.ops` bumps
``tile_op`` (one per unfused tile op).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_JNP_DTYPES = {
    "f64": jnp.float64,
    "f32": jnp.float32,
    "f16": jnp.float16,
    "bf16": jnp.bfloat16,
    "f8e4m3": jnp.float8_e4m3fn,
    "f8e4m3s": jnp.float8_e4m3fn,
}

# trace-time kernel dispatch counters (see launch_counts)
_LAUNCHES = {"fused_column": 0, "tile_op": 0}


def launch_counts() -> dict:
    """Kernel dispatches since the last reset: ``fused_column`` counts
    fused column-step launches, ``tile_op`` unfused per-tile-op launches
    (incremented by the :mod:`repro.kernels.ops` wrappers)."""
    return dict(_LAUNCHES)


def reset_launch_counts() -> None:
    for k in _LAUNCHES:
        _LAUNCHES[k] = 0


def count_tile_op() -> None:
    _LAUNCHES["tile_op"] += 1


def _fp8_scale_of(amax, dtype):
    """Power-of-two scale for a scaled-FP8 tile from its amax: largest
    ``2^e`` with ``amax * 2^e <= 448``.  Computed via frexp so jax and
    numpy agree bitwise (a log2/floor boundary could differ by one ulp
    and shift the scale a whole octave)."""
    m, e = jnp.frexp(amax)
    exp = (8 - e) + jnp.where(m <= 0.875, 1, 0)
    s = jnp.exp2(exp.astype(dtype))
    ok = jnp.isfinite(amax) & (amax > 0)
    return jnp.where(ok, s, jnp.asarray(1.0, dtype))


def _round_class(x, name: str):
    """Round-trip one tile through a storage class inside the kernel
    epilogue (the executors' ``_jx_round`` semantics: f64 degrades to the
    compute dtype when x64 is off; the scaled-FP8 class applies its
    store-time amax scale before the cast and inverts it after)."""
    if name == "f64":
        if not jax.config.jax_enable_x64 or x.dtype == jnp.float64:
            return x
        return x.astype(jnp.float64).astype(x.dtype)
    if _JNP_DTYPES[name] == x.dtype:
        return x
    if name == "f8e4m3s":
        s = _fp8_scale_of(jnp.max(jnp.abs(x)), x.dtype)
        return ((x * s).astype(jnp.float8_e4m3fn).astype(x.dtype)) / s
    return x.astype(_JNP_DTYPES[name]).astype(x.dtype)


def _epilogue(x, cls_id, ladder):
    """Class-indexed epilogue cast: ``cls_id`` selects which storage
    class the result is rounded through (-1 = leave unrounded; the
    executor's own STORE will round it)."""
    out = x
    for idx, name in enumerate(ladder):
        out = jnp.where(cls_id == idx, _round_class(x, name), out)
    return out


def _chol_tile(c):
    """Column-recursive in-VMEM Cholesky (the potrf.py loop)."""
    a = 0.5 * (c + c.T)
    n = a.shape[0]
    rows = jax.lax.iota(jnp.int32, n)

    def col(j, l):
        v = a[:, j] - l @ l[j, :]
        d = jnp.sqrt(v[j])
        colv = jnp.where(rows >= j, v / d, jnp.zeros_like(v))
        return l.at[:, j].set(colv)

    return jax.lax.fori_loop(0, n, col, jnp.zeros_like(a))


def _trsm_tile(l, c):
    """Forward substitution X L^T = C in VMEM (the trsm.py loop)."""
    n = l.shape[0]

    def col(j, x):
        v = (c[:, j] - x @ l[j, :]) / l[j, j]
        return x.at[:, j].set(v)

    return jax.lax.fori_loop(0, n, col, jnp.zeros_like(c))


def _fused_kernel(c_ref, h_ref, b_ref, l_ref, cls_ref, o_ref, acc_ref,
                  l_scr, *, k_steps, with_diag, ladder):
    r = pl.program_id(0)
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = c_ref[0].astype(acc_ref.dtype)

    a = h_ref[0, 0].astype(acc_ref.dtype)
    b = b_ref[0].astype(acc_ref.dtype)
    acc_ref[...] -= jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(kk == k_steps - 1)
    def _final():
        cls_id = cls_ref[0, 0]
        if with_diag:
            @pl.when(r == 0)
            def _diag():
                # the epilogue-rounded factor goes to scratch too: the
                # row TRSMs must solve against the *stored* (class-
                # rounded) diagonal, exactly as the unfused trace reads
                # it back after its STORE
                l = _epilogue(_chol_tile(acc_ref[...]), cls_id, ladder)
                l_scr[...] = l
                o_ref[0] = l.astype(o_ref.dtype)

            @pl.when(r > 0)
            def _row():
                x = _trsm_tile(l_scr[...], acc_ref[...])
                o_ref[0] = _epilogue(x, cls_id, ladder).astype(o_ref.dtype)
        else:
            x = _trsm_tile(l_ref[...].astype(acc_ref.dtype), acc_ref[...])
            o_ref[0] = _epilogue(x, cls_id, ladder).astype(o_ref.dtype)


def fused_column_step(c_stack, hist, bhist, l_kk, cls_ids, *,
                      ladder, with_diag: bool, interpret: bool = True):
    """One fused column step: trailing update + solve, one launch.

    Args:
      c_stack: ``[R, tb, tb]`` tiles to update.  With ``with_diag`` row 0
        is the diagonal tile (SYRK wave + POTRF); every later row gets
        the GEMM wave + TRSM against the in-launch factor.  Without
        ``with_diag`` every row is a panel row solved against ``l_kk``.
      hist: ``[R, K, tb, tb]`` A-operand history (``A[m, j]`` for
        ``j < k``).  ``K = 0`` is allowed (column 0: pure solve).
      bhist: ``[K, tb, tb]`` B-operand history — the diagonal row's
        panel tiles ``A[k, j]``; with ``with_diag``, ``hist[0] == bhist``.
      l_kk: ``[tb, tb]`` pre-factored diagonal (ignored with
        ``with_diag`` — pass zeros).
      cls_ids: ``[R]`` int32 storage-class index per output row for the
        epilogue cast (-1 leaves a row unrounded).
      ladder: the precision-plan ladder naming the class indices.
      with_diag: statically selects the POTRF-in-launch variant.

    Returns ``[R, tb, tb]``: the factored diagonal (row 0, with_diag)
    and solved panel rows, epilogue-cast per class.
    """
    r_tiles, tb, _ = c_stack.shape
    k_hist = hist.shape[1]
    if k_hist == 0:     # pure-solve column: accumulate an exact zero
        hist = jnp.zeros((r_tiles, 1, tb, tb), dtype=c_stack.dtype)
        bhist = jnp.zeros((1, tb, tb), dtype=c_stack.dtype)
        k_hist = 1
    acc_dtype = (jnp.float64 if c_stack.dtype == jnp.float64
                 else jnp.float32)
    cls_arr = jnp.asarray(cls_ids, dtype=jnp.int32).reshape(r_tiles, 1)
    _LAUNCHES["fused_column"] += 1
    kernel = functools.partial(_fused_kernel, k_steps=k_hist,
                               with_diag=with_diag, ladder=tuple(ladder))
    return pl.pallas_call(
        kernel,
        grid=(r_tiles, k_hist),
        out_shape=jax.ShapeDtypeStruct((r_tiles, tb, tb), c_stack.dtype),
        in_specs=[
            pl.BlockSpec((1, tb, tb), lambda r, kk: (r, 0, 0)),     # C
            pl.BlockSpec((1, 1, tb, tb), lambda r, kk: (r, kk, 0, 0)),  # A
            pl.BlockSpec((1, tb, tb), lambda r, kk: (kk, 0, 0)),    # B
            pl.BlockSpec((tb, tb), lambda r, kk: (0, 0)),           # L in
            pl.BlockSpec((1, 1), lambda r, kk: (r, 0)),             # cls
        ],
        out_specs=pl.BlockSpec((1, tb, tb), lambda r, kk: (r, 0, 0)),
        scratch_shapes=[pltpu.VMEM((tb, tb), acc_dtype),
                        pltpu.VMEM((tb, tb), acc_dtype)],
        interpret=interpret,
    )(c_stack, hist, bhist, l_kk, cls_arr)
