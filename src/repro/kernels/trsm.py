"""Pallas TPU kernel: triangular solve X @ L^T = C (TRSM, right/lower-T).

One grid cell per C-row-panel: L (tb x tb) is broadcast to every cell, the
C panel streams through VMEM in ``bm``-row blocks so arbitrarily tall C
panels (the paper's column block of TRSMs, Fig. 3c) stay within the VMEM
budget.  Columns are produced by forward substitution; each step is one
masked matvec over the already-solved panel (VPU), the panel itself sits
in registers/VMEM the whole time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trsm_kernel(l_ref, c_ref, o_ref):
    l = l_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    n = l.shape[0]

    def col(j, x):
        # X[:, j] = (C[:, j] - X @ L[j, :]^T) / L[j, j]
        v = (c[:, j] - x @ l[j, :]) / l[j, j]
        return x.at[:, j].set(v)

    x = jax.lax.fori_loop(0, n, col, jnp.zeros_like(c))
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def trsm(l: jax.Array, c: jax.Array, bm: int | None = None,
         interpret: bool = True) -> jax.Array:
    """Solve X L^T = C.  l: [n, n] lower-triangular; c: [m, n]."""
    m, n = c.shape
    bm = bm or m
    assert m % bm == 0, (m, bm)
    return pl.pallas_call(
        _trsm_kernel,
        grid=(m // bm,),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),      # L broadcast
            pl.BlockSpec((bm, n), lambda i: (i, 0)),     # C row panel
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        interpret=interpret,
    )(l, c)
