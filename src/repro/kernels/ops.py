"""jit'd public wrappers over the Pallas kernels.

Dispatch rule (DESIGN.md §2): f64 tiles take the stock XLA path (the TPU
has no native f64 MXU); f32/bf16/fp8 tiles take the Pallas kernels.  On
CPU CI every kernel runs in interpret mode, which executes the kernel body
through XLA and validates the BlockSpec pipeline end to end.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import ref as _ref
from .potrf import potrf as _potrf_pallas
from .trsm import trsm as _trsm_pallas
from .syrk import syrk_update as _syrk_pallas
from .mxp_gemm import mxp_gemm_update as _gemm_pallas
# fused column-step megakernel (CholeskyConfig.fuse_columns) + the
# launch accounting shared by fused and unfused dispatch
from .fused_column import (fused_column_step, launch_counts,  # noqa: F401
                           reset_launch_counts)

_F64 = (jnp.float64,)


def _is_f64(*xs) -> bool:
    return any(x.dtype in _F64 for x in xs)


def potrf(a, interpret: bool = True):
    if _is_f64(a):
        return _ref.potrf_ref(a)
    return _potrf_pallas(a, interpret=interpret)


def trsm(l, c, interpret: bool = True):
    if _is_f64(l, c):
        return _ref.trsm_ref(l, c)
    return _trsm_pallas(l, c, interpret=interpret)


def syrk_update(c, a, interpret: bool = True):
    if _is_f64(c, a):
        return _ref.syrk_update_ref(c, a)
    out = _syrk_pallas(c, a, interpret=interpret)
    # mirror the lower triangle (kernel skips strictly-upper blocks)
    return jnp.tril(out) + jnp.tril(out, -1).T


def gemm_update(c, a, b, interpret: bool = True):
    if _is_f64(c, a, b):
        return _ref.gemm_update_ref(c, a, b)
    return _gemm_pallas(c, a, b, interpret=interpret)


mxp_gemm_update = gemm_update
