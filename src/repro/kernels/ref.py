"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests ``assert_allclose`` against
(interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def potrf_ref(a: jax.Array) -> jax.Array:
    """Lower Cholesky factor of a (symmetrized) SPD tile."""
    return jnp.linalg.cholesky(0.5 * (a + a.T))


def trsm_ref(l: jax.Array, c: jax.Array) -> jax.Array:
    """Solve X @ L^T = C for X (right-solve against the transposed factor)."""
    return jax.scipy.linalg.solve_triangular(l, c.T, lower=True).T


def syrk_update_ref(c: jax.Array, a: jax.Array) -> jax.Array:
    """C - A @ A^T (the left-looking diagonal update)."""
    return c - a @ a.T


def gemm_update_ref(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """C - A @ B^T (the left-looking off-diagonal update)."""
    return c - a @ b.T


def mxp_gemm_ref(c: jax.Array, a: jax.Array, b: jax.Array,
                 acc_dtype=jnp.float32) -> jax.Array:
    """Mixed-precision C - A @ B^T: low-precision operands, wide accumulate.

    Operands keep their storage dtype (fp8/bf16/f32); products accumulate
    in ``acc_dtype``; result is cast back to C's dtype.
    """
    prod = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=acc_dtype)
    return (c.astype(acc_dtype) - prod).astype(c.dtype)
