"""Deterministic, shardable, resumable synthetic-token data pipeline.

Design mirrors a production loader even though the token source is
synthetic (no datasets ship with this container):

* **determinism** — batch ``i`` is a pure function of (seed, i); every
  host computes only its slice, so a restart at step ``k`` reproduces the
  exact stream without replaying.
* **sharding** — ``host_slice(mesh)`` returns this process's batch rows;
  under full SPMD each host feeds its addressable shard.
* **resumability** — :class:`PipelineState` is a (seed, step) pair stored
  inside every checkpoint; restore = construct + ``seek(step)`` (O(1)).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(int(d["seed"]), int(d["step"]))


class DataPipeline:
    """Synthetic next-token-prediction batches with markov-ish structure
    (so losses actually decrease and overfitting tests are meaningful)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, n_docs: int = 64):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.state = PipelineState(seed=seed, step=0)
        # fixed fake corpus: a bank of repeating "documents"
        rng = np.random.default_rng(seed ^ 0x5EED)
        self._docs = rng.integers(0, vocab, size=(n_docs, seq_len + 1),
                                  dtype=np.int32)

    def seek(self, step: int):
        self.state.step = step

    def _batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.state.seed << 20) ^ step)
        doc_ids = rng.integers(0, self._docs.shape[0], size=self.global_batch)
        seqs = self._docs[doc_ids]
        # light noise so batches differ but remain learnable
        noise_pos = rng.integers(0, self.seq_len, size=(self.global_batch, 4))
        for b in range(self.global_batch):
            seqs[b, noise_pos[b]] = rng.integers(0, self.vocab, size=4)
        return {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}

    def __next__(self):
        b = self._batch_at(self.state.step)
        self.state.step += 1
        return b

    def __iter__(self):
        return self

    def host_slice(self, batch: dict, host_index: int, host_count: int) -> dict:
        rows = self.global_batch // host_count
        lo = host_index * rows
        return {k: v[lo:lo + rows] for k, v in batch.items()}
