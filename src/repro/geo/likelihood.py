"""Gaussian log-likelihood through the (MxP OOC) Cholesky factor (Eq. 1).

ℓ(θ; y) = −n/2 log 2π − ½ log|Σ| − ½ yᵀ Σ⁻¹ y

log|Σ| = 2 Σ_i log L_ii and yᵀΣ⁻¹y = ‖L⁻¹y‖² via one triangular solve.
The factor comes from any policy/precision of ``repro.core`` — this module
is precision-agnostic and is what the KL-divergence assessment drives.

Both entry points accept either a dense lower factor (ndarray) or a
factored :class:`~repro.core.api.OOCSolver`: the solver path never
materializes the dense n x n factor — log|Σ| comes off the diagonal tiles
and the quad form runs through the blocked forward substitution of
``repro.core.solve``, which is how the MLE loop in
``examples/geospatial_mle.py`` evaluates ℓ out-of-core.

Multi-observation (0.7): ``y`` may be ``k`` stacked observation vectors
as an ``(n, k)`` matrix — one forward substitution sweeps the factor for
all ``k`` quad forms and the entry points return length-``k`` arrays.
An MLE step over replicated fields (or a serve tenant fanning out many
correlated likelihood evaluations — the paper's motivating request
stream) therefore reads each factor tile once, not ``k`` times.  A
:class:`repro.serve.Session` duck-types the solver surface
(``solve_lower``/``logdet``/``n``), so the same functions drive the
served solver pool unchanged.
"""
from __future__ import annotations

import numpy as np
import scipy.linalg as sla


def _is_solver(obj) -> bool:
    return hasattr(obj, "solve_lower") and hasattr(obj, "logdet")


def _quad(z: np.ndarray):
    """‖z‖² per column: float for one rhs, length-k array for a stack."""
    if z.ndim == 1:
        return float(z @ z)
    return np.einsum("ij,ij->j", z, z)


def loglik_terms_from_factor(l, y: np.ndarray | None = None):
    """(logdet, quad) from a lower Cholesky factor or a factored solver.

    ``y`` of shape ``(n,)`` gives a scalar quad form; ``(n, k)`` stacked
    observations give a length-``k`` array of quad forms from a single
    blocked substitution sweep.
    """
    if _is_solver(l):
        logdet = l.logdet()
        if y is None:
            return logdet, 0.0
        z = l.solve_lower(np.asarray(y, dtype=np.float64))
        return logdet, _quad(z)
    diag = np.diag(l)
    logdet = 2.0 * np.sum(np.log(diag))
    if y is None:
        return logdet, 0.0
    z = sla.solve_triangular(l, y, lower=True)
    return logdet, _quad(z)


def gaussian_loglik(l, y: np.ndarray | None = None):
    """ℓ(θ; y) — a float for one observation vector, a length-``k``
    array for ``(n, k)`` stacked observations."""
    n = l.n if _is_solver(l) else l.shape[0]
    logdet, quad = loglik_terms_from_factor(l, y)
    out = -0.5 * n * np.log(2.0 * np.pi) - 0.5 * logdet - 0.5 * quad
    return out if isinstance(quad, np.ndarray) else float(out)
