"""Gaussian log-likelihood through the (MxP OOC) Cholesky factor (Eq. 1).

ℓ(θ; y) = −n/2 log 2π − ½ log|Σ| − ½ yᵀ Σ⁻¹ y

log|Σ| = 2 Σ_i log L_ii and yᵀΣ⁻¹y = ‖L⁻¹y‖² via one triangular solve.
The factor comes from any policy/precision of ``repro.core`` — this module
is precision-agnostic and is what the KL-divergence assessment drives.
"""
from __future__ import annotations

import numpy as np
import scipy.linalg as sla


def loglik_terms_from_factor(l: np.ndarray, y: np.ndarray | None = None):
    """(logdet, quad) from a lower Cholesky factor (NaN-safe logdet)."""
    diag = np.diag(l)
    logdet = 2.0 * np.sum(np.log(diag))
    if y is None:
        return logdet, 0.0
    z = sla.solve_triangular(l, y, lower=True)
    return logdet, float(z @ z)


def gaussian_loglik(l: np.ndarray, y: np.ndarray | None = None) -> float:
    n = l.shape[0]
    logdet, quad = loglik_terms_from_factor(l, y)
    return float(-0.5 * n * np.log(2.0 * np.pi) - 0.5 * logdet - 0.5 * quad)
