from .matern import matern_covariance, generate_locations
from .likelihood import gaussian_loglik, loglik_terms_from_factor
from .kl import kl_divergence_mxp

__all__ = [
    "matern_covariance", "generate_locations",
    "gaussian_loglik", "loglik_terms_from_factor",
    "kl_divergence_mxp",
]
