"""KL-divergence accuracy assessment of the MxP factorization (Eq. 3).

D_KL(N₀ ‖ N_a) = ℓ₀(θ; 0) − ℓ_a(θ; 0)

ℓ₀ is the FP64 log-likelihood at y = 0, ℓ_a the MxP one: the divergence
reduces to ½(log|Σ|_a − log|Σ|₀) — exactly the metric of Fig. 10.
"""
from __future__ import annotations

import numpy as np

from repro.core.cholesky import ooc_cholesky
from .likelihood import gaussian_loglik


def kl_divergence_mxp(
    cov: np.ndarray,
    tb: int,
    eps_target: float,
    policy: str = "v3",
    ladder: str = "tpu",
    backend: str = "numpy",
) -> dict:
    """Return the KL divergence between FP64 and MxP likelihoods + details."""
    l_ref, _ = ooc_cholesky(cov, tb, policy=policy, eps_target=None,
                            backend=backend)
    l_mxp, sched = ooc_cholesky(cov, tb, policy=policy, eps_target=eps_target,
                                ladder=ladder, backend=backend)
    l0 = gaussian_loglik(l_ref)
    la = gaussian_loglik(l_mxp)
    return {
        "kl": l0 - la,
        "abs_kl": abs(l0 - la),
        "loglik_fp64": l0,
        "loglik_mxp": la,
        "precision_histogram": sched.plan.histogram(),
        "loads_bytes": sched.loads_bytes(),
        "eps_target": eps_target,
    }
