"""KL-divergence accuracy assessment of the MxP factorization (Eq. 3).

D_KL(N₀ ‖ N_a) = ℓ₀(θ; 0) − ℓ_a(θ; 0)

ℓ₀ is the FP64 log-likelihood at y = 0, ℓ_a the MxP one: the divergence
reduces to ½(log|Σ|_a − log|Σ|₀) — exactly the metric of Fig. 10.

Both factorizations run through the planner/executor API: the FP64
reference plan is matrix-independent, so sweeping ``eps_target`` over one
covariance (the Fig. 10 sweep) reuses a single cached reference schedule
and executor.
"""
from __future__ import annotations

import numpy as np

from repro.core.api import CholeskyConfig, plan


def kl_divergence_mxp(
    cov: np.ndarray,
    tb: int,
    eps_target: float,
    policy: str = "v3",
    ladder: str = "tpu",
    backend: str = "numpy",
) -> dict:
    """Return the KL divergence between FP64 and MxP likelihoods + details."""
    from .likelihood import gaussian_loglik

    cov = np.asarray(cov, dtype=np.float64)
    n = cov.shape[0]
    base = CholeskyConfig(tb=tb, policy=policy, ladder=ladder,
                          backend=backend)
    ref = plan(n, base).compile()
    ref.factor(cov, materialize=False)    # logdet reads the tile store
    mxp_cfg = CholeskyConfig(tb=tb, policy=policy, ladder=ladder,
                             backend=backend,
                             eps_target=eps_target).specialize(cov)
    mxp = plan(n, mxp_cfg).compile()
    mxp.factor(cov, materialize=False)
    sched = mxp.schedule
    l0 = gaussian_loglik(ref)
    la = gaussian_loglik(mxp)
    return {
        "kl": l0 - la,
        "abs_kl": abs(l0 - la),
        "loglik_fp64": l0,
        "loglik_mxp": la,
        "precision_histogram": sched.plan.histogram(),
        "loads_bytes": sched.loads_bytes(),
        "eps_target": eps_target,
    }
