"""Matérn covariance construction (paper §III-D, Eq. 2).

C(h; θ) = σ²/(2^{ν−1}Γ(ν)) (h/a)^ν K_ν(h/a),   θ = (σ², a, ν)

The paper's experiments use ν = 0.5 (exponential kernel) with spatial
range β ∈ {0.02627, 0.078809, 0.210158} for weak/medium/strong correlation.
Covariance assembly is a host-side data-generation step (float64, SciPy
Bessel for general ν, closed forms for ν ∈ {1/2, 3/2, 5/2}); the
factorization of the resulting Σ is the device workload.
"""
from __future__ import annotations

import numpy as np

# paper's three correlation regimes (β = spatial range a)
BETA_WEAK = 0.02627
BETA_MEDIUM = 0.078809
BETA_STRONG = 0.210158


def _morton_key(pts: np.ndarray, bits: int = 16) -> np.ndarray:
    """Z-order (Morton) key per point — ExaGeoStat orders locations this way
    so that covariance tiles correspond to spatial blocks and off-diagonal
    tile norms decay (that decay is what the MxP criterion harvests)."""
    q = np.clip((pts * (2**bits - 1)).astype(np.uint64), 0, 2**bits - 1)

    def spread(x):
        x = x.astype(np.uint64)
        x = (x | (x << np.uint64(16))) & np.uint64(0x0000FFFF0000FFFF)
        x = (x | (x << np.uint64(8))) & np.uint64(0x00FF00FF00FF00FF)
        x = (x | (x << np.uint64(4))) & np.uint64(0x0F0F0F0F0F0F0F0F)
        x = (x | (x << np.uint64(2))) & np.uint64(0x3333333333333333)
        x = (x | (x << np.uint64(1))) & np.uint64(0x5555555555555555)
        return x

    return spread(q[:, 0]) | (spread(q[:, 1]) << np.uint64(1))


def generate_locations(n: int, seed: int = 0) -> np.ndarray:
    """Irregular locations on the unit square, Morton-ordered
    (ExaGeoStat-style jittered grid + space-filling-curve ordering)."""
    rng = np.random.default_rng(seed)
    side = int(np.ceil(np.sqrt(n)))
    xs, ys = np.meshgrid(np.arange(side), np.arange(side))
    pts = np.stack([xs.ravel(), ys.ravel()], axis=1).astype(np.float64)
    pts += rng.uniform(-0.4, 0.4, size=pts.shape)
    pts = (pts - pts.min(0)) / (pts.max(0) - pts.min(0))
    idx = rng.permutation(pts.shape[0])[:n]
    pts = pts[idx]
    order = np.argsort(_morton_key(pts))
    return pts[order]


def matern_covariance(locs: np.ndarray, sigma2: float = 1.0,
                      beta: float = BETA_MEDIUM, nu: float = 0.5,
                      nugget: float = 1e-6) -> np.ndarray:
    """Dense Matérn covariance matrix Σ_θ over the given locations."""
    d = np.sqrt(((locs[:, None, :] - locs[None, :, :]) ** 2).sum(-1))
    h = d / beta
    if nu == 0.5:
        c = np.exp(-h)
    elif nu == 1.5:
        s = np.sqrt(3.0) * h
        c = (1.0 + s) * np.exp(-s)
    elif nu == 2.5:
        s = np.sqrt(5.0) * h
        c = (1.0 + s + s * s / 3.0) * np.exp(-s)
    else:
        from scipy.special import kv, gamma
        hp = np.where(h == 0.0, 1.0, h)
        c = (2.0 ** (1.0 - nu) / gamma(nu)) * (hp ** nu) * kv(nu, hp)
        c = np.where(h == 0.0, 1.0, c)
    cov = sigma2 * c
    cov[np.diag_indices_from(cov)] += nugget * sigma2
    return cov
