"""Mixed-precision out-of-core Cholesky with static task scheduling.

Reproduction of "Accelerating Mixed-Precision Out-of-Core Cholesky
Factorization with Static Task Scheduling" on the JAX/Pallas stack, grown
toward a production-scale serving system (see ROADMAP.md).

Public surface — the two-phase planner/executor API::

    import repro

    cfg = repro.CholeskyConfig(tb=256, policy="v3")
    solver = repro.plan(n, cfg).compile()   # schedule + jit built once
    l = solver.factor(a)                    # replayed per matrix
    x = solver.solve(b)                     # blocked fwd/back substitution
    r = solver.simulate(repro.HW["gh200"])  # three-engine event model
    v = solver.volume()                     # exact byte-volume report

The one-shot :func:`ooc_cholesky` remains as a deprecated shim.

Autotuning (0.4): leave dimensions open and the planner resolves them —
``repro.plan(n, CholeskyConfig(tb=0, policy="auto", hw="gh200"))`` picks
tile size, policy, and cache budget by exact-simulation search; see
:mod:`repro.tune` for hardware calibration and explicit campaigns.

Multi-device (0.5): ``CholeskyConfig(ndev=4)`` runs one static op
stream per device — 1D tile-row ownership by default, or a 2D
block-cyclic grid (``grid=(2, 2)``) whose scoped broadcasts cut the
interconnect volume to O(sqrt(P)); the tuner searches the grid shape
when it is left open.

Lookahead pipelining (0.6): schedules are built from an explicit tile
task DAG (:mod:`repro.core.taskgraph`) by a topological emitter;
``CholeskyConfig(ndev=4, grid=(2, 2), lookahead=2)`` interleaves up to
``lookahead`` panel columns ahead of the trailing update with eager
peer pushes, closing the 2D grid's compute-bound makespan gap (the
tuner searches the depth when it is left open).

Serving (0.7): :mod:`repro.serve` puts a concurrent front end on the
plan cache — ``SolverService`` admits mixed factor/solve/logdet traffic
from many tenants, pools per-session solvers over shared plans, batches
concurrent single-RHS solves into stacked multi-RHS sweeps, and
schedules device memory across tenants; ``solve(B)`` itself now takes
``(n, k)`` stacked right-hand sides.  The ``docs/`` tree (architecture,
schedule-format, multidevice, tuning, serving, spill) is the narrative
documentation; its code blocks are executed by CI.

Disk spill tier + restart (0.8): ``CholeskyConfig(host_slots=H)`` bounds
*host* residency the same way ``cache_slots`` bounds device residency —
the tile store lives on disk (:class:`DiskTileStore`), the builder
post-pass interleaves static ``FETCH``/``SPILL`` ops, and matrices larger
than host memory factor end-to-end.  The repaired
:mod:`repro.checkpoint` persists progress at column boundaries keyed by
the schedule digest; :class:`RestartableFactorization` resumes a killed
run — mid-column included, via a tile undo journal — to a bit-identical
factor (docs/spill.md).

Observability (0.9): :mod:`repro.obs` measures what the simulator
predicts — ``factor(a, trace=TraceRecorder())`` records one fenced span
per executed op on every executor, exports it in the simulator's
chrome://tracing lane vocabulary, and ``drift_report`` aligns it op-by-op
against ``simulate``/``simulate_multi``;
``tune.calibrate(refine_from=trace)`` refits the hardware model from the
measured spans.  The process-wide metrics registry
(``repro.obs.snapshot()``) absorbs plan-cache, solver, and serve
counters (docs/observability.md).
"""
from repro.core.analytics import (HW, HardwareModel, ascii_trace,
                                  chrome_trace, crosscheck_executed_volume,
                                  simulate, simulate_multi, volume_report,
                                  volume_report_multi)
from repro.core.api import (CholeskyConfig, CholeskyPlan, OOCSolver,
                            clear_plan_cache, plan, plan_cache_stats)
from repro.core.cholesky import (MultiDeviceJaxExecutor, SpillJaxExecutor,
                                 make_multidevice_jax_executor, ooc_cholesky,
                                 plan_for_matrix, run_multidevice_spill,
                                 run_schedule_spill)
from repro.core.spill import (ArrayTileStore, DiskTileStore,
                              SpilledHostStore, host_residency_at)
from repro.checkpoint import (CheckpointManager, RestartableFactorization,
                              TileJournal)
from repro.core.precision import (LADDERS, PrecisionPlan, assign_precision,
                                  uniform_plan)
from repro.core.schedule import (MultiDeviceSchedule, Op, OpKind, Schedule,
                                 build_multidevice_schedule, build_schedule)
from repro.core.taskgraph import build_task_dag, verify_dispatch
from repro.core.tiling import TileLayout, from_tiles, random_spd, to_tiles
from repro import obs, serve, tune
from repro.obs import NullRecorder, TraceRecorder, drift_report
from repro.serve import SolverService

__version__ = "0.10.0"

__all__ = [
    "__version__",
    # planner/executor API
    "CholeskyConfig", "CholeskyPlan", "OOCSolver", "plan", "clear_plan_cache",
    "plan_cache_stats",
    # executors
    "MultiDeviceJaxExecutor", "make_multidevice_jax_executor",
    "SpillJaxExecutor", "run_schedule_spill", "run_multidevice_spill",
    # disk tier + checkpoint/restart
    "DiskTileStore", "ArrayTileStore", "SpilledHostStore",
    "host_residency_at", "CheckpointManager", "RestartableFactorization",
    "TileJournal",
    # one-shot shim + precision planning
    "ooc_cholesky", "plan_for_matrix",
    "PrecisionPlan", "assign_precision", "uniform_plan", "LADDERS",
    # schedules + task DAG
    "Schedule", "MultiDeviceSchedule", "Op", "OpKind",
    "build_schedule", "build_multidevice_schedule",
    "build_task_dag", "verify_dispatch",
    # analytics
    "HardwareModel", "HW", "simulate", "simulate_multi",
    "volume_report", "volume_report_multi", "ascii_trace", "chrome_trace",
    "crosscheck_executed_volume",
    # autotuner
    "tune",
    # serving
    "serve", "SolverService",
    # observability
    "obs", "TraceRecorder", "NullRecorder", "drift_report",
    # tiling
    "TileLayout", "to_tiles", "from_tiles", "random_spd",
]
