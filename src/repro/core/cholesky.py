"""Executors for the statically scheduled OOC tile Cholesky.

Two interpreters for the :class:`~repro.core.schedule.Schedule` op stream:

* ``run_schedule_numpy``  — plain NumPy oracle (any size, any policy).
* ``run_schedule_jax``    — the op stream is *unrolled into a single jit*:
  LOAD/STORE become dynamic slices between the host tile store and a bounded
  ``slots`` buffer (the "GPU memory"); compute ops run on slots.  On TPU the
  host store is placed with ``memory_kind='pinned_host'`` so the LOAD/STORE
  slices lower to asynchronous host<->HBM DMAs that XLA overlaps with the
  MXU work — the TPU equivalent of the paper's multi-stream ``async`` engine
  (DESIGN.md §2).  On CPU the same program runs with a device-resident store.

Mixed precision: LOAD casts host(f64) -> tile class -> compute dtype, i.e.
the interconnect carries class-precision bytes ("on-the-fly down-casting",
paper §IV-C).  STORE rounds the finished tile through its class, and the
rounded value is also written back to the slot so that later consumers see
exactly what the paper's low-precision device tile would contain.

Public API migration (0.2): the one-shot :func:`ooc_cholesky` is a
deprecated shim over the two-phase planner/executor API in
:mod:`repro.core.api` — build a frozen config once, then reuse the
compiled solver across same-shape factorizations::

    solver = repro.plan(n, repro.CholeskyConfig(tb=256, policy="v3")).compile()
    l = solver.factor(a)        # schedule + jit amortized across calls
    x = solver.solve(b)         # blocked triangular substitution

Old kwarg -> new config field: ``tb/policy/eps_target/ladder/cache_slots/
compute_dtype/use_pallas/block/ndev`` map 1:1 onto
:class:`~repro.core.api.CholeskyConfig` fields of the same name;
``backend`` gains an ``"auto"`` default (jax single-device, numpy
multi-device), and combinations the old entry point silently ignored for
``ndev > 1`` (explicit ``backend="jax"``, ``compute_dtype``,
``use_pallas``) now raise at config construction.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
import ml_dtypes

from .schedule import MultiDeviceSchedule, Op, OpKind, Schedule
from .precision import PrecisionPlan, assign_precision, tile_norms, uniform_plan

_NP_DTYPES = {
    "f64": np.float64,
    "f32": np.float32,
    "f16": np.float16,
    "bf16": ml_dtypes.bfloat16,
    "f8e4m3": ml_dtypes.float8_e4m3fn,
}
_JNP_DTYPES = {
    "f64": jnp.float64,
    "f32": jnp.float32,
    "f16": jnp.float16,
    "bf16": jnp.bfloat16,
    "f8e4m3": jnp.float8_e4m3fn,
}


# --------------------------------------------------------------------------
# NumPy oracle
# --------------------------------------------------------------------------

def _np_round(x: np.ndarray, cls_name: str) -> np.ndarray:
    return x.astype(_NP_DTYPES[cls_name]).astype(x.dtype)


def _np_interpret_op(host: np.ndarray, slots: np.ndarray, op: Op,
                     lad: tuple) -> None:
    """Execute one op against the shared host store and a slot buffer.

    The single numerical semantics for both the single-device and the
    multi-device replay (a RECV is a LOAD whose bytes crossed the
    interconnect instead of the host link — the class round-trip is the
    same; BCAST/ALLOC/FREE are bookkeeping-only)."""
    if op.kind is OpKind.LOAD or op.kind is OpKind.RECV:
        slots[op.slot_c] = _np_round(host[op.i, op.j], lad[op.cls])
    elif op.kind is OpKind.STORE:
        rounded = _np_round(slots[op.slot_c], lad[op.cls])
        slots[op.slot_c] = rounded
        host[op.i, op.j] = rounded
    elif op.kind is OpKind.SYRK:
        a = slots[op.slot_a]
        slots[op.slot_c] = slots[op.slot_c] - a @ a.T
    elif op.kind is OpKind.GEMM:
        slots[op.slot_c] = slots[op.slot_c] - slots[op.slot_a] @ slots[op.slot_b].T
    elif op.kind is OpKind.POTRF:
        slots[op.slot_c] = np.linalg.cholesky(
            0.5 * (slots[op.slot_c] + slots[op.slot_c].T))
    elif op.kind is OpKind.TRSM:
        import scipy.linalg as sla
        l = slots[op.slot_a]
        slots[op.slot_c] = sla.solve_triangular(
            l, slots[op.slot_c].T, lower=True).T


def run_schedule_numpy(host_tiles: np.ndarray, sched: Schedule) -> np.ndarray:
    """Interpret the op stream with NumPy.  Returns the factored tile store."""
    host = host_tiles.astype(np.float64).copy()
    tb = sched.tb
    nslots = max(max(o.slot_c, o.slot_a, o.slot_b) for o in sched.ops) + 1
    slots = np.zeros((nslots, tb, tb), dtype=np.float64)
    lad = sched.plan.ladder
    for op in sched.ops:
        _np_interpret_op(host, slots, op, lad)
    return host


def run_multidevice_numpy(host_tiles: np.ndarray,
                          msched: MultiDeviceSchedule) -> np.ndarray:
    """Interpret all per-device op streams against one host tile store.

    Each device gets its own slot buffer; the streams are replayed in
    :meth:`MultiDeviceSchedule.iter_column_order` (column-by-column,
    owner first), so every RECV observes the owner's finalized
    (host-coherent) panel-row tile.
    """
    host = host_tiles.astype(np.float64).copy()
    tb = msched.tb
    lad = msched.plan.ladder
    slots = []
    for stream in msched.streams:
        ns = max((max(o.slot_c, o.slot_a, o.slot_b) for o in stream),
                 default=-1) + 1
        slots.append(np.zeros((ns, tb, tb), dtype=np.float64))
    for d, op in msched.iter_column_order():
        _np_interpret_op(host, slots[d], op, lad)
    return host


# --------------------------------------------------------------------------
# JAX executor (single jit, schedule unrolled)
# --------------------------------------------------------------------------

def _jx_round(x, cls_name, compute_dtype):
    if _JNP_DTYPES[cls_name] == compute_dtype:
        return x
    if cls_name == "f64" and not jax.config.jax_enable_x64:
        return x  # f64 class degrades to compute dtype when x64 is off
    return x.astype(_JNP_DTYPES[cls_name]).astype(compute_dtype)


def _trsm_jax(l, c):
    # X L^T = C  =>  L X^T = C^T
    return jax.scipy.linalg.solve_triangular(l, c.T, lower=True).T


def _make_kernel_fns(use_pallas: bool, interpret: bool):
    if not use_pallas:
        return {
            "potrf": lambda c: jnp.linalg.cholesky(0.5 * (c + c.T)),
            "trsm": _trsm_jax,
            "syrk": lambda c, a: c - a @ a.T,
            "gemm": lambda c, a, b: c - a @ b.T,
        }
    from repro.kernels import ops as kops
    return {
        "potrf": partial(kops.potrf, interpret=interpret),
        "trsm": partial(kops.trsm, interpret=interpret),
        "syrk": partial(kops.syrk_update, interpret=interpret),
        "gemm": partial(kops.gemm_update, interpret=interpret),
    }


def make_jax_executor(sched: Schedule, compute_dtype=jnp.float64,
                      use_pallas: bool = False, interpret: bool = True):
    """Build a jit-able ``host_tiles -> factored host_tiles`` function.

    The returned function's HLO contains exactly the transfers of the static
    schedule; everything else (overlap, async copies) is XLA's job — the
    deterministic-schedule insight of the paper moved to trace time.
    """
    tb = sched.tb
    lad = sched.plan.ladder
    nslots = max(max(o.slot_c, o.slot_a, o.slot_b) for o in sched.ops) + 1
    kf = _make_kernel_fns(use_pallas, interpret)

    def run(host_tiles):
        host = host_tiles.astype(compute_dtype)
        slots = jnp.zeros((nslots, tb, tb), dtype=compute_dtype)

        def get(s):
            return slots[s]

        for op in sched.ops:
            if op.kind is OpKind.LOAD:
                t = _jx_round(host[op.i, op.j], lad[op.cls], compute_dtype)
                slots = slots.at[op.slot_c].set(t)
            elif op.kind is OpKind.STORE:
                r = _jx_round(get(op.slot_c), lad[op.cls], compute_dtype)
                slots = slots.at[op.slot_c].set(r)
                host = host.at[op.i, op.j].set(r)
            elif op.kind is OpKind.SYRK:
                slots = slots.at[op.slot_c].set(kf["syrk"](get(op.slot_c), get(op.slot_a)))
            elif op.kind is OpKind.GEMM:
                slots = slots.at[op.slot_c].set(
                    kf["gemm"](get(op.slot_c), get(op.slot_a), get(op.slot_b)))
            elif op.kind is OpKind.POTRF:
                slots = slots.at[op.slot_c].set(kf["potrf"](get(op.slot_c)))
            elif op.kind is OpKind.TRSM:
                slots = slots.at[op.slot_c].set(kf["trsm"](get(op.slot_a), get(op.slot_c)))
        return host

    return run


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------

def plan_for_matrix(a_tiles: np.ndarray, eps_target: float | None,
                    ladder: str = "tpu") -> PrecisionPlan:
    nt = a_tiles.shape[0]
    if eps_target is None:
        return uniform_plan(nt, "f64", ladder)
    norms, total = tile_norms(a_tiles)
    return assign_precision(norms, total, eps_target, ladder)


def ooc_cholesky(
    a: np.ndarray,
    tb: int,
    policy: str = "v3",
    eps_target: float | None = None,
    ladder: str = "tpu",
    cache_slots: int = 0,
    backend: str | None = None,
    compute_dtype=None,
    use_pallas: bool = False,
    block: tuple = (4, 4),
    ndev: int = 1,
) -> tuple[np.ndarray, MultiDeviceSchedule]:
    """One-shot out-of-core Cholesky — deprecated shim over the planner API.

    .. deprecated:: 0.2
       Use ``repro.plan(n, CholeskyConfig(...)).compile()`` instead: the
       static schedule and jitted executor are then built once and reused
       across every same-shape factorization.  Kwarg migration:

       ============== ===========================================
       old kwarg      CholeskyConfig field
       ============== ===========================================
       tb             ``tb``
       policy         ``policy``
       eps_target     ``eps_target`` (freeze via ``specialize(a)``)
       ladder         ``ladder``
       cache_slots    ``cache_slots``
       backend        ``backend`` (new default ``"auto"``)
       compute_dtype  ``compute_dtype``
       use_pallas     ``use_pallas``
       block          ``block``
       ndev           ``ndev``
       ============== ===========================================

    Returns ``(L, schedule)`` with L lower-triangular (upper part zeroed)
    and ``schedule`` the unified
    :class:`~repro.core.schedule.MultiDeviceSchedule` (ndev=1 degenerate
    for the single-device path) carrying the exact data-movement record.

    Unsupported combinations now raise eagerly from config validation —
    notably ``ndev > 1`` with an explicit ``backend="jax"``,
    ``compute_dtype``, or ``use_pallas``, which the pre-0.2 API silently
    ignored.
    """
    import warnings

    from .api import CholeskyConfig, plan as _plan

    warnings.warn(
        "ooc_cholesky() is deprecated: use "
        "repro.plan(n, CholeskyConfig(...)).compile() to amortize the "
        "schedule build and jit across factorizations",
        DeprecationWarning, stacklevel=2)
    a = np.asarray(a, dtype=np.float64)
    cfg = CholeskyConfig(
        tb=tb, policy=policy, eps_target=eps_target, ladder=ladder,
        cache_slots=cache_slots, backend=backend or "auto",
        compute_dtype=compute_dtype, use_pallas=use_pallas, block=block,
        ndev=ndev,
    ).specialize(a)
    solver = _plan(a.shape[0], cfg).compile()
    return solver.factor(a), solver.schedule
