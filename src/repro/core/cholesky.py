"""Executors for the statically scheduled OOC tile Cholesky.

Three executors over the static op streams:

* ``run_schedule_numpy`` / ``run_multidevice_numpy`` — plain NumPy
  oracles (any size, any policy; one host store shared by all streams).
* ``make_jax_executor``   — the op stream is *unrolled into a single jit*:
  LOAD/STORE become dynamic slices between the host tile store and a bounded
  ``slots`` buffer (the "GPU memory"); compute ops run on slots.  On TPU the
  host store is placed with ``memory_kind='pinned_host'`` so the LOAD/STORE
  slices lower to asynchronous host<->HBM DMAs that XLA overlaps with the
  MXU work — the TPU equivalent of the paper's multi-stream ``async`` engine
  (DESIGN.md §2).  On CPU the same program runs with a device-resident store.
* ``make_multidevice_jax_executor`` — the per-device op streams of a
  :class:`~repro.core.schedule.MultiDeviceSchedule` on real JAX devices:
  one jitted column-segment sequence per device (same unrolled machinery
  and kernel fns as the single-device executor), the BCAST/RECV edges
  lowered to class-precision ``jax.device_put`` transfers into each
  peer's dedicated panel slot (see :class:`MultiDeviceJaxExecutor`).

Mixed precision: LOAD casts host(f64) -> tile class -> compute dtype, i.e.
the interconnect carries class-precision bytes ("on-the-fly down-casting",
paper §IV-C).  STORE rounds the finished tile through its class, and the
rounded value is also written back to the slot so that later consumers see
exactly what the paper's low-precision device tile would contain.

Public API migration (0.2): the one-shot :func:`ooc_cholesky` is a
deprecated shim over the two-phase planner/executor API in
:mod:`repro.core.api` — build a frozen config once, then reuse the
compiled solver across same-shape factorizations::

    solver = repro.plan(n, repro.CholeskyConfig(tb=256, policy="v3")).compile()
    l = solver.factor(a)        # schedule + jit amortized across calls
    x = solver.solve(b)         # blocked triangular substitution

Old kwarg -> new config field: ``tb/policy/eps_target/ladder/cache_slots/
compute_dtype/use_pallas/block/ndev`` map 1:1 onto
:class:`~repro.core.api.CholeskyConfig` fields of the same name;
``backend`` gains an ``"auto"`` default: jax single-device, and for
``ndev > 1`` jax whenever the process sees at least ``ndev`` devices
(the per-device executor) with the NumPy host replay as the fallback.
An explicit ``backend="jax"`` with too few visible devices raises at
``compile()``.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
import ml_dtypes

from .schedule import (HOST_IO, MultiDeviceSchedule, Op, OpKind, Schedule,
                       grid_owner)
from .precision import (PrecisionPlan, assign_precision, tile_norms,
                        uniform_plan)
from .precision import tile_amax as _tile_amax

_NP_DTYPES = {
    "f64": np.float64,
    "f32": np.float32,
    "f16": np.float16,
    "bf16": ml_dtypes.bfloat16,
    "f8e4m3": ml_dtypes.float8_e4m3fn,
    # the *scaled* FP8 class stores the same e4m3 payload; the per-tile
    # power-of-two scale applied around the cast is what differs
    "f8e4m3s": ml_dtypes.float8_e4m3fn,
}
_JNP_DTYPES = {
    "f64": jnp.float64,
    "f32": jnp.float32,
    "f16": jnp.float16,
    "bf16": jnp.bfloat16,
    "f8e4m3": jnp.float8_e4m3fn,
    "f8e4m3s": jnp.float8_e4m3fn,
}


# --------------------------------------------------------------------------
# NumPy oracle
# --------------------------------------------------------------------------

def _np_fp8_scale(amax: float) -> float:
    """Store-time power-of-two scale of a scaled-FP8 tile (the frexp form
    of :func:`repro.core.precision.fp8_scale` — see the jax twin
    ``fused_column._fp8_scale_of`` for why frexp and not log2/floor)."""
    if not amax > 0.0 or not np.isfinite(amax):
        return 1.0
    m, e = np.frexp(amax)
    return float(2.0 ** (int(8 - e) + (1 if m <= 0.875 else 0)))


def _np_round(x: np.ndarray, cls_name: str) -> np.ndarray:
    if cls_name == "f8e4m3s":
        s = _np_fp8_scale(float(np.max(np.abs(x))))
        return ((x * s).astype(_NP_DTYPES[cls_name]).astype(x.dtype)) / s
    return x.astype(_NP_DTYPES[cls_name]).astype(x.dtype)


def _np_interpret_op(host: np.ndarray, slots: np.ndarray, op: Op,
                     lad: tuple) -> None:
    """Execute one op against the shared host store and a slot buffer.

    The single numerical semantics for both the single-device and the
    multi-device replay (a RECV is a LOAD whose bytes crossed the
    interconnect instead of the host link — the class round-trip is the
    same; BCAST/ALLOC/FREE are bookkeeping-only).  A host-landing RECV
    (``slot_c < 0``, the 2D grid's row-scoped ownership broadcast) moves
    a finalized tile between per-device host slabs; against the replay's
    *shared* host store it is coherence bookkeeping with no effect.

    FETCH/SPILL (the disk tier) delegate to the host store object: a
    spill schedule is replayed against a
    :class:`repro.core.spill.SpilledHostStore` instead of the full
    ``[Nt, Nt, tb, tb]`` array — both support the same ``host[i, j]``
    tile indexing, so every other branch is tier-agnostic."""
    if op.kind is OpKind.FETCH:
        host.fetch(op)
    elif op.kind is OpKind.SPILL:
        host.spill(op)
    elif op.kind is OpKind.LOAD or op.kind is OpKind.RECV:
        if op.slot_c < 0:
            return
        slots[op.slot_c] = _np_round(host[op.i, op.j], lad[op.cls])
    elif op.kind is OpKind.STORE:
        rounded = _np_round(slots[op.slot_c], lad[op.cls])
        slots[op.slot_c] = rounded
        host[op.i, op.j] = rounded
    elif op.kind is OpKind.SYRK:
        a = slots[op.slot_a]
        slots[op.slot_c] = slots[op.slot_c] - a @ a.T
    elif op.kind is OpKind.GEMM:
        slots[op.slot_c] = slots[op.slot_c] - slots[op.slot_a] @ slots[op.slot_b].T
    elif op.kind is OpKind.POTRF:
        slots[op.slot_c] = np.linalg.cholesky(
            0.5 * (slots[op.slot_c] + slots[op.slot_c].T))
    elif op.kind is OpKind.TRSM:
        import scipy.linalg as sla
        l = slots[op.slot_a]
        slots[op.slot_c] = sla.solve_triangular(
            l, slots[op.slot_c].T, lower=True).T


def _device_nslots(ops) -> int:
    return max((max(o.slot_c, o.slot_a, o.slot_b)
                for o in ops if o.kind not in HOST_IO), default=-1) + 1


def run_schedule_numpy(host_tiles: np.ndarray, sched: Schedule,
                       trace=None) -> np.ndarray:
    """Interpret the op stream with NumPy.  Returns the factored tile store.

    A spill schedule (``host_slots > 0``) is replayed through a bounded
    host cache over an in-memory backing store with the disk store's
    interface — convenient for equivalence tests; use
    :func:`run_schedule_spill` to drive a real on-disk
    :class:`~repro.core.spill.DiskTileStore`.

    ``trace``: an active :class:`repro.obs.trace.TraceRecorder` records
    one measured span per op (NumPy is synchronous, so no fencing is
    needed); ``None`` or an inactive recorder leaves the replay loop
    untouched.
    """
    if sched.host_slots > 0:
        from .spill import ArrayTileStore
        store = ArrayTileStore(host_tiles)
        run_schedule_spill(store, sched, trace=trace)
        return store.to_tiles()
    host = host_tiles.astype(np.float64).copy()
    tb = sched.tb
    nslots = _device_nslots(sched.ops)
    slots = np.zeros((nslots, tb, tb), dtype=np.float64)
    lad = sched.plan.ladder
    if trace is not None and getattr(trace, "active", False):
        for idx, op in enumerate(sched.ops):
            t0 = trace.now()
            _np_interpret_op(host, slots, op, lad)
            trace.record(idx, op.kind.value, 0, t0, trace.now(), op.bytes,
                         lad[op.cls], op.i, op.j)
        return host
    for op in sched.ops:
        _np_interpret_op(host, slots, op, lad)
    return host


def run_schedule_spill(store, sched: Schedule, trace=None):
    """Replay a spill schedule against a disk-backed tile store in place.

    ``store`` is a :class:`~repro.core.spill.DiskTileStore` (or anything
    with its tile interface) holding the input matrix tiles; on return it
    holds the factored tiles.  Host memory use is bounded: one
    ``[host_slots, tb, tb]`` slab cache plus the device slot buffer.
    Returns the :class:`~repro.core.spill.SpilledHostStore` (its
    fetched/spilled byte counters crosscheck the schedule).  An active
    ``trace`` recorder gets one measured span per op, disk I/O included.
    """
    from .spill import SpilledHostStore
    if sched.host_slots < 1:
        raise ValueError("run_schedule_spill needs a spill schedule "
                         "(build with host_slots > 0)")
    host = SpilledHostStore(store, sched.host_slots)
    slots = np.zeros((_device_nslots(sched.ops), sched.tb, sched.tb),
                     dtype=np.float64)
    lad = sched.plan.ladder
    if trace is not None and getattr(trace, "active", False):
        for idx, op in enumerate(sched.ops):
            t0 = trace.now()
            _np_interpret_op(host, slots, op, lad)
            trace.record(idx, op.kind.value, 0, t0, trace.now(), op.bytes,
                         lad[op.cls], op.i, op.j)
    else:
        for op in sched.ops:
            _np_interpret_op(host, slots, op, lad)
    store.flush()
    return host


def run_multidevice_numpy(host_tiles: np.ndarray,
                          msched: MultiDeviceSchedule,
                          trace=None) -> np.ndarray:
    """Interpret all per-device op streams against one host tile store.

    Each device gets its own slot buffer; the streams are replayed in
    :meth:`MultiDeviceSchedule.iter_dispatch_order` (column-major with
    the owner first for ``lookahead = 0``, the emitter's pipelined chunk
    order otherwise), so every RECV observes the sender's finalized
    (host-coherent) tile.  An active ``trace`` recorder gets one span per
    op, tagged with its device stream and dispatch phase.
    """
    if msched.host_slots > 0:
        from .spill import ArrayTileStore
        store = ArrayTileStore(host_tiles)
        run_multidevice_spill(store, msched, trace=trace)
        return store.to_tiles()
    host = host_tiles.astype(np.float64).copy()
    tb = msched.tb
    lad = msched.plan.ladder
    slots = [np.zeros((msched.stream_nslots(d), tb, tb), dtype=np.float64)
             for d in range(msched.ndev)]
    if trace is not None and getattr(trace, "active", False):
        for idx, (d, op, phase) in enumerate(
                msched.iter_dispatch_order(with_phase=True)):
            t0 = trace.now()
            _np_interpret_op(host, slots[d], op, lad)
            trace.record(idx, op.kind.value, d, t0, trace.now(), op.bytes,
                         lad[op.cls], op.i, op.j, phase)
        return host
    for d, op in msched.iter_column_order():
        _np_interpret_op(host, slots[d], op, lad)
    return host


def run_multidevice_spill(store, msched: MultiDeviceSchedule, trace=None):
    """Replay a multi-device spill schedule against one shared tile store.

    Each device bounds its own host tier (one
    :class:`~repro.core.spill.SpilledHostStore` per stream) over the
    single shared disk store — per-device host accesses are disjoint or
    replicated-final, so a shared backing tier is coherent.  Unlike the
    plain replay, the shared-host shortcut for broadcasts is gone: a
    BCAST snapshots the sender's resident slab onto a wire keyed
    ``(i, j, k, src)`` (exactly the JAX executor's wire table) and each
    RECV consumes the wire — into a panel slot (class-rounded) or, for
    the row-scoped host-landing RECV, into the receiver's own slab.
    Returns the per-device host stores (fetch/spill counters).
    """
    from .spill import SpilledHostStore
    if msched.host_slots < 1:
        raise ValueError("run_multidevice_spill needs a spill schedule "
                         "(build with host_slots > 0)")
    tb = msched.tb
    lad = msched.plan.ladder
    hosts = [SpilledHostStore(store, msched.host_slots)
             for _ in range(msched.ndev)]
    slots = [np.zeros((msched.stream_nslots(d), tb, tb), dtype=np.float64)
             for d in range(msched.ndev)]
    wires: dict = {}
    recording = trace is not None and getattr(trace, "active", False)
    for idx, (d, op, phase) in enumerate(
            msched.iter_dispatch_order(with_phase=True)):
        t0 = trace.now() if recording else 0
        if op.kind is OpKind.BCAST:
            wires[(op.i, op.j, op.k, op.src)] = np.array(hosts[d][op.i, op.j])
        elif op.kind is OpKind.RECV:
            t = wires[(op.i, op.j, op.k, op.src)]
            if op.slot_c >= 0:
                slots[d][op.slot_c] = _np_round(t, lad[op.cls])
            else:
                hosts[d][op.i, op.j] = t
        else:
            _np_interpret_op(hosts[d], slots[d], op, lad)
        if recording:
            trace.record(idx, op.kind.value, d, t0, trace.now(), op.bytes,
                         lad[op.cls], op.i, op.j, phase)
    store.flush()
    return hosts


# --------------------------------------------------------------------------
# JAX executor (single jit, schedule unrolled)
# --------------------------------------------------------------------------

def _jx_fp8_scale(amax, compute_dtype):
    """Store-time power-of-two scale (jax twin of :func:`_np_fp8_scale`;
    frexp keeps the two bitwise-identical across backends)."""
    m, e = jnp.frexp(amax)
    exp = (8 - e) + jnp.where(m <= 0.875, 1, 0)
    s = jnp.exp2(exp.astype(compute_dtype))
    ok = jnp.isfinite(amax) & (amax > 0)
    return jnp.where(ok, s, jnp.asarray(1.0, compute_dtype))


def _jx_round(x, cls_name, compute_dtype):
    if _JNP_DTYPES[cls_name] == compute_dtype:
        return x
    if cls_name == "f64" and not jax.config.jax_enable_x64:
        return x  # f64 class degrades to compute dtype when x64 is off
    if cls_name == "f8e4m3s":
        s = _jx_fp8_scale(jnp.max(jnp.abs(x)), compute_dtype)
        return ((x * s).astype(_JNP_DTYPES[cls_name])
                .astype(compute_dtype)) / s
    return x.astype(_JNP_DTYPES[cls_name]).astype(compute_dtype)


def _trsm_jax(l, c):
    # X L^T = C  =>  L X^T = C^T
    return jax.scipy.linalg.solve_triangular(l, c.T, lower=True).T


def _make_kernel_fns(use_pallas: bool, interpret: bool):
    from repro.kernels.fused_column import count_tile_op

    def counted(fn):
        # trace-time dispatch counter, symmetric with the fused path's
        # launch accounting (repro.kernels.fused_column.launch_counts)
        def wrapped(*args):
            count_tile_op()
            return fn(*args)
        return wrapped

    if not use_pallas:
        fns = {
            "potrf": lambda c: jnp.linalg.cholesky(0.5 * (c + c.T)),
            "trsm": _trsm_jax,
            "syrk": lambda c, a: c - a @ a.T,
            "gemm": lambda c, a, b: c - a @ b.T,
        }
    else:
        from repro.kernels import ops as kops
        fns = {
            "potrf": partial(kops.potrf, interpret=interpret),
            "trsm": partial(kops.trsm, interpret=interpret),
            "syrk": partial(kops.syrk_update, interpret=interpret),
            "gemm": partial(kops.gemm_update, interpret=interpret),
        }
    return {name: counted(fn) for name, fn in fns.items()}


def _jx_interpret_op(host, slots, op: Op, lad, kf, compute_dtype, lrow):
    """Trace one op against a (host store, slot buffer) pair.

    The single unrolled-op semantics shared by the single-device executor
    and every per-device segment of the multi-device executor; ``lrow``
    maps a global tile row to the host store's row index (identity for a
    full store, ``i // ndev`` for a device's block-cyclic row slab).
    Returns the updated ``(host, slots)``.
    """
    if op.kind is OpKind.LOAD:
        t = _jx_round(host[lrow(op.i), op.j], lad[op.cls], compute_dtype)
        slots = slots.at[op.slot_c].set(t)
    elif op.kind is OpKind.STORE:
        r = _jx_round(slots[op.slot_c], lad[op.cls], compute_dtype)
        slots = slots.at[op.slot_c].set(r)
        host = host.at[lrow(op.i), op.j].set(r)
    elif op.kind is OpKind.SYRK:
        slots = slots.at[op.slot_c].set(
            kf["syrk"](slots[op.slot_c], slots[op.slot_a]))
    elif op.kind is OpKind.GEMM:
        slots = slots.at[op.slot_c].set(
            kf["gemm"](slots[op.slot_c], slots[op.slot_a], slots[op.slot_b]))
    elif op.kind is OpKind.POTRF:
        slots = slots.at[op.slot_c].set(kf["potrf"](slots[op.slot_c]))
    elif op.kind is OpKind.TRSM:
        slots = slots.at[op.slot_c].set(
            kf["trsm"](slots[op.slot_a], slots[op.slot_c]))
    return host, slots


# --------------------------------------------------------------------------
# Fused column-step tracing (CholeskyConfig.fuse_columns)
# --------------------------------------------------------------------------
#
# The unfused trace dispatches one kernel per tile op.  The fused trace
# groups the compute ops of one column step (same ``op.k``) and replaces
# the whole group — SYRK wave + POTRF on the diagonal, GEMM wave + TRSM
# per row — with a single ``fused_column_step`` pallas launch
# (repro.kernels.fused_column).  LOAD/STORE/ALLOC/FREE are *not* fused:
# the data-movement record (bytes, digests, crosschecks) is the
# schedule's contract and stays op-for-op identical; LOADs execute ahead
# of the group and STOREs are deferred behind it, with explicit hazard
# checks forcing a flush whenever the reordering could be observed.

_FUSABLE = (OpKind.SYRK, OpKind.GEMM, OpKind.POTRF, OpKind.TRSM)


def _parse_column_group(group):
    """Match one column step's pending group against the canonical
    pattern the megakernel implements; ``None`` means run it per-op.

    Expected compute shape: an optional diagonal phase (SYRKs into one
    slot, then POTRF on it) followed by zero or more rows (GEMMs into one
    slot, then TRSM on it against the column's diagonal slot), with a
    uniform history depth and identical B-operand slot sequence across
    rows (the fused grid batches the rows over one shared B stack).
    STOREs riding in the group must be expressible as the launch
    epilogue: at most one per slot, positioned after the slot's last
    compute (the diagonal's directly after its POTRF — the row TRSMs
    then solve against the epilogue-rounded scratch factor).  Anything
    else — advance-update chunks of a lookahead schedule, v4 block
    phases, slot-reuse corner cases, mid-accumulation partial stores —
    falls back to the per-op interpreter.
    """
    ops = [op for op, _s in group if op.kind is not OpKind.STORE]
    last_compute_pos = {}
    for pos, (op, _s) in enumerate(group):
        if op.kind is not OpKind.STORE:
            last_compute_pos[op.slot_c] = pos
    store_of = {}
    for pos, (op, _s) in enumerate(group):
        if op.kind is OpKind.STORE:
            if op.slot_c in store_of:       # two roundings of one slot
                return None
            if pos < last_compute_pos.get(op.slot_c, -1):
                return None                 # mid-accumulation store
            store_of[op.slot_c] = op
    idx, n = 0, len(ops)
    syrks: list = []
    potrf = None
    while idx < n and ops[idx].kind is OpKind.SYRK:
        syrks.append(ops[idx])
        idx += 1
    if idx < n and ops[idx].kind is OpKind.POTRF:
        potrf = ops[idx]
        idx += 1
        if any(o.slot_c != potrf.slot_c for o in syrks):
            return None
    elif syrks:
        return None
    rows = []
    while idx < n:
        gemms: list = []
        while idx < n and ops[idx].kind is OpKind.GEMM:
            gemms.append(ops[idx])
            idx += 1
        if idx >= n or ops[idx].kind is not OpKind.TRSM:
            return None
        trsm = ops[idx]
        idx += 1
        if any(o.slot_c != trsm.slot_c for o in gemms):
            return None
        rows.append((gemms, trsm))
    with_diag = potrf is not None
    if not with_diag and not rows:
        return None
    k_steps = len(syrks) if with_diag else len(rows[0][0])
    bslots = ([o.slot_a for o in syrks] if with_diag
              else [o.slot_b for o in rows[0][0]])
    for gemms, _t in rows:
        if len(gemms) != k_steps or [o.slot_b for o in gemms] != bslots:
            return None
    if with_diag:
        diag_slot = potrf.slot_c
    else:
        diag_slot = rows[0][1].slot_a
    if any(t.slot_a != diag_slot for _g, t in rows):
        return None
    c_slots = ([diag_slot] if with_diag else []) + [t.slot_c for _g, t in rows]
    if len(set(c_slots)) != len(c_slots):
        return None
    if not set(store_of) <= set(c_slots):
        return None     # a store of a tile this launch doesn't produce
    operand_slots = set(bslots)
    for gemms, _t in rows:
        operand_slots.update(o.slot_a for o in gemms)
    if set(c_slots) & operand_slots:
        # an output slot doubling as a history operand: the operand
        # snapshot would be stale by the time the unfused order reads it
        return None
    return {"with_diag": with_diag, "potrf": potrf, "rows": rows,
            "syrks": syrks, "k_steps": k_steps, "bslots": bslots,
            "diag_slot": diag_slot, "c_slots": c_slots,
            "store_of": store_of}


def _flush_group_fused(group, c_init, slots, lad, cdt, kf, interpret):
    """Run one pending group: a single fused launch when it matches the
    column-step pattern, the per-op interpreter otherwise.

    ``group`` is a list of ``(op, snap)`` pairs — compute ops with their
    operand values captured at the op's stream position (see
    :func:`_run_ops_fused`) plus the column's STOREs — and ``c_init``
    maps each touched slot to its value when the group first saw it;
    together they reproduce the unfused read order exactly, no matter
    what LOADs ran in between.  Returns ``(slots, host_writes)`` where
    ``host_writes`` lists ``(store_op, rounded_tile)`` in stream order
    for the caller to apply to its host tier.
    """
    def val(t):
        return local[t[1]] if t[0] == "slot" else t[1]

    parsed = _parse_column_group(group)
    if parsed is None:
        # per-op replay over the snapshots (not the live slot buffer:
        # later hoisted LOADs may have re-used operand slots); STORE
        # roundings apply at their exact stream position
        local = dict(c_init)
        host_writes = []
        for op, snap in group:
            if op.kind is OpKind.STORE:
                r = _jx_round(local[op.slot_c], lad[op.cls], cdt)
                local[op.slot_c] = r
                host_writes.append((op, r))
            elif op.kind is OpKind.SYRK:
                local[op.slot_c] = kf["syrk"](local[op.slot_c],
                                              val(snap["a"]))
            elif op.kind is OpKind.GEMM:
                local[op.slot_c] = kf["gemm"](local[op.slot_c],
                                              val(snap["a"]),
                                              val(snap["b"]))
            elif op.kind is OpKind.POTRF:
                local[op.slot_c] = kf["potrf"](local[op.slot_c])
            elif op.kind is OpKind.TRSM:
                local[op.slot_c] = kf["trsm"](val(snap["l"]),
                                              local[op.slot_c])
        for s, v in local.items():
            slots = slots.at[s].set(v)
        return slots, host_writes

    from repro.kernels.fused_column import fused_column_step
    local = c_init     # markers can only name diag (parse rejects others)
    tb = slots.shape[1]
    snaps = {id(op): snap for op, snap in group}
    rows = parsed["rows"]
    with_diag = parsed["with_diag"]
    k_steps = parsed["k_steps"]
    c_slots = parsed["c_slots"]
    store_of = parsed["store_of"]
    c_stack = jnp.stack([c_init[s] for s in c_slots])
    if k_steps:
        hist_rows = [[val(snaps[id(o)]["a"]) for o in gemms]
                     for gemms, _t in rows]
        if with_diag:
            bhist_tiles = [val(snaps[id(o)]["a"]) for o in parsed["syrks"]]
            hist_rows = [bhist_tiles] + hist_rows
        else:
            bhist_tiles = [val(snaps[id(o)]["b"]) for o in rows[0][0]]
        hist = jnp.stack([jnp.stack(r) for r in hist_rows])
        bhist = jnp.stack(bhist_tiles)
    else:
        hist = jnp.zeros((len(c_slots), 0, tb, tb), dtype=cdt)
        bhist = jnp.zeros((0, tb, tb), dtype=cdt)
    l_kk = (jnp.zeros((tb, tb), dtype=cdt) if with_diag
            else val(snaps[id(rows[0][1])]["l"]))
    cls_ids = [store_of[s].cls if s in store_of else -1 for s in c_slots]
    out = fused_column_step(c_stack, hist, bhist, l_kk, cls_ids,
                            ladder=lad, with_diag=with_diag,
                            interpret=interpret)
    out = out.astype(cdt)
    slots = slots.at[jnp.asarray(c_slots)].set(out)
    row_of = {s: r for r, s in enumerate(c_slots)}
    host_writes = [(op, out[row_of[op.slot_c]])
                   for op, _s in group if op.kind is OpKind.STORE]
    return slots, host_writes


def _run_ops_fused(ops, host, slots, lad, cdt, kf, interpret,
                   read_host, write_host):
    """Trace an op stream with column-step fusion.

    ``read_host(host, op) -> tile`` / ``write_host(host, op, tile) ->
    host`` abstract the host tier (full store, block-cyclic slab, or
    spill slab buffer — the three executor contexts).  Compute ops of one
    column accumulate into a pending group launched as one megakernel.
    Each op's operands are *snapshotted at its stream position* (a slot
    marker when the operand is itself a pending group output), so LOADs
    that later re-use an operand slot need no flush — the executed read
    order is op-for-op that of the unfused trace.  STOREs are deferred
    behind the launch; the remaining hazards (a LOAD targeting a pending
    output slot or a host tile with a deferred STORE, a compute op
    reading a deferred-STORE slot before its in-place rounding) force a
    flush.  IO ops themselves are never fused — the schedule's
    data-movement record is preserved exactly.  Returns the updated
    ``(host, slots)``.
    """
    group: list = []        # (op, operand snapshots); STOREs ride along
    gwrite: set = set()     # slots the pending group writes (or rounds)
    c_init: dict = {}       # slot -> value at first group touch
    dtiles: set = set()     # host tiles with a pending in-group STORE

    def snap_operand(s):
        if s in gwrite:
            return ("slot", s)
        return ("val", slots[s])

    def flush():
        nonlocal host, slots
        if not group:
            return
        slots, host_writes = _flush_group_fused(group, c_init, slots,
                                                lad, cdt, kf, interpret)
        for o, r in host_writes:
            host = write_host(host, o, r)
        group.clear()
        gwrite.clear()
        c_init.clear()
        dtiles.clear()

    for op in ops:
        if op.kind is OpKind.LOAD:
            if op.slot_c in gwrite or (op.i, op.j) in dtiles:
                # the slot would be clobbered by the group's scatter, or
                # the host tile's STORE hasn't landed yet
                flush()
            t = _jx_round(read_host(host, op), lad[op.cls], cdt)
            slots = slots.at[op.slot_c].set(t)
        elif op.kind is OpKind.STORE:
            if group:
                # ride in the group: the rounding applies at this exact
                # stream position (launch epilogue / fallback replay),
                # the host write lands at flush
                if op.slot_c not in gwrite:
                    c_init[op.slot_c] = slots[op.slot_c]
                    gwrite.add(op.slot_c)
                group.append((op, None))
                dtiles.add((op.i, op.j))
            else:
                r = _jx_round(slots[op.slot_c], lad[op.cls], cdt)
                slots = slots.at[op.slot_c].set(r)
                host = write_host(host, op, r)
        elif op.kind in _FUSABLE:
            if group and op.k != group[0][0].k:
                flush()
            snap = {}
            if op.kind is OpKind.SYRK:
                snap["a"] = snap_operand(op.slot_a)
            elif op.kind is OpKind.GEMM:
                snap["a"] = snap_operand(op.slot_a)
                snap["b"] = snap_operand(op.slot_b)
            elif op.kind is OpKind.TRSM:
                snap["l"] = snap_operand(op.slot_a)
            if op.slot_c not in gwrite:
                c_init[op.slot_c] = slots[op.slot_c]
            group.append((op, snap))
            gwrite.add(op.slot_c)
        # ALLOC/FREE are bookkeeping-only, as in the unfused trace
    flush()
    return host, slots


def _donate_argnums(n: int) -> tuple:
    """Cross-segment buffer donation for the fused executors: the slab /
    slot buffers are dead after each segment call (the caller rebinds
    them to the outputs), so on accelerator backends XLA may reuse their
    HBM for the results.  CPU ignores donation with a warning per jit —
    keep it off there."""
    try:
        if jax.default_backend() == "cpu":
            return ()
    except Exception:
        return ()
    return tuple(range(n))


def make_jax_executor(sched: Schedule, compute_dtype=jnp.float64,
                      use_pallas: bool = False, interpret: bool = True,
                      fuse_columns: bool = False):
    """Build a jit-able ``host_tiles -> factored host_tiles`` function.

    The returned function's HLO contains exactly the transfers of the static
    schedule; everything else (overlap, async copies) is XLA's job — the
    deterministic-schedule insight of the paper moved to trace time.
    ``fuse_columns`` swaps the per-op compute trace for the column-step
    megakernels (:func:`_run_ops_fused`); the transfers are unchanged.
    """
    if sched.host_slots > 0:
        raise ValueError(
            "make_jax_executor jits over the full host store; a spill "
            "schedule bounds host residency — use SpillJaxExecutor")
    tb = sched.tb
    lad = sched.plan.ladder
    nslots = _device_nslots(sched.ops)
    kf = _make_kernel_fns(use_pallas, interpret)

    def run(host_tiles):
        host = host_tiles.astype(compute_dtype)
        slots = jnp.zeros((nslots, tb, tb), dtype=compute_dtype)
        if fuse_columns:
            host, _ = _run_ops_fused(
                sched.ops, host, slots, lad, compute_dtype, kf, interpret,
                read_host=lambda h, o: h[o.i, o.j],
                write_host=lambda h, o, r: h.at[o.i, o.j].set(r))
            return host
        for op in sched.ops:
            host, slots = _jx_interpret_op(host, slots, op, lad, kf,
                                           compute_dtype, lambda i: i)
        return host

    return run


def run_traced_jax(sched: Schedule, host_tiles: np.ndarray, trace,
                   compute_dtype=jnp.float64, use_pallas: bool = False,
                   interpret: bool = True) -> np.ndarray:
    """Single-device JAX execution in *measured* mode: op-by-op, eager,
    with a ``jax.block_until_ready`` fence after every op so each
    recorded span covers that op's actual execution (under async
    dispatch an unfenced timestamp would measure queue insertion).

    This is what ``OOCSolver.factor(a, trace=rec)`` runs on the jax
    backend instead of the unrolled single-jit program — per-op spans
    are unobservable from inside one jitted computation.  The numerical
    semantics are identical (:func:`_jx_interpret_op` is the same
    interpreter the jit unrolls); the fencing serializes the engines, so
    a traced run is slower than an untraced one by construction.
    Records exactly one span per schedule op (ALLOC/FREE included, as
    zero-width bookkeeping spans) and returns the factored f64 tiles.
    """
    if sched.host_slots > 0:
        raise ValueError("run_traced_jax runs host-resident schedules; "
                         "spill schedules trace through SpillJaxExecutor")
    tb = sched.tb
    lad = sched.plan.ladder
    kf = _make_kernel_fns(use_pallas, interpret)
    host = jnp.asarray(np.asarray(host_tiles, dtype=np.float64),
                       dtype=compute_dtype)
    slots = jnp.zeros((max(_device_nslots(sched.ops), 1), tb, tb),
                      dtype=compute_dtype)
    jax.block_until_ready((host, slots))   # setup outside the first span
    ident = lambda i: i  # noqa: E731
    for idx, op in enumerate(sched.ops):
        t0 = trace.now()
        host, slots = _jx_interpret_op(host, slots, op, lad, kf,
                                       compute_dtype, ident)
        jax.block_until_ready((host, slots))
        trace.record(idx, op.kind.value, 0, t0, trace.now(), op.bytes,
                     lad[op.cls], op.i, op.j)
    return np.asarray(host, dtype=np.float64)


class SpillJaxExecutor:
    """JAX executor for single-device spill schedules (bounded host tier).

    The stream is split at its FETCH/SPILL ops into maximal device
    *segments*; each segment is unrolled into one jitted
    ``(slabs, slots) -> (slabs, slots)`` program where LOAD/STORE address
    the bounded ``[host_slots, tb, tb]`` slab buffer at trace-time-static
    slab indices (the tile -> slab map is constant within a segment — it
    only changes at FETCH ops, which run between segments).  The disk
    tier itself is driven from Python between segments: a FETCH reads
    one tile from the :class:`~repro.core.spill.DiskTileStore` into its
    slab, a SPILL writes one slab back.  Device memory never sees more
    than ``host_slots + device slots`` tiles; host memory never holds the
    full store.

    ``jit_traces`` counts segment traces (constant across repeated runs
    on same-shape stores — the plan-cache amortization contract).
    """

    def __init__(self, sched: Schedule, compute_dtype=jnp.float64,
                 use_pallas: bool = False, interpret: bool = True,
                 fuse_columns: bool = False):
        if sched.host_slots < 1:
            raise ValueError("SpillJaxExecutor needs a spill schedule "
                             "(build with host_slots > 0)")
        self.sched = sched
        self.compute_dtype = compute_dtype
        self.jit_traces = 0
        self.last_io_stats = None     # executed FETCH/SPILL counters
        self._kf = _make_kernel_fns(use_pallas, interpret)
        self._interpret = interpret
        self._fuse = fuse_columns
        self._nslots = _device_nslots(sched.ops)
        self._segments = self._build_segments()

    def _make_segment(self, ops: list[Op]):
        lad, cdt, kf = self.sched.plan.ladder, self.compute_dtype, self._kf
        ops = tuple(ops)
        interpret = self._interpret
        if self._fuse:
            def seg(slabs, slots):
                self.jit_traces += 1    # body runs only while tracing
                return _run_ops_fused(
                    ops, slabs, slots, lad, cdt, kf, interpret,
                    read_host=lambda h, o: h[o.hslot],
                    write_host=lambda h, o, r: h.at[o.hslot].set(r))

            return jax.jit(seg, donate_argnums=_donate_argnums(2))

        def seg(slabs, slots):
            self.jit_traces += 1        # body runs only while tracing
            for op in ops:
                if op.kind is OpKind.LOAD:
                    t = _jx_round(slabs[op.hslot], lad[op.cls], cdt)
                    slots = slots.at[op.slot_c].set(t)
                elif op.kind is OpKind.STORE:
                    r = _jx_round(slots[op.slot_c], lad[op.cls], cdt)
                    slots = slots.at[op.slot_c].set(r)
                    slabs = slabs.at[op.hslot].set(r)
                else:
                    _, slots = _jx_interpret_op(None, slots, op, lad, kf,
                                                cdt, None)
            return slabs, slots

        return jax.jit(seg)

    def _build_segments(self):
        """Cut the stream at host-IO ops; resolve each LOAD/STORE's slab.

        Segments are keyed by their op tuple including the resolved
        ``hslot`` attributes, so the static residency decided by the
        spill post-pass is baked into the traced programs.
        """
        import dataclasses as _dc

        @_dc.dataclass(frozen=True)
        class _SlabOp:
            """An op plus the host slab its tile occupies (segment-local
            static metadata; not part of the schedule vocabulary)."""
            kind: object
            i: int
            j: int
            slot_c: int
            slot_a: int
            slot_b: int
            cls: int
            hslot: int
            k: int = -1     # column step, for fused-trace grouping

        where: dict[tuple[int, int], int] = {}
        segments = []       # list of ("io", op) | ("run", jitted fn)
        pending: list = []

        def close_run():
            if pending:
                segments.append(("run", self._make_segment(pending)))
                pending.clear()

        for op in self.sched.ops:
            if op.kind in HOST_IO:
                if op.kind is OpKind.FETCH:
                    # rebind: drop whatever tile held this slab
                    for t, s in list(where.items()):
                        if s == op.slot_c:
                            del where[t]
                    where[(op.i, op.j)] = op.slot_c
                close_run()
                segments.append(("io", op))
            elif op.kind in (OpKind.LOAD, OpKind.STORE):
                pending.append(_SlabOp(op.kind, op.i, op.j, op.slot_c,
                                       op.slot_a, op.slot_b, op.cls,
                                       where[(op.i, op.j)], op.k))
            elif op.kind in (OpKind.ALLOC, OpKind.FREE):
                continue
            else:
                pending.append(_SlabOp(op.kind, op.i, op.j, op.slot_c,
                                       op.slot_a, op.slot_b, op.cls, -1,
                                       op.k))
        close_run()
        return segments

    def run_store(self, store, trace=None) -> None:
        """Factor the tile store in place (input tiles -> L tiles).

        An active ``trace`` recorder switches to the measured path: the
        full op stream is executed eagerly op-by-op with a
        ``block_until_ready`` fence per op (one span per op, disk I/O
        included) instead of the jitted segments.  Either way,
        ``last_io_stats`` holds the executed FETCH/SPILL counters."""
        if trace is not None and getattr(trace, "active", False):
            return self._run_traced_store(store, trace)
        sched = self.sched
        tb, cdt = sched.tb, self.compute_dtype
        slabs = jnp.zeros((sched.host_slots, tb, tb), dtype=cdt)
        slots = jnp.zeros((max(self._nslots, 1), tb, tb), dtype=cdt)
        io = {"fetch_ops": 0, "spill_ops": 0,
              "fetched_bytes": 0, "spilled_bytes": 0}
        for kind, item in self._segments:
            if kind == "io":
                op = item
                if op.kind is OpKind.FETCH:
                    io["fetch_ops"] += 1
                    io["fetched_bytes"] += op.bytes
                    if op.bytes:
                        slabs = slabs.at[op.slot_c].set(
                            jnp.asarray(store.read_tile(op.i, op.j),
                                        dtype=cdt))
                else:
                    io["spill_ops"] += 1
                    io["spilled_bytes"] += op.bytes
                    store.write_tile(
                        op.i, op.j,
                        np.asarray(slabs[op.slot_c], dtype=np.float64))
            else:
                slabs, slots = item(slabs, slots)
        store.flush()
        self.last_io_stats = io

    def _run_traced_store(self, store, trace) -> None:
        """Measured replay: the stream op-by-op, fenced, one span each.

        Maintains the same tile->slab residency map the segment builder
        bakes into its jitted programs (it changes only at FETCH), so
        LOAD/STORE hit the same slabs and the numerics match the
        segmented path op-for-op."""
        sched = self.sched
        tb, cdt = sched.tb, self.compute_dtype
        lad = sched.plan.ladder
        slabs = jnp.zeros((sched.host_slots, tb, tb), dtype=cdt)
        slots = jnp.zeros((max(self._nslots, 1), tb, tb), dtype=cdt)
        jax.block_until_ready((slabs, slots))
        where: dict[tuple[int, int], int] = {}
        io = {"fetch_ops": 0, "spill_ops": 0,
              "fetched_bytes": 0, "spilled_bytes": 0}
        for idx, op in enumerate(sched.ops):
            t0 = trace.now()
            if op.kind is OpKind.FETCH:
                for t, s in list(where.items()):
                    if s == op.slot_c:
                        del where[t]
                where[(op.i, op.j)] = op.slot_c
                io["fetch_ops"] += 1
                io["fetched_bytes"] += op.bytes
                if op.bytes:
                    slabs = slabs.at[op.slot_c].set(
                        jnp.asarray(store.read_tile(op.i, op.j), dtype=cdt))
                    jax.block_until_ready(slabs)
            elif op.kind is OpKind.SPILL:
                io["spill_ops"] += 1
                io["spilled_bytes"] += op.bytes
                store.write_tile(
                    op.i, op.j,
                    np.asarray(slabs[op.slot_c], dtype=np.float64))
            elif op.kind is OpKind.LOAD:
                t = _jx_round(slabs[where[(op.i, op.j)]], lad[op.cls], cdt)
                slots = slots.at[op.slot_c].set(t)
                jax.block_until_ready(slots)
            elif op.kind is OpKind.STORE:
                r = _jx_round(slots[op.slot_c], lad[op.cls], cdt)
                slots = slots.at[op.slot_c].set(r)
                slabs = slabs.at[where[(op.i, op.j)]].set(r)
                jax.block_until_ready((slabs, slots))
            elif op.kind is OpKind.ALLOC or op.kind is OpKind.FREE:
                pass
            else:
                _, slots = _jx_interpret_op(None, slots, op, lad, self._kf,
                                            cdt, None)
                jax.block_until_ready(slots)
            trace.record(idx, op.kind.value, 0, t0, trace.now(), op.bytes,
                         lad[op.cls], op.i, op.j)
        store.flush()
        self.last_io_stats = io

    def __call__(self, host_tiles: np.ndarray, trace=None) -> np.ndarray:
        """Array-in/array-out convenience: factor a full tile array
        through an in-memory backing store (tests, the solver path when
        the caller holds the matrix anyway)."""
        from .spill import ArrayTileStore
        store = ArrayTileStore(host_tiles)
        self.run_store(store, trace=trace)
        return store.to_tiles()


# --------------------------------------------------------------------------
# Multi-device JAX executor (one jitted column segment per device stream)
# --------------------------------------------------------------------------

def _wire_dtype(cls_name: str, compute_dtype):
    """Dtype a broadcast tile travels in: the tile's precision class (the
    interconnect carries class-precision bytes, paper §IV-C), degraded to
    the compute dtype when the f64 class is unavailable (x64 off)."""
    if cls_name == "f64" and not jax.config.jax_enable_x64:
        return compute_dtype
    return _JNP_DTYPES[cls_name]


def _make_wire(tile, cls_name, compute_dtype):
    """Round a finalized tile onto the interconnect wire.

    Every wire is a ``(payload, scale)`` pair so the pytree structure is
    class-independent: plain classes ship their class-dtype payload with
    ``scale=None`` (an empty pytree leaf — nothing travels), the scaled
    FP8 class ships the e4m3 payload plus its power-of-two scale scalar.
    Byte accounting counts the payload only — the scale is 4 bytes of
    metadata riding the ``[Nt, Nt]`` scale table, not tile traffic.
    """
    if cls_name == "f8e4m3s":
        s = _jx_fp8_scale(jnp.max(jnp.abs(tile)), compute_dtype)
        return ((tile * s).astype(_JNP_DTYPES[cls_name]), s)
    return (tile.astype(_wire_dtype(cls_name, compute_dtype)), None)


def _unwire(wire, compute_dtype):
    """Promote a received wire back to the compute dtype (inverting the
    scaled-FP8 store-time scale when one rode along)."""
    payload, scale = wire
    t = payload.astype(compute_dtype)
    return t if scale is None else t / scale


class MultiDeviceJaxExecutor:
    """Replay a :class:`MultiDeviceSchedule` on ``ndev`` real JAX devices.

    Each device stream is compiled as a sequence of *dispatch-chunk
    segments* (:meth:`MultiDeviceSchedule.dispatch_chunks`) — unrolled
    jitted programs (same op semantics and kernel fns as the
    single-device executor) operating on that device's block-cyclic host
    row slab and its private slot buffer.  The slab holds the tile rows
    of the device's *grid row* (``[ceil(Nt/p), Nt, tb, tb]``; with the 1D
    default grid ``(ndev, 1)`` each device has a private slab, a 2D grid
    replicates each slab across its ``q`` grid-row peers).  The
    ``BCAST``/``RECV`` cross-stream edges are the only points where data
    leaves a device: a segment returns the tiles its BCAST ops publish,
    rounded to their class (wire) dtype, and :func:`jax.device_put`
    moves each tile to its receivers, where the consuming segment writes
    it into its panel slot — or, for the 2D grid's row-scoped ownership
    broadcast (``slot_c < 0``), directly into the receiver's host slab.
    For ``lookahead = 0`` the chunk order is the historical per-column
    wave::

        owner head (diag update + POTRF + panel-row wire tiles)
          -> device_put to each grid-column peer  (the BCAST/RECV edges)
          -> owner tail (its own rows of column k)  |  concurrently
          -> each worker's segment (RECV + rows)    |  (async dispatch)
          -> row-scoped receivers (host-slab RECVs of finalized tiles)

    and for ``lookahead > 0`` the emitter's pipelined chunk list: a
    column's final waves interleave with the next panels' bulk pushes,
    eager panel receives, and advance-update segments (whose partial
    accumulators are stored back to the slab), so the owner's trailing
    update overlaps the in-flight panels exactly as in the static
    schedule's partial order.

    Numerics are op-for-op those of :func:`run_multidevice_numpy`: a RECV
    observes the sender's host-coherent tile rounded through its class, so
    FP64 plans agree with the NumPy replay to BLAS round-off and MxP plans
    perform the identical rounding events.

    Attributes: ``jit_traces`` counts segment traces (amortization
    contract: constant across repeated calls); ``last_transfer_stats``
    holds the executed BCAST/RECV op and byte counters of the most recent
    run, cross-checkable against the schedule and the event simulator via
    :func:`repro.core.analytics.crosscheck_executed_volume`.
    """

    def __init__(self, msched: MultiDeviceSchedule, compute_dtype=jnp.float64,
                 use_pallas: bool = False, interpret: bool = True,
                 devices=None, fuse_columns: bool = False):
        if msched.ndev < 2:
            raise ValueError(
                f"MultiDeviceJaxExecutor needs ndev >= 2 (got "
                f"{msched.ndev}); use make_jax_executor for one device")
        if devices is None:
            devices = jax.devices()
        if len(devices) < msched.ndev:
            raise RuntimeError(
                f"multi-device jax executor needs {msched.ndev} devices, "
                f"found {len(devices)} ({devices[0].platform}); on CPU, "
                f"set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{msched.ndev} before importing jax, or use "
                f"backend='numpy'")
        self.msched = msched
        self.devices = list(devices[:msched.ndev])
        self.compute_dtype = compute_dtype
        self.jit_traces = 0
        self.last_transfer_stats = None
        self._kf = _make_kernel_fns(use_pallas, interpret)
        self._interpret = interpret
        self._fuse = fuse_columns
        # device d's host slab holds the rows of its grid row (d // q);
        # tile-level ownership within the slab follows schedule.grid_owner,
        # the same rule the builder and column_device_order use
        p, q = msched.grid
        self._rows = [
            [i for i in range(msched.nt) if i % p == d // q]
            for d in range(msched.ndev)
        ]
        self._local_row = [
            {g: l for l, g in enumerate(rows)} for rows in self._rows
        ]
        self._segments = self._build_segments()

    # -- compile-time: split streams into per-column jitted segments -------
    def _make_segment(self, d: int, ops: list[Op]):
        """Jit one device-column slice of device ``d``'s stream.

        ``seg(host_slab, slots, recv_tiles) -> (host_slab, slots, wires)``
        where ``recv_tiles`` match the slice's RECV ops in order (panel
        RECVs land in their slot, host-landing RECVs in the slab) and
        ``wires`` are the class-dtype tiles its BCAST ops publish.
        """
        msched = self.msched
        lad, cdt = msched.plan.ladder, self.compute_dtype
        recv_ops = tuple(o for o in ops if o.kind is OpKind.RECV)
        bcast_ops = tuple(o for o in ops if o.kind is OpKind.BCAST)
        body = tuple(o for o in ops
                     if o.kind is not OpKind.RECV and o.kind is not OpKind.BCAST)
        lrow = self._local_row[d].__getitem__
        fuse, kf, interpret = self._fuse, self._kf, self._interpret

        def seg(host, slots, recv_tiles):
            self.jit_traces += 1        # body runs only while tracing
            for o, t in zip(recv_ops, recv_tiles):
                t = _unwire(t, cdt)
                if o.slot_c >= 0:
                    slots = slots.at[o.slot_c].set(t)
                else:
                    host = host.at[lrow(o.i), o.j].set(t)
            if fuse:
                host, slots = _run_ops_fused(
                    body, host, slots, lad, cdt, kf, interpret,
                    read_host=lambda h, o: h[lrow(o.i), o.j],
                    write_host=lambda h, o, r: h.at[lrow(o.i), o.j].set(r))
            else:
                for o in body:
                    host, slots = _jx_interpret_op(host, slots, o, lad,
                                                   kf, cdt, lrow)
            wires = tuple(
                _make_wire(host[lrow(o.i), o.j], lad[o.cls], cdt)
                for o in bcast_ops)
            return host, slots, wires

        donate = _donate_argnums(2) if fuse else ()
        return jax.jit(seg, donate_argnums=donate), recv_ops, bcast_ops

    def _build_segments(self):
        """Compile one jitted segment per dispatch chunk.

        The segment waves are :meth:`MultiDeviceSchedule.dispatch_chunks`
        — for ``lookahead = 0`` the historical column-major order (the
        diagonal owner's column ops split at its last panel BCAST into a
        head publishing the panel wires and a tail running its own rows);
        for ``lookahead > 0`` the emitter's interleaved final / advance /
        push chunks, so an in-flight panel's early updates run between a
        column's finalization waves.  Wires are matched to their RECVs by
        ``(i, j, k, src)`` — with eager panel pushes the same tile can be
        on two wires at once (row-scoped now, panel-scoped for a later
        column), so the tile id alone is not a key.  ``self._nrecv``
        records each wire's receiver count (executed-bcast-bytes
        accounting for scoped broadcasts, and wire lifetime).
        """
        msched = self.msched
        nrecv = {}
        for stream in msched.streams:
            for o in stream:
                if o.kind is OpKind.RECV:
                    key = (o.i, o.j, o.k, o.src)
                    nrecv[key] = nrecv.get(key, 0) + 1
        self._nrecv = nrecv
        chunks = [(d, list(msched.streams[d][start:stop]))
                  for d, start, stop, _k, _phase in msched.dispatch_chunks()]
        if self._fuse:
            # PR 3 leftover: segment fusion across adjacent dispatch
            # chunks of the same device (consecutive same-owner columns,
            # owner tail + next head, back-to-back worker waves).  Safe
            # exactly when the absorbed chunk has no RECV ops: cross-
            # device data flows only over wires, so a recv-free chunk
            # cannot depend on anything dispatched between the two — and
            # pulling its BCAST publications earlier only ever helps
            # (wire keys are unique per (i, j, k, src)).
            merged: list = []
            for d, ops in chunks:
                if (merged and merged[-1][0] == d
                        and not any(o.kind is OpKind.RECV for o in ops)):
                    merged[-1][1].extend(ops)
                else:
                    merged.append((d, ops))
            chunks = merged
        return [(d,) + self._make_segment(d, ops) for d, ops in chunks]

    # -- run time ----------------------------------------------------------
    def __call__(self, host_tiles: np.ndarray, trace=None) -> np.ndarray:
        """Factor the [Nt, Nt, tb, tb] host store; returns it in f64.

        An active ``trace`` recorder switches to the measured path
        (:meth:`_run_traced`): the dispatch order op-by-op, eagerly, with
        a fence per op — one span per op across all device streams.  An
        inactive/absent trace runs the jitted segments unchanged."""
        if trace is not None and getattr(trace, "active", False):
            return self._run_traced(host_tiles, trace)
        msched = self.msched
        tb, ndev, cdt = msched.tb, msched.ndev, self.compute_dtype
        host_tiles = np.asarray(host_tiles, dtype=np.float64)
        row_slabs = self._rows
        host_d = [jax.device_put(jnp.asarray(host_tiles[rows], dtype=cdt),
                                 self.devices[d])
                  for d, rows in enumerate(row_slabs)]
        slots_d = [
            jax.device_put(
                jnp.zeros((max(msched.stream_nslots(d), 1), tb, tb),
                          dtype=cdt), self.devices[d])
            for d in range(ndev)
        ]
        stats = {"bcast_ops": 0, "recv_ops": 0,
                 "bcast_bytes": 0, "recv_bytes": 0}
        wire_of = {}
        pending = dict(self._nrecv)     # wire -> receivers still to land
        for d, fn, recv_ops, bcast_ops in self._segments:
            recv_tiles = tuple(
                jax.device_put(wire_of[(o.i, o.j, o.k, o.src)],
                               self.devices[d])
                for o in recv_ops)
            for o in recv_ops:
                key = (o.i, o.j, o.k, o.src)
                pending[key] -= 1
                if pending[key] == 0:   # last receiver landed: free the wire
                    del wire_of[key]
            stats["recv_ops"] += len(recv_tiles)
            stats["recv_bytes"] += sum(t[0].nbytes for t in recv_tiles)
            host_d[d], slots_d[d], wires = fn(host_d[d], slots_d[d],
                                              recv_tiles)
            for o, t in zip(bcast_ops, wires):
                key = (o.i, o.j, o.k, o.src)
                wire_of[key] = t
                stats["bcast_bytes"] += t[0].nbytes * self._nrecv[key]
            stats["bcast_ops"] += len(bcast_ops)
        out = np.empty_like(host_tiles)
        p, q = msched.grid
        for d, rows in enumerate(row_slabs):
            if d % q:                   # grid-row peers hold replica slabs
                continue
            out[rows] = np.asarray(host_d[d], dtype=np.float64)
        if q > 1:
            # slabs are replicated along grid rows and kept coherent by the
            # row-scoped broadcast — except the diagonal tiles, which no
            # later task consumes and which are therefore never shipped:
            # read each one from its own diagonal owner
            for k in range(msched.nt):
                if k % q:
                    dv = grid_owner(k, k, p, q)
                    out[k, k] = np.asarray(
                        host_d[dv][self._local_row[dv][k], k],
                        dtype=np.float64)
        self.last_transfer_stats = stats
        return out

    def _run_traced(self, host_tiles: np.ndarray, trace) -> np.ndarray:
        """Measured replay: every op of every stream in dispatch order,
        eagerly, fenced per op — one recorded span per op.

        The numerics are those of the segmented path (same interpreter,
        same wire table keyed ``(i, j, k, src)``, same class-dtype wire
        rounding); only the execution granularity changes, so per-op
        durations are observable.  ``last_transfer_stats`` is maintained
        exactly as on the jitted path."""
        msched = self.msched
        tb, ndev, cdt = msched.tb, msched.ndev, self.compute_dtype
        lad = msched.plan.ladder
        host_tiles = np.asarray(host_tiles, dtype=np.float64)
        host_d = [jax.device_put(jnp.asarray(host_tiles[rows], dtype=cdt),
                                 self.devices[d])
                  for d, rows in enumerate(self._rows)]
        slots_d = [
            jax.device_put(
                jnp.zeros((max(msched.stream_nslots(d), 1), tb, tb),
                          dtype=cdt), self.devices[d])
            for d in range(ndev)
        ]
        jax.block_until_ready((host_d, slots_d))  # setup outside spans
        stats = {"bcast_ops": 0, "recv_ops": 0,
                 "bcast_bytes": 0, "recv_bytes": 0}
        wire_of = {}
        pending = dict(self._nrecv)
        for idx, (d, op, phase) in enumerate(
                msched.iter_dispatch_order(with_phase=True)):
            t0 = trace.now()
            lrow = self._local_row[d].__getitem__
            if op.kind is OpKind.BCAST:
                key = (op.i, op.j, op.k, op.src)
                w = _make_wire(host_d[d][lrow(op.i), op.j],
                               lad[op.cls], cdt)
                jax.block_until_ready(w)
                wire_of[key] = w
                stats["bcast_ops"] += 1
                stats["bcast_bytes"] += w[0].nbytes * self._nrecv[key]
            elif op.kind is OpKind.RECV:
                key = (op.i, op.j, op.k, op.src)
                wire = jax.device_put(wire_of[key], self.devices[d])
                t = _unwire(wire, cdt)
                if op.slot_c >= 0:
                    slots_d[d] = slots_d[d].at[op.slot_c].set(t)
                    jax.block_until_ready(slots_d[d])
                else:
                    host_d[d] = host_d[d].at[lrow(op.i), op.j].set(t)
                    jax.block_until_ready(host_d[d])
                stats["recv_ops"] += 1
                stats["recv_bytes"] += wire[0].nbytes
                pending[key] -= 1
                if pending[key] == 0:
                    del wire_of[key]
            else:
                host_d[d], slots_d[d] = _jx_interpret_op(
                    host_d[d], slots_d[d], op, lad, self._kf, cdt, lrow)
                jax.block_until_ready((host_d[d], slots_d[d]))
            trace.record(idx, op.kind.value, d, t0, trace.now(), op.bytes,
                         lad[op.cls], op.i, op.j, phase)
        out = np.empty_like(host_tiles)
        p, q = msched.grid
        for d, rows in enumerate(self._rows):
            if d % q:                   # grid-row peers hold replica slabs
                continue
            out[rows] = np.asarray(host_d[d], dtype=np.float64)
        if q > 1:
            for k in range(msched.nt):
                if k % q:
                    dv = grid_owner(k, k, p, q)
                    out[k, k] = np.asarray(
                        host_d[dv][self._local_row[dv][k], k],
                        dtype=np.float64)
        self.last_transfer_stats = stats
        return out


def make_multidevice_jax_executor(msched: MultiDeviceSchedule,
                                  compute_dtype=jnp.float64,
                                  use_pallas: bool = False,
                                  interpret: bool = True,
                                  devices=None,
                                  fuse_columns: bool = False,
                                  ) -> MultiDeviceJaxExecutor:
    """Build the per-device JAX executor for a multi-device schedule.

    Returns a callable ``host_tiles -> factored host_tiles`` (f64 NumPy in
    and out) backed by one jitted program sequence per device stream; see
    :class:`MultiDeviceJaxExecutor`.  Raises ``RuntimeError`` when fewer
    than ``msched.ndev`` JAX devices are visible.
    """
    return MultiDeviceJaxExecutor(msched, compute_dtype,
                                  use_pallas=use_pallas, interpret=interpret,
                                  devices=devices, fuse_columns=fuse_columns)


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------

def plan_for_matrix(a_tiles: np.ndarray, eps_target: float | None,
                    ladder: str = "tpu") -> PrecisionPlan:
    nt = a_tiles.shape[0]
    if eps_target is None:
        return uniform_plan(nt, "f64", ladder)
    norms, total = tile_norms(a_tiles)
    # amax-aware classification: tiles outside e4m3's representable band
    # no longer qualify for the unscaled FP8 class (the scaled class is
    # unaffected — its per-tile scale recentres the band)
    return assign_precision(norms, total, eps_target, ladder,
                            tile_amax=_tile_amax(a_tiles))


def ooc_cholesky(
    a: np.ndarray,
    tb: int,
    policy: str = "v3",
    eps_target: float | None = None,
    ladder: str = "tpu",
    cache_slots: int = 0,
    backend: str | None = None,
    compute_dtype=None,
    use_pallas: bool = False,
    block: tuple = (4, 4),
    ndev: int = 1,
) -> tuple[np.ndarray, MultiDeviceSchedule]:
    """One-shot out-of-core Cholesky — deprecated shim over the planner API.

    .. deprecated:: 0.2
       Use ``repro.plan(n, CholeskyConfig(...)).compile()`` instead: the
       static schedule and jitted executor are then built once and reused
       across every same-shape factorization.  Kwarg migration:

       ============== ===========================================
       old kwarg      CholeskyConfig field
       ============== ===========================================
       tb             ``tb``
       policy         ``policy``
       eps_target     ``eps_target`` (freeze via ``specialize(a)``)
       ladder         ``ladder``
       cache_slots    ``cache_slots``
       backend        ``backend`` (new default ``"auto"``)
       compute_dtype  ``compute_dtype``
       use_pallas     ``use_pallas``
       block          ``block``
       ndev           ``ndev``
       ============== ===========================================

    Returns ``(L, schedule)`` with L lower-triangular (upper part zeroed)
    and ``schedule`` the unified
    :class:`~repro.core.schedule.MultiDeviceSchedule` (ndev=1 degenerate
    for the single-device path) carrying the exact data-movement record.

    ``ndev > 1`` with ``backend="jax"`` (or ``"auto"`` with enough
    visible devices) runs the per-device JAX executor
    (:class:`MultiDeviceJaxExecutor`); with too few devices an explicit
    ``"jax"`` raises ``RuntimeError`` at compile.  Unsupported
    combinations (``async``/``v4`` multi-device, pallas or compute_dtype
    on a numpy-resolved backend) raise eagerly from config validation.
    """
    import warnings

    from .api import CholeskyConfig, plan as _plan

    warnings.warn(
        "ooc_cholesky() is deprecated: use "
        "repro.plan(n, CholeskyConfig(...)).compile() to amortize the "
        "schedule build and jit across factorizations",
        DeprecationWarning, stacklevel=2)
    a = np.asarray(a, dtype=np.float64)
    cfg = CholeskyConfig(
        tb=tb, policy=policy, eps_target=eps_target, ladder=ladder,
        cache_slots=cache_slots, backend=backend or "auto",
        compute_dtype=compute_dtype, use_pallas=use_pallas, block=block,
        ndev=ndev,
    ).specialize(a)
    solver = _plan(a.shape[0], cfg).compile()
    return solver.factor(a), solver.schedule
