"""Static task schedule for the left-looking tile Cholesky (Algorithms 1-3).

The paper's static scheduler assigns tasks ahead of time and consults a
runtime *cache table* (Algorithm 3) to decide whether a tile must be copied
host->device.  Because the schedule is deterministic, the entire cache
behaviour — every hit, miss, and eviction — is computable *before* execution.

This module replays Algorithms 1+2+3 in Python and emits a flat list of
:class:`Op` records (LOAD / compute / STORE).  The emitted program contains
exactly the transfers the paper's runtime would perform; executors
(``cholesky.py``) simply trace it, and ``analytics.py`` folds it into the
byte-volume numbers of Fig. 8 / Fig. 12.

Policies (paper §IV-A/B):
  * ``sync`` / ``async`` — naive OOC: every task loads its operands and
    stores its output.  (``async`` differs at runtime by multi-stream
    overlap and per-tile malloc/free; the op stream is identical, the
    allocation events are counted for the analytics.)
  * ``v1``  — the accumulator tile C of ``C = -A @ B.T + C`` is loaded once
    per update sweep and stored once when it reaches its final state.
  * ``v2``  — V1 + operand cache table: GEMM/SYRK/TRSM operands already on
    the device are reused; least-recently-used unpinned slots are repurposed
    when the device memory budget is exhausted.
  * ``v3``  — V2 + the column's diagonal tile is pinned until every TRSM of
    that column block has consumed it.

Multi-device (paper §IV-D, Fig. 5/9): :func:`build_multidevice_schedule`
extends the same static trace to ``ndev`` devices arranged as a ``p x q``
block-cyclic grid (``grid=(p, q)``, ``p*q == ndev``; the default
``(ndev, 1)`` is the paper's 1D tile-row ownership) and emits *one op
stream per device*, each with its own cache table.  Tile ``(i, j)``
belongs to device ``(i % p) * q + (j % q)``
(:meth:`TileLayout.owner_grid`); the column-``k`` tasks therefore all
live on the ``p`` devices of grid column ``k % q``, and two scoped
partial broadcasts are the only inter-device communication:

* **column-scoped panel broadcast** — after the diagonal owner of step
  ``k`` finalizes ``(k, k)``, it ships the panel row ``(k, 0..k)`` to the
  ``p - 1`` other devices of grid column ``k % q`` (one ``BCAST`` per
  tile on the owner stream, bytes = tile bytes x receivers; one ``RECV``
  per receiver into its dedicated panel slot ``panel_base + n``);
* **row-scoped ownership broadcast** (``q > 1`` only) — when a device
  finalizes column tile ``(m, k)`` it ships it to the ``q - 1`` peers of
  grid row ``m % p``, whose *host slabs* must stay coherent for the
  later steps where they load ``(m, k)`` as a GEMM operand.  These
  ``RECV`` ops land host-side (``slot_c = -1``), not in a device slot.

With ``grid=(ndev, 1)`` the row-scoped broadcast is empty and the stream
is op-for-op the 1D schedule of earlier releases: each tile-row is
broadcast once per factorization to all ``ndev - 1`` peers and the
collective volume matches ``distributed.panel_broadcast_bytes`` exactly.
A 2D grid trades that for ``(p-1)`` panel receivers plus ``(q-1)``
ownership receivers — ``distributed.grid_broadcast_bytes`` — which is
strictly less for every true 2D factorization of ``ndev >= 2`` (the
classic O(sqrt(P)) communication argument, Donfack et al. 2011).
Everything else — operand loads, accumulator stores, cache decisions — is
device-local and policy-identical to the single-device trace; with
``ndev=1`` no BCAST/RECV is emitted and the stream's byte volumes equal
:func:`build_schedule`'s.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from .precision import PrecisionPlan, BYTES, uniform_plan
from .tiling import grid_owner


def min_cache_slots(policy: str, block: tuple = (4, 4),
                    lookahead: int = 0) -> int:
    """Smallest device-slot budget a policy's schedule can be built with.

    These are the worst-case *concurrent pin* counts of each builder (one
    victim slot must remain findable at every cache load), previously
    inlined where they were needed:

      * ``sync``/``async`` use fixed slots 0..2 (C, A, B);
      * ``v1`` adds slot 3 for the TRSM diagonal;
      * ``v2`` pins C+A+B during a GEMM;
      * ``v3`` additionally keeps the column's diagonal tile pinned;
      * ``v4`` pins an h x w accumulator block plus w panel operands plus
        the A operand and the diagonal (``h*w + w + 2``).

    Each lookahead depth of a pipelined multi-device schedule pins one
    extra slot on top: the advance chunks of an in-flight panel hold
    their own accumulator/operand pins concurrently with the final
    chunk's, and the panel-slot region itself starts at ``cache_slots``
    (growing the budget moves ``panel_base`` up with it).

    The tuner's feasibility filter and ``CholeskyConfig``'s eager
    validation both consult this instead of re-deriving the constants.
    """
    policy = policy.lower()
    if policy == "v4":
        h, w = block
        return h * w + w + 2
    return ({"sync": 3, "async": 3, "v1": 4, "v2": 3, "v3": 4}[policy]
            + lookahead)


def default_cache_slots(policy: str, nt: int, block: tuple = (4, 4),
                        multidevice: bool = False,
                        lookahead: int = 0) -> int:
    """Slot budget the builders use when ``cache_slots`` is 0 (unset).

    Exactly the historical inlined defaults (golden op streams depend on
    them): ``2*nt + 2`` (floor 4) for the cache-table policies, the fixed
    4-slot window for multi-device sync/v1, and ``h*w + h + w + 4`` for
    the 2D-blocked v4 — plus one slot per lookahead depth (see
    :func:`min_cache_slots`).
    """
    policy = policy.lower()
    if policy == "v4":
        h, w = block
        return h * w + h + w + 4
    if multidevice and policy not in ("v2", "v3"):
        return 4 + lookahead
    return max(4, nt * 2 + 2) + lookahead


class OpKind(enum.Enum):
    LOAD = "load"        # host tile (i,j) -> device slot (cast to tile class)
    STORE = "store"      # device slot -> host tile (i,j) (cast to tile class)
    SYRK = "syrk"        # C[slot_c] += -A[slot_a] @ A[slot_a].T
    GEMM = "gemm"        # C[slot_c] += -A[slot_a] @ B[slot_b].T
    POTRF = "potrf"      # C[slot_c] = chol(C[slot_c])
    TRSM = "trsm"        # C[slot_c] = C[slot_c] @ inv(L[slot_a]).T
    ALLOC = "alloc"      # async policy only: per-tile cudaMalloc analogue
    FREE = "free"
    BCAST = "bcast"      # owner device sends tile (i,j) to all peers
    RECV = "recv"        # peer device receives tile (i,j) into a panel slot
    FETCH = "fetch"      # disk tile (i,j) -> host slab slot_c (bytes=0: bind
    #                      the slab without reading — the next op overwrites)
    SPILL = "spill"      # host slab slot_c -> disk tile (i,j)


#: ops that move data on the host<->disk tier; their ``slot_c`` is a *host
#: slab* index, not a device slot (executors and slot sizing must skip them)
HOST_IO = frozenset((OpKind.FETCH, OpKind.SPILL))


@dataclasses.dataclass(frozen=True)
class Op:
    kind: OpKind
    i: int = -1              # tile row (LOAD/STORE target tile)
    j: int = -1              # tile col
    slot_c: int = -1         # destination / accumulator slot
    slot_a: int = -1         # first operand slot
    slot_b: int = -1         # second operand slot
    cls: int = 0             # precision class (index into plan.ladder)
    bytes: int = 0           # transfer bytes (LOAD/STORE/BCAST/RECV only)
    k: int = -1              # column step this op belongs to (for tracing)
    src: int = -1            # source device (BCAST/RECV only)


def _ops_digest_update(h, ops) -> None:
    for o in ops:
        h.update((f"{o.kind.value}:{o.i},{o.j},{o.slot_c},{o.slot_a},"
                  f"{o.slot_b},{o.cls},{o.bytes},{o.k},{o.src};").encode())


@dataclasses.dataclass
class Schedule:
    ops: list[Op]
    nt: int
    tb: int
    policy: str
    cache_slots: int
    plan: PrecisionPlan
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    host_slots: int = 0      # >0: host cache bounded, SPILL/FETCH in stream

    def loads_bytes(self) -> int:
        return sum(o.bytes for o in self.ops if o.kind is OpKind.LOAD)

    def stores_bytes(self) -> int:
        return sum(o.bytes for o in self.ops if o.kind is OpKind.STORE)

    def fetch_bytes(self) -> int:
        return sum(o.bytes for o in self.ops if o.kind is OpKind.FETCH)

    def spill_bytes(self) -> int:
        return sum(o.bytes for o in self.ops if o.kind is OpKind.SPILL)

    def flops(self) -> float:
        """Model FLOPs of the factorization: n^3/3 for the full matrix."""
        n = self.nt * self.tb
        return n**3 / 3.0

    def count(self, kind: OpKind) -> int:
        return sum(1 for o in self.ops if o.kind is kind)

    def digest(self) -> str:
        """Content hash of the op stream (golden-schedule regression).

        A spill schedule (``host_slots > 0``) folds the host-slab budget
        in as executor-facing metadata — the slab buffer the executors
        size from it is as execution-visible as an op; plain schedules
        hash ops only so historical digests stay valid."""
        import hashlib
        h = hashlib.sha256()
        if self.host_slots > 0:
            h.update(f"|hslots{self.host_slots}|".encode())
        _ops_digest_update(h, self.ops)
        return h.hexdigest()[:16]


class _CacheTable:
    """Trace-time replay of Algorithm 3 (load_tile with cache table).

    O(1) amortized per access: free slots on a stack, LRU order in an
    OrderedDict (linear scans made 100k-tile schedules untraceable)."""

    def __init__(self, slots: int, emit, plan: PrecisionPlan, tb: int):
        import collections
        self.slots = slots
        self.emit = emit
        self.plan = plan
        self.tb = tb
        self.where: dict[tuple[int, int], int] = {}   # tile -> slot
        self.resident: list[Optional[tuple[int, int]]] = [None] * slots
        self.pinned: set[int] = set()
        self.free: list[int] = list(range(slots - 1, -1, -1))
        self.lru = collections.OrderedDict()          # slot -> None, LRU first
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _touch(self, s: int):
        self.lru[s] = None
        self.lru.move_to_end(s)

    def _victim(self) -> int:
        while self.free:
            s = self.free.pop()
            if self.resident[s] is None:
                return s
        for s in self.lru:
            if s not in self.pinned:
                return s
        raise RuntimeError(
            f"cache thrash: all {self.slots} slots pinned; "
            "increase cache_slots"
        )

    def lookup(self, i: int, j: int) -> Optional[int]:
        return self.where.get((i, j))

    def load(self, i: int, j: int, k: int, pin: bool = False,
             cacheable: bool = True) -> int:
        """Algorithm 3: return a slot holding tile (i, j), loading on miss."""
        s = self.where.get((i, j))
        if s is not None:
            self.hits += 1
            self._touch(s)
            if pin:
                self.pinned.add(s)
            return s
        self.misses += 1
        s = self._victim()
        if self.resident[s] is not None:
            self.evictions += 1
            del self.where[self.resident[s]]
            self.lru.pop(s, None)
        cls = int(self.plan.classes[i, j])
        nbytes = BYTES[self.plan.ladder[cls]] * self.tb * self.tb
        self.emit(Op(OpKind.LOAD, i=i, j=j, slot_c=s, cls=cls, bytes=nbytes, k=k))
        if cacheable:
            self.resident[s] = (i, j)
            self.where[(i, j)] = s
        self._touch(s)
        if pin:
            self.pinned.add(s)
        return s

    def adopt(self, i: int, j: int, s: int, pin: bool = False):
        """Register a tile produced on-device (e.g. fresh L[k,k]) in slot s."""
        if self.resident[s] is not None and self.resident[s] != (i, j):
            self.where.pop(self.resident[s], None)
        self.resident[s] = (i, j)
        self.where[(i, j)] = s
        self._touch(s)
        if pin:
            self.pinned.add(s)

    def unpin(self, s: int):
        self.pinned.discard(s)

    def invalidate(self, i: int, j: int):
        s = self.where.pop((i, j), None)
        if s is not None:
            self.resident[s] = None
            self.pinned.discard(s)
            self.lru.pop(s, None)
            self.free.append(s)


def with_host_cache(ops: list[Op], tb: int, host_slots: int) -> list[Op]:
    """Bound a stream's host residency to ``host_slots`` slabs (disk tier).

    The third-tier analogue of the device cache table: the host store is
    no longer the full ``[Nt, Nt, tb, tb]`` array but a bounded cache of
    ``host_slots`` fp64 slabs over a disk-backed tile store
    (:class:`repro.core.spill.DiskTileStore`).  This post-pass replays
    the stream's host accesses through an LRU slab table and interleaves
    the tier traffic as explicit ops — the same ahead-of-time treatment
    Algorithm 3 gives device residency:

    * a host *read* (LOAD of an operand, BCAST publishing a tile) of a
      non-resident tile emits ``FETCH`` (disk -> slab, full tile bytes);
    * a host *write* (STORE, host-landing RECV) of a non-resident tile
      emits a binding ``FETCH`` with ``bytes = 0`` — the write fully
      overwrites the slab, so nothing is read from disk;
    * evicting a dirty slab (written since it was bound) emits ``SPILL``
      (slab -> disk); clean slabs are dropped for free;
    * at stream end every dirty resident slab is spilled, so the disk
      store finishes coherent and the scheduled SPILL/FETCH byte totals
      are exact ahead of time (the simulator's disk lane and the
      executors replay precisely these ops).

    Host slabs always hold the fp64 host representation (8 bytes/elem),
    whatever the tile's precision class: the class cast happens on the
    device edge (LOAD/STORE), exactly as with the unbounded host store.
    """
    if host_slots < 1:
        raise ValueError(f"host_slots must be >= 1, got {host_slots}")
    import collections
    slab_bytes = 8 * tb * tb
    out: list[Op] = []
    where: dict[tuple[int, int], int] = {}     # tile -> slab
    tile_of: list[Optional[tuple[int, int]]] = [None] * host_slots
    dirty = [False] * host_slots
    free = list(range(host_slots - 1, -1, -1))
    lru = collections.OrderedDict()            # slab -> None, LRU first

    def touch(s: int):
        lru[s] = None
        lru.move_to_end(s)

    def ensure(i: int, j: int, k: int, read: bool):
        s = where.get((i, j))
        if s is not None:
            touch(s)
            return
        s = free.pop() if free else next(iter(lru))
        old = tile_of[s]
        if old is not None:
            if dirty[s]:
                out.append(Op(OpKind.SPILL, i=old[0], j=old[1], slot_c=s,
                              bytes=slab_bytes, k=k))
            del where[old]
            lru.pop(s, None)
        out.append(Op(OpKind.FETCH, i=i, j=j, slot_c=s,
                      bytes=slab_bytes if read else 0, k=k))
        tile_of[s] = (i, j)
        where[(i, j)] = s
        dirty[s] = False
        touch(s)

    last_k = 0
    for op in ops:
        if op.k >= 0:
            last_k = op.k
        if op.kind is OpKind.LOAD or op.kind is OpKind.BCAST:
            ensure(op.i, op.j, op.k, read=True)
        elif op.kind is OpKind.STORE or (op.kind is OpKind.RECV
                                         and op.slot_c < 0):
            ensure(op.i, op.j, op.k, read=False)
            dirty[where[(op.i, op.j)]] = True
        out.append(op)
    for s in range(host_slots):
        if tile_of[s] is not None and dirty[s]:
            out.append(Op(OpKind.SPILL, i=tile_of[s][0], j=tile_of[s][1],
                          slot_c=s, bytes=slab_bytes, k=last_k))
    return out


def build_schedule(
    nt: int,
    tb: int,
    policy: str = "v3",
    cache_slots: int = 0,
    plan: PrecisionPlan | None = None,
    block: tuple = (4, 4),
    host_slots: int = 0,
) -> Schedule:
    """Emit the static op stream for one left-looking tile Cholesky.

    ``v4`` is the beyond-paper 2D-blocked left-looking variant (see
    :func:`_build_v4`); ``block=(h, w)`` are its row/column block sizes.
    ``host_slots > 0`` bounds the host tier to that many fp64 tile slabs
    over a disk-backed store and interleaves the SPILL/FETCH traffic
    into the stream (:func:`with_host_cache`); 0 keeps the historical
    unbounded host store (no disk tier, digests unchanged).
    """
    policy = policy.lower()
    if policy not in ("sync", "async", "v1", "v2", "v3", "v4"):
        raise ValueError(f"unknown policy {policy!r}")
    if plan is None:
        plan = uniform_plan(nt)
    if plan.classes.shape[0] != nt:
        raise ValueError("precision plan Nt mismatch")
    if host_slots < 0:
        raise ValueError(f"host_slots must be >= 0, got {host_slots}")
    if policy == "v4":
        sched = _build_v4(nt, tb, plan, cache_slots, block)
        if host_slots > 0:
            sched.ops = with_host_cache(sched.ops, tb, host_slots)
            sched.host_slots = host_slots
        return sched
    if cache_slots <= 0:
        cache_slots = default_cache_slots(policy, nt)

    def finish(sched: Schedule) -> Schedule:
        if host_slots > 0:
            sched.ops = with_host_cache(sched.ops, tb, host_slots)
            sched.host_slots = host_slots
        return sched

    ops: list[Op] = []
    emit = ops.append

    def ccls(*tiles: tuple[int, int]) -> int:
        """Compute class of a task = lowest precision among its operands
        (tensor-core engines run at the rate of the narrowest operand)."""
        return max(int(plan.classes[i, j]) for i, j in tiles)
    operand_cache = policy in ("v2", "v3")
    reuse_accum = policy in ("v1", "v2", "v3")
    pin_diag = policy == "v3"
    per_task_alloc = policy == "async"

    cache = _CacheTable(cache_slots, emit, plan, tb)

    def store(i, j, s, k):
        cls = int(plan.classes[i, j])
        emit(Op(OpKind.STORE, i=i, j=j, slot_c=s, cls=cls,
                bytes=BYTES[plan.ladder[cls]] * tb * tb, k=k))

    def naive_load(i, j, k, slot):
        """sync/async path: unconditional transfer into a fixed slot."""
        cls = int(plan.classes[i, j])
        if per_task_alloc:
            emit(Op(OpKind.ALLOC, i=i, j=j, slot_c=slot, k=k))
        emit(Op(OpKind.LOAD, i=i, j=j, slot_c=slot, cls=cls,
                bytes=BYTES[plan.ladder[cls]] * tb * tb, k=k))
        return slot

    if not reuse_accum:
        # ---- sync / async: no cache table, fixed slots 0=C, 1=A, 2=B ----
        for k in range(nt):
            # diagonal tile
            for n in range(k):
                c = naive_load(k, k, k, 0)
                a = naive_load(k, n, k, 1)
                emit(Op(OpKind.SYRK, slot_c=c, slot_a=a, k=k, cls=ccls((k, n))))
                store(k, k, c, k)
                if per_task_alloc:
                    emit(Op(OpKind.FREE, slot_c=1, k=k))
            c = naive_load(k, k, k, 0)
            emit(Op(OpKind.POTRF, slot_c=c, k=k, cls=ccls((k, k))))
            store(k, k, c, k)
            # off-diagonal tiles of column k
            for m in range(k + 1, nt):
                for n in range(k):
                    c = naive_load(m, k, k, 0)
                    a = naive_load(m, n, k, 1)
                    b = naive_load(k, n, k, 2)
                    emit(Op(OpKind.GEMM, slot_c=c, slot_a=a, slot_b=b, k=k, cls=ccls((m, n), (k, n))))
                    store(m, k, c, k)
                    if per_task_alloc:
                        emit(Op(OpKind.FREE, slot_c=1, k=k))
                        emit(Op(OpKind.FREE, slot_c=2, k=k))
                c = naive_load(m, k, k, 0)
                d = naive_load(k, k, k, 1)
                emit(Op(OpKind.TRSM, slot_c=c, slot_a=d, k=k, cls=ccls((k, k), (m, k))))
                store(m, k, c, k)
                if per_task_alloc:
                    emit(Op(OpKind.FREE, slot_c=0, k=k))
                    emit(Op(OpKind.FREE, slot_c=1, k=k))
        sched = Schedule(ops, nt, tb, policy, cache_slots, plan)
        sched.misses = sched.count(OpKind.LOAD)
        return finish(sched)

    if not operand_cache:
        # ---- V1: accumulator reuse only, no cache table ----
        # Fixed slots: 0 = accumulator C, 1 = operand A, 2 = operand B,
        # 3 = diagonal for TRSM.  Every operand access transfers.
        for k in range(nt):
            c = naive_load(k, k, k, 0)       # accumulator: loaded ONCE
            for n in range(k):
                a = naive_load(k, n, k, 1)
                emit(Op(OpKind.SYRK, slot_c=c, slot_a=a, k=k, cls=ccls((k, n))))
            emit(Op(OpKind.POTRF, slot_c=c, k=k, cls=ccls((k, k))))
            store(k, k, c, k)                # stored ONCE, in final state
            for m in range(k + 1, nt):
                c = naive_load(m, k, k, 0)
                for n in range(k):
                    a = naive_load(m, n, k, 1)
                    b = naive_load(k, n, k, 2)
                    emit(Op(OpKind.GEMM, slot_c=c, slot_a=a, slot_b=b, k=k, cls=ccls((m, n), (k, n))))
                d = naive_load(k, k, k, 3)   # V1: diagonal reloaded per TRSM
                emit(Op(OpKind.TRSM, slot_c=c, slot_a=d, k=k, cls=ccls((k, k), (m, k))))
                store(m, k, c, k)
        sched = Schedule(ops, nt, tb, policy, cache_slots, plan)
        sched.misses = sched.count(OpKind.LOAD)
        return finish(sched)

    # ---- V2/V3: accumulator reuse + cache table for operands ----
    for k in range(nt):
        # --- diagonal tile A[k,k]: SYRK sweep then POTRF ---
        c = cache.load(k, k, k, pin=True)
        for n in range(k):
            a = cache.load(k, n, k, pin=True)
            emit(Op(OpKind.SYRK, slot_c=c, slot_a=a, k=k, cls=ccls((k, n))))
            cache.unpin(a)
        emit(Op(OpKind.POTRF, slot_c=c, k=k, cls=ccls((k, k))))
        store(k, k, c, k)
        # the fresh diagonal factor stays registered; V3 pins it for the
        # whole column block (paper Fig. 3c)
        cache.unpin(c)
        cache.adopt(k, k, c, pin=pin_diag)
        diag_slot = c

        # --- off-diagonal tiles A[m,k]: GEMM sweep then TRSM ---
        for m in range(k + 1, nt):
            c = cache.load(m, k, k, pin=True)
            for n in range(k):
                a = cache.load(m, n, k, pin=True)
                b = cache.load(k, n, k, pin=True)
                emit(Op(OpKind.GEMM, slot_c=c, slot_a=a, slot_b=b, k=k, cls=ccls((m, n), (k, n))))
                cache.unpin(a)
                cache.unpin(b)
            d = cache.load(k, k, k, pin=True)
            emit(Op(OpKind.TRSM, slot_c=c, slot_a=d, k=k, cls=ccls((k, k), (m, k))))
            if not pin_diag:
                cache.unpin(d)
            store(m, k, c, k)
            cache.adopt(m, k, c)   # factored tile stays reusable (V2/V3)
            cache.unpin(c)
        if pin_diag:
            cache.unpin(diag_slot)

    sched = Schedule(ops, nt, tb, policy, cache_slots, plan,
                     hits=cache.hits, misses=cache.misses,
                     evictions=cache.evictions)
    return finish(sched)


def _build_v4(nt: int, tb: int, plan: PrecisionPlan, cache_slots: int,
              block: tuple) -> Schedule:
    """Beyond-paper V4: 2D-blocked left-looking schedule.

    The paper's V1-V3 stream operands per GEMM: even with a perfect
    cache, the external-update sweep loads ~1 tile per GEMM once the
    working set exceeds the cache.  Blocking the update into (h rows x w
    panel columns) amortizes each loaded operand over h*w GEMMs:
    loads/GEMM ~ (h+w)/(h*w) ~ 2/w — the classic surface-to-volume
    trade, applied to the host-device link instead of a cache line.

    Structure per panel [k0, k0+w):
      phase 1 — external updates (n < k0) for all panel tiles, 2D-blocked;
                partially-updated accumulators are stored back (one extra
                triangular G2C pass vs V3 — cheap next to the C2G win);
      phase 2 — internal left-looking factorization of the w panel
                columns (operands are panel-resident).
    """
    h, w = block
    if cache_slots <= 0:
        cache_slots = default_cache_slots("v4", nt, block)
    if cache_slots < min_cache_slots("v4", block):
        raise ValueError(
            f"v4 needs >= h*w + w + 2 = {min_cache_slots('v4', block)} "
            f"slots, got {cache_slots}")

    ops: list[Op] = []
    emit = ops.append
    cache = _CacheTable(cache_slots, emit, plan, tb)

    def ccls(*tiles):
        return max(int(plan.classes[i, j]) for i, j in tiles)

    def store(i, j, s, k):
        cls = int(plan.classes[i, j])
        emit(Op(OpKind.STORE, i=i, j=j, slot_c=s, cls=cls,
                bytes=BYTES[plan.ladder[cls]] * tb * tb, k=k))

    for k0 in range(0, nt, w):
        k1 = min(k0 + w, nt)
        cols = list(range(k0, k1))

        # ---- phase 1: external updates, blocked (h rows x w cols) ----
        if k0 > 0:
            for m0 in range(k0, nt, h):
                rows = list(range(m0, min(m0 + h, nt)))
                accs = {}
                for m in rows:
                    for j in cols:
                        if j <= m:
                            accs[(m, j)] = cache.load(m, j, k0, pin=True)
                for n in range(k0):
                    bslots = {j: cache.load(j, n, k0, pin=True)
                              for j in cols}
                    for m in rows:
                        a = cache.load(m, n, k0, pin=True)
                        for j in cols:
                            if j > m:
                                continue
                            if m == j:
                                emit(Op(OpKind.SYRK, slot_c=accs[(m, j)],
                                        slot_a=a, k=k0, cls=ccls((m, n))))
                            else:
                                emit(Op(OpKind.GEMM, slot_c=accs[(m, j)],
                                        slot_a=a, slot_b=bslots[j], k=k0,
                                        cls=ccls((m, n), (j, n))))
                        cache.unpin(a)
                    for j in cols:
                        cache.unpin(bslots[j])
                # write partially-updated tiles back; host stays coherent
                for (m, j), s in accs.items():
                    store(m, j, s, k0)
                    cache.unpin(s)

        # ---- phase 2: internal panel factorization ----
        for j in cols:
            c = cache.load(j, j, j, pin=True)
            for n in range(k0, j):
                a = cache.load(j, n, j, pin=True)
                emit(Op(OpKind.SYRK, slot_c=c, slot_a=a, k=j,
                        cls=ccls((j, n))))
                cache.unpin(a)
            emit(Op(OpKind.POTRF, slot_c=c, k=j, cls=ccls((j, j))))
            store(j, j, c, j)
            cache.unpin(c)
            cache.adopt(j, j, c, pin=True)
            diag = c
            for m in range(j + 1, nt):
                c2 = cache.load(m, j, j, pin=True)
                for n in range(k0, j):
                    a = cache.load(m, n, j, pin=True)
                    b = cache.load(j, n, j, pin=True)
                    emit(Op(OpKind.GEMM, slot_c=c2, slot_a=a, slot_b=b,
                            k=j, cls=ccls((m, n), (j, n))))
                    cache.unpin(a)
                    cache.unpin(b)
                d = cache.load(j, j, j, pin=True)
                emit(Op(OpKind.TRSM, slot_c=c2, slot_a=d, k=j,
                        cls=ccls((j, j), (m, j))))
                if d != diag:
                    cache.unpin(d)
                store(m, j, c2, j)
                cache.adopt(m, j, c2)
                cache.unpin(c2)
            cache.unpin(diag)

    return Schedule(ops, nt, tb, "v4", cache_slots, plan,
                    hits=cache.hits, misses=cache.misses,
                    evictions=cache.evictions)


# ---------------------------------------------------------------------------
# Multi-device static schedule (paper §IV-D, Fig. 5/9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MultiDeviceSchedule:
    """One static op stream per device, ``p x q`` block-cyclic ownership.

    Stream ``d`` contains every op device ``d`` executes, in order; the
    only cross-stream edges are BCAST (sender) -> RECV (receivers) pairs:
    the column-scoped panel broadcast (RECV into a panel slot) and, for
    2D grids (``q > 1``), the row-scoped ownership broadcast of each
    finalized column tile (RECV with ``slot_c = -1``, landing in the
    receiver's host slab).  ``grid`` is the device grid ``(p, q)``
    (``(ndev, 1)`` = the 1D tile-row layout).  ``hits``/``misses``/
    ``evictions`` are per-device cache-table counters (v2/v3 only).

    ``panel_base`` is the executor-facing slot contract: every slot id
    ``>= panel_base`` is a *panel slot* — the dedicated landing region for
    RECVed row-``k`` tiles (tile ``(k, n)`` lands in ``panel_base + n``),
    outside the cache table's managed range, so a broadcast tile can never
    be evicted by a device-local operand load.  Executors (the NumPy
    replay and the per-device JAX executor) size each device's slot
    buffer with :meth:`stream_nslots`.

    This is the *unified* schedule type of the public API: a single-device
    :class:`Schedule` is represented as its ``ndev=1`` degenerate form via
    :meth:`from_single` (one stream, no BCAST/RECV), so planners and
    executors expose one type instead of the old
    ``Schedule | MultiDeviceSchedule`` union.  :meth:`to_single` recovers
    the flat view where a single op list is needed (executors, the
    three-engine simulator).
    """
    streams: list[list[Op]]
    nt: int
    tb: int
    ndev: int
    policy: str
    cache_slots: int
    plan: PrecisionPlan
    hits: list[int] = dataclasses.field(default_factory=list)
    misses: list[int] = dataclasses.field(default_factory=list)
    evictions: list[int] = dataclasses.field(default_factory=list)
    panel_base: int = -1     # first panel slot id; -1 = no panel region
    grid: tuple = ()         # (p, q) device grid; () normalizes to (ndev, 1)
    lookahead: int = 0       # pipelined-panel depth (0 = column-major)
    dispatch: Optional[list] = None  # (dev, start, stop, k, phase) chunks;
    #                          None = derivable column-major order
    host_slots: int = 0      # >0: per-device host cache bounded to this many
    #                          slabs; streams carry SPILL/FETCH disk-tier ops

    def __post_init__(self):
        if not self.grid:
            self.grid = (self.ndev, 1)
        self.grid = tuple(self.grid)

    @classmethod
    def from_single(cls, sched: Schedule) -> "MultiDeviceSchedule":
        """Wrap a single-device schedule as the ndev=1 degenerate form."""
        return cls(streams=[list(sched.ops)], nt=sched.nt, tb=sched.tb,
                   ndev=1, policy=sched.policy, cache_slots=sched.cache_slots,
                   plan=sched.plan, hits=[sched.hits], misses=[sched.misses],
                   evictions=[sched.evictions], host_slots=sched.host_slots)

    def stream_nslots(self, dev: int) -> int:
        """Slot-buffer length device ``dev``'s stream requires (cache slots
        actually referenced plus its RECV panel region).  FETCH/SPILL ops
        address *host slabs* through ``slot_c``, not device slots, so they
        are excluded."""
        return max((max(o.slot_c, o.slot_a, o.slot_b)
                    for o in self.streams[dev] if o.kind not in HOST_IO),
                   default=-1) + 1

    def to_single(self) -> Schedule:
        """Flat single-device view; only valid for the ndev=1 degenerate."""
        if self.ndev != 1:
            raise ValueError(
                f"schedule has ndev={self.ndev}; only the ndev=1 degenerate "
                "form has a single-device view (use the per-device streams "
                "or simulate_multi/volume_report_multi)")
        return Schedule(list(self.streams[0]), self.nt, self.tb, self.policy,
                        self.cache_slots, self.plan,
                        hits=self.hits[0] if self.hits else 0,
                        misses=self.misses[0] if self.misses else 0,
                        evictions=self.evictions[0] if self.evictions else 0,
                        host_slots=self.host_slots)

    def _bytes(self, kind: OpKind, dev: Optional[int]) -> int:
        streams = self.streams if dev is None else [self.streams[dev]]
        return sum(o.bytes for s in streams for o in s if o.kind is kind)

    def loads_bytes(self, dev: Optional[int] = None) -> int:
        return self._bytes(OpKind.LOAD, dev)

    def stores_bytes(self, dev: Optional[int] = None) -> int:
        return self._bytes(OpKind.STORE, dev)

    def bcast_bytes(self) -> int:
        """Total interconnect volume = sum of per-receiver RECV bytes."""
        return self._bytes(OpKind.RECV, None)

    def fetch_bytes(self, dev: Optional[int] = None) -> int:
        return self._bytes(OpKind.FETCH, dev)

    def spill_bytes(self, dev: Optional[int] = None) -> int:
        return self._bytes(OpKind.SPILL, dev)

    def count(self, kind: OpKind, dev: Optional[int] = None) -> int:
        streams = self.streams if dev is None else [self.streams[dev]]
        return sum(1 for s in streams for o in s if o.kind is kind)

    def flops(self) -> float:
        n = self.nt * self.tb
        return n**3 / 3.0

    def digest(self) -> str:
        """Content hash over all device streams (golden-schedule tests).

        For ``ndev > 1`` the hash also pins the executor-facing metadata
        (``panel_base`` and each stream's slot-buffer length): the JAX
        executor sizes and addresses device buffers from these, so a
        change there is as execution-visible as a reordered op.  A
        genuinely 2D grid (``q > 1``) is folded in too — it changes the
        executor's host-slab layout; the 1D default ``(ndev, 1)`` is
        left out so pre-grid digests stay valid.  The ndev=1 degenerate
        hashes ops only, keeping ``from_single(s).digest()`` equal to
        the planner's digest.
        """
        import hashlib
        h = hashlib.sha256()
        if self.host_slots > 0:
            # the host-slab budget is executor-facing metadata for any
            # ndev (same prefix as Schedule.digest so the ndev=1
            # degenerate keeps matching the planner's digest)
            h.update(f"|hslots{self.host_slots}|".encode())
        if self.ndev > 1:
            h.update(f"|panel{self.panel_base}|".encode())
            if self.grid[1] > 1:
                h.update(f"grid{self.grid[0]}x{self.grid[1]}|".encode())
            if self.lookahead > 0:
                # a pipelined schedule's dispatch chunks are executor
                # metadata exactly like panel_base: the segment waves the
                # JAX executor jits follow them, so fold them in (the
                # lookahead=0 column-major order is derivable and stays
                # out, keeping historical digests valid)
                h.update(f"look{self.lookahead}|".encode())
                for c in self.dispatch or ():
                    h.update(f"{c[0]}:{c[1]}:{c[2]}:{c[3]}:{c[4]};".encode())
        for d, stream in enumerate(self.streams):
            h.update(f"|dev{d}|".encode())
            if self.ndev > 1:
                h.update(f"slots{self.stream_nslots(d)}|".encode())
            _ops_digest_update(h, stream)
        return h.hexdigest()[:16]

    def column_device_order(self, k: int) -> list[int]:
        """Device replay order for column step ``k``: the diagonal owner
        first, then the grid-column workers, then the row-scoped
        receivers.  This is exactly the partial order the BCAST->RECV
        edges impose — a panel RECV must observe the owner's finalized
        copy, and a row-scoped (host-landing) RECV must observe the
        worker's final STORE of that tile."""
        p, q = self.grid
        dv = grid_owner(k, k, p, q)
        workers = [grid_owner(r, k, p, q) for r in range(p)
                   if grid_owner(r, k, p, q) != dv]
        rest = [d for d in range(self.ndev)
                if d != dv and d % q != k % q]
        return [dv] + workers + rest

    def dispatch_chunks(self) -> list[tuple]:
        """The schedule's dispatch order as ``(dev, start, stop, k,
        phase)`` stream slices — the one order every op-stream consumer
        (NumPy replay, JAX executor segments, event simulator) shares
        with the builder.

        Pipelined schedules (``lookahead > 0``) carry the emitter's
        chunk list verbatim (final / advance / push waves interleave
        across columns); for ``lookahead = 0`` the historical
        column-major order is derived from :meth:`column_device_order`,
        splitting each diagonal owner's column ops at its last panel
        BCAST (the head every receiver's RECV depends on)."""
        if self.dispatch is not None:
            return self.dispatch
        chunks = []
        ptr = [0] * self.ndev
        q = self.grid[1]
        for k in range(self.nt):
            order = self.column_device_order(k)
            dv = order[0]
            for d in order:
                stream = self.streams[d]
                start = ptr[d]
                while ptr[d] < len(stream) and stream[ptr[d]].k == k:
                    ptr[d] += 1
                if ptr[d] == start:
                    continue
                if d == dv:
                    ops = stream[start:ptr[d]]
                    split = max((i + 1 for i, o in enumerate(ops)
                                 if o.kind is OpKind.BCAST and o.i == k),
                                default=len(ops))
                    chunks.append((d, start, start + split, k, "panel"))
                    if start + split < ptr[d]:
                        chunks.append((d, start + split, ptr[d], k, "update"))
                else:
                    phase = "update" if d % q == k % q else "recv"
                    chunks.append((d, start, ptr[d], k, phase))
        assert all(ptr[d] == len(self.streams[d]) for d in range(self.ndev))
        return chunks

    def iter_dispatch_order(self, with_phase: bool = False):
        """Yield ``(device, op)`` (or ``(device, op, phase)``) in
        dispatch-chunk order — see :meth:`dispatch_chunks`."""
        for d, start, stop, _k, phase in self.dispatch_chunks():
            stream = self.streams[d]
            for idx in range(start, stop):
                if with_phase:
                    yield d, stream[idx], phase
                else:
                    yield d, stream[idx]

    def iter_column_order(self):
        """Back-compat alias for :meth:`iter_dispatch_order` (the name
        predates lookahead pipelining, when the dispatch order was
        always column-major)."""
        return self.iter_dispatch_order()


def build_multidevice_schedule(
    nt: int,
    tb: int,
    ndev: int = 1,
    policy: str = "v3",
    cache_slots: int = 0,
    plan: PrecisionPlan | None = None,
    grid: tuple | None = None,
    lookahead: int = 0,
    host_slots: int = 0,
) -> MultiDeviceSchedule:
    """Emit per-device op streams for the block-cyclic tile Cholesky.

    ``grid=(p, q)`` (``p*q == ndev``; default ``(ndev, 1)``) arranges the
    devices as a 2D block-cyclic grid: tile ``(i, j)`` is owned by device
    ``TileLayout.owner_grid(i, j, grid)`` = ``(i % p) * q + (j % q)``.
    At column step ``k`` the diagonal owner updates and factors
    ``(k, k)``, ships the finalized panel row ``(k, 0..k)`` to the
    ``p - 1`` other devices of grid column ``k % q`` (BCAST on the owner
    stream, one RECV per receiver into its panel slot region), and each
    grid-column device then updates/factors its own rows of column ``k``
    locally under its own cache table.  For ``q > 1`` every finalized
    column tile ``(m, k)`` is additionally shipped to the ``q - 1``
    grid-row peers whose host slabs consume it in later steps (row-scoped
    BCAST; host-landing RECV with ``slot_c = -1``).

    With the default 1D grid this degenerates to the paper's tile-row
    ownership (every device computes at every step, one full-ndev panel
    broadcast per column); with ``ndev=1`` the single stream is
    op-for-op identical to :func:`build_schedule` for the same policy
    (no BCAST/RECV emitted).

    ``lookahead = L > 0`` pipelines up to ``L`` panels ahead of the
    trailing update (Donfack et al., arXiv:1110.2677): construction runs
    as an explicit task DAG plus a topological emitter
    (:mod:`repro.core.taskgraph`), finalized panel tiles are pushed
    eagerly to their grid-row peers, and the dispatch order becomes the
    emitter's chunk list (``dispatch``) instead of the column-major
    walk.  ``lookahead = 0`` reproduces the historical streams
    bit-identically.
    """
    policy = policy.lower()
    if policy not in ("sync", "v1", "v2", "v3"):
        raise ValueError(
            f"multi-device schedule supports sync/v1/v2/v3, got {policy!r}")
    if ndev < 1:
        raise ValueError(f"ndev must be >= 1, got {ndev}")
    if grid is None:
        grid = (ndev, 1)
    grid = tuple(grid)
    if (len(grid) != 2 or any(not isinstance(x, int) or x < 1 for x in grid)
            or grid[0] * grid[1] != ndev):
        raise ValueError(
            f"grid must be two positive ints with p*q == ndev={ndev}, "
            f"got {grid!r}")
    p, q = grid
    if plan is None:
        plan = uniform_plan(nt)
    if plan.classes.shape[0] != nt:
        raise ValueError("precision plan Nt mismatch")

    operand_cache = policy in ("v2", "v3")
    if lookahead < 0 or lookahead >= nt:
        raise ValueError(
            f"lookahead must be in [0, nt); got {lookahead} at nt={nt}")
    if lookahead > 0 and ndev < 2:
        raise ValueError("lookahead pipelines panels across devices; "
                         "it needs ndev > 1")
    if host_slots < 0:
        raise ValueError(f"host_slots must be >= 0, got {host_slots}")
    if host_slots > 0 and lookahead > 0:
        raise ValueError(
            "host_slots (the disk spill tier) is not supported with "
            "lookahead > 0: the spill post-pass inserts ops into each "
            "stream, which would invalidate the pipelined emitter's "
            "explicit dispatch-chunk indices")
    if cache_slots <= 0:
        cache_slots = default_cache_slots(policy, nt, multidevice=True,
                                          lookahead=lookahead)
    elif lookahead > 0 \
            and cache_slots < min_cache_slots(policy, lookahead=lookahead):
        raise ValueError(
            f"lookahead={lookahead} {policy} schedules need >= "
            f"{min_cache_slots(policy, lookahead=lookahead)} cache slots "
            f"(each in-flight panel pins one more), got {cache_slots}")

    # stage 1+2 (core/taskgraph.py): explicit task DAG -> topological
    # lookahead emitter; imported lazily to keep the module cycle one-way
    from .taskgraph import emit_pipelined_streams
    streams, dispatch, caches = emit_pipelined_streams(
        nt, tb, ndev, policy, cache_slots, plan, grid, lookahead)
    if host_slots > 0:
        # per-device host tier: each device bounds its own slab cache over
        # the shared disk store.  Host accesses are disjoint across
        # devices (a device LOADs/STOREs only owned rows; row-scoped
        # RECVs land in the receiver's own stream), so the per-stream
        # rewrite composes without cross-stream coordination.
        streams = [with_host_cache(s, tb, host_slots) for s in streams]

    msched = MultiDeviceSchedule(streams, nt, tb, ndev, policy, cache_slots,
                                 plan, panel_base=cache_slots if ndev > 1
                                 else -1, grid=grid, lookahead=lookahead,
                                 dispatch=dispatch, host_slots=host_slots)
    if operand_cache:
        msched.hits = [c.hits for c in caches]
        msched.misses = [c.misses for c in caches]
        msched.evictions = [c.evictions for c in caches]
    else:
        msched.misses = [msched.count(OpKind.LOAD, d) for d in range(ndev)]
        msched.hits = [0] * ndev
        msched.evictions = [0] * ndev
    return msched
