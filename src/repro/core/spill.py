"""Third memory tier: a disk-backed tile store behind the host-store API.

The paper's OOC design bounds *device* residency and streams tiles over
the host<->device link; this module applies the same static-schedule
treatment one tier down.  The full ``[Nt, Nt, tb, tb]`` fp64 tile store
lives on disk (:class:`DiskTileStore`, one memory-mapped ``.npy`` file),
and the host holds only ``host_slots`` tile slabs
(:class:`SpilledHostStore`).  Which slab holds which tile at every point
of the stream is decided ahead of time by
:func:`repro.core.schedule.with_host_cache`, which interleaves explicit
``FETCH`` (disk -> slab) and ``SPILL`` (slab -> disk) ops; executors
just replay them, exactly as they replay LOAD/STORE on the device edge.

Because residency is static, it is also *reconstructible*:
:func:`host_residency_at` replays only the FETCH records of a stream
prefix and returns the slab map at any op index — the piece that makes
mid-stream restart (:mod:`repro.checkpoint.restart`) cheap: a checkpoint
never persists the host slabs, it flushes them to disk and re-fetches on
resume.
"""
from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from .schedule import Op, OpKind


class DiskTileStore:
    """``[Nt, Nt, tb, tb]`` fp64 tile store memory-mapped from one file.

    The on-disk layout is exactly the in-memory host-store layout the
    executors already speak (``core/tiling.py``), so a tile read/write
    is one contiguous ``tb*tb*8``-byte strided slice of the map.  A
    ``meta.json`` sidecar records ``(nt, tb)`` for :meth:`open`.
    """

    def __init__(self, path: str, mmap: np.memmap):
        self.path = path
        self._map = mmap
        self.nt = int(mmap.shape[0])
        self.tb = int(mmap.shape[2])

    # ---- construction ----
    @classmethod
    def create(cls, path: str, nt: int, tb: int) -> "DiskTileStore":
        """Allocate a zero-filled store at ``path`` (a ``.npy`` file)."""
        mm = np.lib.format.open_memmap(
            path, mode="w+", dtype=np.float64, shape=(nt, nt, tb, tb))
        store = cls(path, mm)
        store._write_meta()
        return store

    @classmethod
    def from_tiles(cls, path: str, tiles: np.ndarray) -> "DiskTileStore":
        """Create a store initialized from an in-memory tile array."""
        tiles = np.asarray(tiles, dtype=np.float64)
        if tiles.ndim != 4 or tiles.shape[0] != tiles.shape[1] \
                or tiles.shape[2] != tiles.shape[3]:
            raise ValueError(
                f"expected a [Nt, Nt, tb, tb] tile array, got {tiles.shape}")
        store = cls.create(path, tiles.shape[0], tiles.shape[2])
        store._map[...] = tiles
        store.flush()
        return store

    @classmethod
    def from_matrix(cls, path: str, a: np.ndarray, tb: int) -> "DiskTileStore":
        """Create a store from a dense ``[n, n]`` matrix tiled at ``tb``."""
        from .tiling import to_tiles
        return cls.from_tiles(path, to_tiles(np.asarray(a), tb))

    @classmethod
    def open(cls, path: str, mode: str = "r+") -> "DiskTileStore":
        """Reopen an existing store (shape/dtype from the .npy header)."""
        if not os.path.exists(path):
            raise FileNotFoundError(f"no tile store at {path!r}")
        mm = np.lib.format.open_memmap(path, mode=mode)
        if mm.ndim != 4 or mm.dtype != np.float64:
            raise ValueError(
                f"{path!r} is not a [Nt, Nt, tb, tb] fp64 tile store "
                f"(shape {mm.shape}, dtype {mm.dtype})")
        return cls(path, mm)

    def _write_meta(self):
        with open(self.path + ".meta.json", "w") as f:
            json.dump({"nt": self.nt, "tb": self.tb}, f)

    # ---- tile I/O ----
    def read_tile(self, i: int, j: int) -> np.ndarray:
        return np.array(self._map[i, j])

    def write_tile(self, i: int, j: int, value: np.ndarray):
        self._map[i, j] = value

    def flush(self):
        self._map.flush()

    # ---- whole-store views (small problems / tests) ----
    def to_tiles(self) -> np.ndarray:
        return np.array(self._map)

    def to_array(self) -> np.ndarray:
        from .tiling import from_tiles
        return from_tiles(self.to_tiles())


class ArrayTileStore:
    """In-memory tile store with the :class:`DiskTileStore` interface.

    The backing tier for equivalence tests and the
    ``run_schedule_numpy`` convenience path: same protocol, no file.
    """

    def __init__(self, tiles: np.ndarray):
        tiles = np.asarray(tiles, dtype=np.float64)
        if tiles.ndim != 4 or tiles.shape[0] != tiles.shape[1] \
                or tiles.shape[2] != tiles.shape[3]:
            raise ValueError(
                f"expected a [Nt, Nt, tb, tb] tile array, got {tiles.shape}")
        self._tiles = tiles.copy()
        self.nt = int(tiles.shape[0])
        self.tb = int(tiles.shape[2])

    def read_tile(self, i: int, j: int) -> np.ndarray:
        return np.array(self._tiles[i, j])

    def write_tile(self, i: int, j: int, value: np.ndarray):
        self._tiles[i, j] = value

    def flush(self):
        pass

    def to_tiles(self) -> np.ndarray:
        return np.array(self._tiles)

    def to_array(self) -> np.ndarray:
        from .tiling import from_tiles
        return from_tiles(self.to_tiles())


class SpilledHostStore:
    """The bounded host tier: ``host_slots`` fp64 slabs over a disk store.

    Duck-types the two accesses the op interpreters make against the
    host store — ``host[i, j]`` read and ``host[i, j] = value`` — plus
    the two tier ops, :meth:`fetch` and :meth:`spill`.  Residency is
    never decided here: the schedule's FETCH ops *tell* the store which
    slab holds which tile, and an access to a tile the schedule never
    made resident is a scheduling bug surfaced as ``KeyError``.
    """

    def __init__(self, disk: DiskTileStore, host_slots: int):
        if host_slots < 1:
            raise ValueError(f"host_slots must be >= 1, got {host_slots}")
        self.disk = disk
        self.host_slots = host_slots
        self.slabs = np.zeros((host_slots, disk.tb, disk.tb),
                              dtype=np.float64)
        self.where: dict[tuple[int, int], int] = {}   # tile -> slab
        self.tile_of: list[Optional[tuple[int, int]]] = [None] * host_slots
        self.fetched_bytes = 0
        self.spilled_bytes = 0
        self.fetch_ops = 0       # every FETCH, binding (0-byte) included
        self.spill_ops = 0

    def _slab(self, i: int, j: int) -> int:
        try:
            return self.where[(i, j)]
        except KeyError:
            raise KeyError(
                f"tile ({i}, {j}) is not host-resident: the schedule "
                "accessed it without a preceding FETCH (spill post-pass "
                "bug, or ops replayed out of order)") from None

    def fetch(self, op: Op):
        s = op.slot_c
        old = self.tile_of[s]
        if old is not None:
            del self.where[old]
        self.fetch_ops += 1
        if op.bytes:
            self.slabs[s] = self.disk.read_tile(op.i, op.j)
            self.fetched_bytes += op.bytes
        # bytes == 0: binding fetch — the very next op overwrites the slab
        self.tile_of[s] = (op.i, op.j)
        self.where[(op.i, op.j)] = s

    def spill(self, op: Op):
        if self.tile_of[op.slot_c] != (op.i, op.j):
            raise RuntimeError(
                f"SPILL of tile ({op.i}, {op.j}) from slab {op.slot_c}, "
                f"but the slab holds {self.tile_of[op.slot_c]}")
        self.disk.write_tile(op.i, op.j, self.slabs[op.slot_c])
        self.spill_ops += 1
        self.spilled_bytes += op.bytes

    def apply(self, op: Op) -> bool:
        """Apply ``op`` if it is a host-tier op; return whether it was."""
        if op.kind is OpKind.FETCH:
            self.fetch(op)
            return True
        if op.kind is OpKind.SPILL:
            self.spill(op)
            return True
        return False

    def flush_residents(self):
        """Write every resident slab back to disk (checkpoint flush).

        Clean slabs rewrite the bytes they were fetched with — harmless —
        so no runtime dirty tracking is needed; after this the disk store
        alone determines every resident slab's contents.
        """
        for s, tile in enumerate(self.tile_of):
            if tile is not None:
                self.disk.write_tile(tile[0], tile[1], self.slabs[s])
        self.disk.flush()

    def refetch_residents(self):
        """Reload every resident slab from disk (restart path, after the
        residency map has been rebuilt by :func:`host_residency_at`)."""
        for s, tile in enumerate(self.tile_of):
            if tile is not None:
                self.slabs[s] = self.disk.read_tile(tile[0], tile[1])

    # the two accesses `_np_interpret_op` makes against a host store
    def __getitem__(self, ij: tuple[int, int]) -> np.ndarray:
        return self.slabs[self._slab(*ij)]

    def __setitem__(self, ij: tuple[int, int], value: np.ndarray):
        self.slabs[self._slab(*ij)] = value


def host_residency_at(ops: list[Op], upto: int) -> dict[tuple[int, int], int]:
    """Slab map ``{tile: slab}`` after replaying ``ops[:upto]``.

    Residency changes only at FETCH ops (a SPILL writes disk but leaves
    the slab bound), so replaying the FETCH records of the prefix is the
    whole reconstruction — this is what lets a restart rebuild the host
    tier from the schedule alone, with slab *contents* re-read from disk.
    """
    tile_of: dict[int, tuple[int, int]] = {}
    for op in ops[:upto]:
        if op.kind is OpKind.FETCH:
            tile_of[op.slot_c] = (op.i, op.j)
    return {tile: s for s, tile in tile_of.items()}
