"""Blocked triangular substitution against the factored tile store.

After the OOC factorization the host store holds the lower Cholesky
factor tile-by-tile (``tiles[i, j]`` with ``i >= j``; strictly-upper
tiles are untouched input and never read here).  These routines turn the
factorization into an actual linear solver without ever materializing the
dense n x n factor: the right-hand side is partitioned into ``Nt`` blocks
of ``tb`` rows and streamed through the same tiles the schedule produced.

    forward:   L z = b      z_i = L_ii^-1 (b_i - sum_{j<i} L_ij z_j)
    backward:  L^T x = z    x_i = L_ii^-T (z_i - sum_{j>i} L_ji^T x_j)

``cho_solve_tiles`` chains both, matching ``scipy.linalg.cho_solve`` on
the dense factor to fp64 round-off.  The per-block GEMM/TRSM structure is
the transfer-volume-optimal access pattern for an out-of-core factor: each
tile of L is read exactly once per substitution sweep.

Multi-RHS (0.7): every routine accepts ``k`` stacked right-hand sides as
an ``(n, k)`` matrix and solves them in **one** sweep over the tile
store — the per-block update becomes a ``(tb, tb) @ (tb, k)`` GEMM, so
the factor-read traffic (the OOC bottleneck) is amortized ``k``-fold.
This is the substrate :mod:`repro.serve`'s batcher stands on: concurrent
single-RHS solves against the same factor coalesce into one stacked
call.  For very wide stacks ``rhs_block`` tiles the sweep over RHS
*column panels* of at most that many columns, bounding the live
workspace to ``n * rhs_block`` doubles while keeping the per-panel GEMM
shape; each column's arithmetic is independent, so panel width only
affects scheduling, not the mathematical result.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import scipy.linalg as sla


def _blocks(tiles: np.ndarray, b: np.ndarray):
    """Validate shapes and view b as [Nt, tb, k] blocks (k may be 1)."""
    nt, nt2, tb, tb2 = tiles.shape
    if nt != nt2 or tb != tb2:
        raise ValueError(f"malformed tile store {tiles.shape}")
    n = nt * tb
    b = np.asarray(b, dtype=np.float64)
    if b.ndim not in (1, 2):
        raise ValueError(f"rhs must be (n,) or stacked (n, k), "
                         f"got shape {b.shape}")
    squeeze = b.ndim == 1
    if squeeze:
        b = b[:, None]
    if b.shape[1] == 0:
        raise ValueError("rhs has 0 columns; nothing to solve")
    if b.shape[0] != n:
        raise ValueError(f"rhs has {b.shape[0]} rows, factor is {n}x{n}")
    return b.reshape(nt, tb, b.shape[1]), squeeze


def _panels(k: int, rhs_block: Optional[int]):
    """Column-panel slices tiling ``k`` RHS columns (one slice if unset)."""
    if rhs_block is not None and rhs_block < 1:
        raise ValueError(f"rhs_block must be >= 1, got {rhs_block}")
    step = k if rhs_block is None else min(rhs_block, k)
    return [slice(c, min(c + step, k)) for c in range(0, k, step)]


def solve_lower_tiles(tiles: np.ndarray, b: np.ndarray,
                      rhs_block: Optional[int] = None) -> np.ndarray:
    """Solve ``L z = b`` with L in the [Nt, Nt, tb, tb] tile store.

    ``b`` may be ``(n,)`` or ``(n, k)`` stacked columns; ``rhs_block``
    optionally tiles the sweep over RHS column panels of that width.
    """
    blocks, squeeze = _blocks(tiles, b)
    nt = tiles.shape[0]
    z = np.empty_like(blocks)
    for cols in _panels(blocks.shape[2], rhs_block):
        for i in range(nt):
            rhs = blocks[i, :, cols].copy()
            for j in range(i):
                rhs -= tiles[i, j] @ z[j, :, cols]
            z[i, :, cols] = sla.solve_triangular(tiles[i, i], rhs,
                                                 lower=True)
    out = z.reshape(-1, blocks.shape[2])
    return out[:, 0] if squeeze else out


def solve_lower_t_tiles(tiles: np.ndarray, b: np.ndarray,
                        rhs_block: Optional[int] = None) -> np.ndarray:
    """Solve ``L^T x = b`` with L in the [Nt, Nt, tb, tb] tile store."""
    blocks, squeeze = _blocks(tiles, b)
    nt = tiles.shape[0]
    x = np.empty_like(blocks)
    for cols in _panels(blocks.shape[2], rhs_block):
        for i in range(nt - 1, -1, -1):
            rhs = blocks[i, :, cols].copy()
            for j in range(i + 1, nt):
                rhs -= tiles[j, i].T @ x[j, :, cols]
            x[i, :, cols] = sla.solve_triangular(tiles[i, i], rhs,
                                                 lower=True, trans="T")
    out = x.reshape(-1, blocks.shape[2])
    return out[:, 0] if squeeze else out


def cho_solve_tiles(tiles: np.ndarray, b: np.ndarray,
                    rhs_block: Optional[int] = None) -> np.ndarray:
    """Solve ``A x = b`` given ``A = L L^T`` in the tile store."""
    return solve_lower_t_tiles(tiles,
                               solve_lower_tiles(tiles, b, rhs_block),
                               rhs_block)


def logdet_tiles(tiles: np.ndarray) -> float:
    """``log|A| = 2 sum_i log L_ii`` from the diagonal tiles.

    A valid Cholesky factor has strictly positive diagonal entries; a
    non-positive entry means the factorization failed upstream (loss of
    positive definiteness, e.g. under an MxP ladder too aggressive for
    the matrix) and ``log`` would silently produce NaN/-inf.
    """
    nt = tiles.shape[0]
    acc = 0.0
    for i in range(nt):
        d = np.diag(tiles[i, i])
        if not np.all(d > 0.0):
            bad = np.flatnonzero(~(d > 0.0))
            raise ValueError(
                f"logdet: diagonal tile ({i}, {i}) has non-positive "
                f"diagonal entries at local indices {bad.tolist()} "
                f"(min value {d.min()!r}); the factor is not a valid "
                "Cholesky factor — the factorization lost positive "
                "definiteness (e.g. precision ladder too aggressive)")
        acc += float(np.sum(np.log(d)))
    return 2.0 * acc
