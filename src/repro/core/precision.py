"""Per-tile precision assignment (paper §IV-C, following Higham & Mary).

A tile ``A[i, j]`` may be demoted to a lower precision with unit roundoff
``eps_low`` when

    n_col_tiles * ||A_ij||_F / ||A||_F  <=  eps_target / eps_low

where ``eps_target`` is the requested accuracy level (the paper sweeps
1e-5 .. 1e-8 in Fig. 10/11) and ``n_col_tiles`` the number of tiles in the
column block.  Each tile gets the *lowest* precision in the ladder that
satisfies the inequality; diagonal tiles are pinned to the highest class
(POTRF stability — they always classify high in practice anyway).

TPU adaptation: the four-precision ladder is FP64/FP32/BF16/FP8-e4m3
(bf16 replaces fp16 — native on the MXU; see DESIGN.md §2).  The original
GPU ladder (fp16) is available via ``ladder="gpu"`` for paper-faithful
accuracy experiments.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Unit roundoffs u = 2^-(t) for each format (t = mantissa bits + 1).
# "f8e4m3s" is the *scaled* FP8 class: the same e4m3 storage format, but
# every tile is multiplied by a per-tile power-of-two scale chosen from its
# amax before the down-cast (and divided back on promotion), so the whole
# tile lands in the format's representable band and the roundoff really is
# the format's relative eps.  The unscaled class only achieves 2^-4 when
# the tile's values happen to fit e4m3's narrow range — see
# :func:`fp8_unscaled_eps`.
EPS = {
    "f64": 2.0 ** -53,
    "f32": 2.0 ** -24,
    "f16": 2.0 ** -11,
    "bf16": 2.0 ** -8,
    "f8e4m3": 2.0 ** -4,
    "f8e4m3s": 2.0 ** -4,
}

LADDERS = {
    # index 0 is highest precision; assignment picks the largest index
    # (lowest precision) whose eps satisfies the criterion.
    "tpu": ("f64", "f32", "bf16", "f8e4m3"),
    "gpu": ("f64", "f32", "f16", "f8e4m3"),
    # the paper's fourth precision as a scaled-FP8 tile class: per-tile
    # amax tracked at store time, scale applied in the kernel epilogue
    # and inverted on promotion (docs/kernels.md)
    "tpu-scaled": ("f64", "f32", "bf16", "f8e4m3s"),
    "gpu-scaled": ("f64", "f32", "f16", "f8e4m3s"),
}

BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e4m3s": 1}

# float8_e4m3fn representable band: max finite 448, smallest normal 2^-6.
FP8_MAX = 448.0
FP8_MIN_NORMAL = 2.0 ** -6


def fp8_scale(amax: float) -> float:
    """Per-tile power-of-two scale for the scaled-FP8 class.

    Chosen so ``amax * scale`` lands just inside e4m3's max finite value
    (the ``max_L`` rule of fp8_chol.cuh): the largest 2^e with
    ``amax * 2^e <= FP8_MAX``.  A power of two keeps the scale
    application/inversion exact in binary floating point, so the only
    rounding is the e4m3 mantissa truncation itself.  ``amax <= 0``
    (zero tile) returns 1.0 — nothing to scale.

    Computed via frexp (``amax = m * 2^e`` with ``m in [0.5, 1)``,
    ``448 = 0.875 * 2^9``) rather than ``floor(log2(448 / amax))``: the
    executors' numpy and jax implementations must agree *bitwise* on the
    scale, and a log2 that lands one ulp across an integer boundary would
    shift the scale a whole octave.
    """
    if not amax > 0.0 or not np.isfinite(amax):
        return 1.0
    m, e = np.frexp(amax)
    return float(2.0 ** int((8 - e) + (1 if m <= 0.875 else 0)))


def fp8_unscaled_eps(amax: float) -> float:
    """Effective roundoff of the *unscaled* FP8 class for a tile with the
    given amax.

    Inside the representable band the unit roundoff is the format's
    2^-4.  Outside it the cast is no longer a rounding: values above
    ``FP8_MAX`` saturate (relative error up to ``1 - FP8_MAX/amax``) and
    tiles living entirely below the subnormal floor flush toward zero
    (relative error approaching 1).  Classification against the plain
    ``EPS["f8e4m3"]`` silently assumed the in-band case; this is the
    honest per-tile figure the criterion must use when the amax is known.
    """
    u = EPS["f8e4m3"]
    if not amax > 0.0 or not np.isfinite(amax):
        return u
    if amax > FP8_MAX:            # saturation: amax clips to FP8_MAX
        return max(u, 1.0 - FP8_MAX / amax)
    if amax < FP8_MIN_NORMAL:     # gradual underflow: 3 mantissa bits of
        # headroom below the normal floor, then flush to zero
        return min(1.0, u * FP8_MIN_NORMAL / amax)
    return u


def class_eps(name: str, amax: float | None = None) -> float:
    """Unit roundoff of one precision class, amax-aware for FP8.

    The scaled class always achieves the format eps (the per-tile scale
    recentres the tile into the representable band); the unscaled class
    degrades outside the band per :func:`fp8_unscaled_eps`.  ``amax=None``
    keeps the historical format-eps behaviour for every class.
    """
    if amax is None or name != "f8e4m3":
        return EPS[name]
    return fp8_unscaled_eps(amax)


@dataclasses.dataclass(frozen=True, eq=False)
class PrecisionPlan:
    """Per-tile precision classes for one factorization.

    Value-hashable (classes compared/hashed by content) so that a plan can
    key the ``(n, config)`` solver cache of :mod:`repro.core.api`.
    """

    classes: np.ndarray        # [Nt, Nt] int8, class index into `ladder`
    ladder: tuple[str, ...]    # precision names, high -> low
    eps_target: float

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, PrecisionPlan)
            and self.ladder == other.ladder
            and self.eps_target == other.eps_target
            and self.classes.shape == other.classes.shape
            and np.array_equal(self.classes, other.classes)
        )

    def __hash__(self) -> int:
        return hash((self.ladder, self.eps_target, self.classes.shape,
                     self.classes.tobytes()))

    @property
    def nt(self) -> int:
        return self.classes.shape[0]

    def name(self, i: int, j: int) -> str:
        return self.ladder[int(self.classes[i, j])]

    def bytes_of(self, i: int, j: int, tb: int) -> int:
        return BYTES[self.name(i, j)] * tb * tb

    def histogram(self) -> dict[str, int]:
        out = {name: 0 for name in self.ladder}
        nt = self.nt
        for j in range(nt):
            for i in range(j, nt):
                out[self.name(i, j)] += 1
        return out


def uniform_plan(nt: int, name: str = "f64", ladder: str = "tpu") -> PrecisionPlan:
    lad = LADDERS[ladder]
    cls = np.full((nt, nt), lad.index(name), dtype=np.int8)
    return PrecisionPlan(cls, lad, eps_target=EPS[name])


def assign_precision(
    tile_norms: np.ndarray,      # [Nt, Nt] Frobenius norms of the tiles
    matrix_norm: float,          # ||A||_F
    eps_target: float,
    ladder: str = "tpu",
    max_classes: int = 4,
    tile_amax: np.ndarray | None = None,   # [Nt, Nt] per-tile max |entry|
) -> PrecisionPlan:
    """Paper Fig. 4: pick per-tile precision from the threshold criterion.

    ``tile_amax``: per-tile absolute maxima.  When given, the criterion
    classifies FP8 tiles against their *effective* roundoff
    (:func:`class_eps`): a tile whose values saturate or underflow e4m3's
    band no longer qualifies for the unscaled ``f8e4m3`` class, while the
    scaled ``f8e4m3s`` class keeps the format eps regardless of amax (the
    per-tile scale recentres it).  ``None`` preserves the historical
    format-eps classification for every class.
    """
    lad = LADDERS[ladder][:max_classes]
    nt = tile_norms.shape[0]
    classes = np.zeros((nt, nt), dtype=np.int8)
    for j in range(nt):
        n_col = nt - j  # tiles in this column block
        for i in range(j, nt):
            if i == j:
                classes[i, j] = 0  # diagonal pinned high
                continue
            ratio = n_col * tile_norms[i, j] / max(matrix_norm, np.finfo(np.float64).tiny)
            amax = None if tile_amax is None else float(tile_amax[i, j])
            chosen = 0
            for c in range(len(lad) - 1, 0, -1):
                if ratio <= eps_target / class_eps(lad[c], amax):
                    chosen = c
                    break
            classes[i, j] = chosen
    return PrecisionPlan(classes, LADDERS[ladder][:max_classes], eps_target)


def tile_norms(tiles: np.ndarray) -> tuple[np.ndarray, float]:
    """Frobenius norms per tile + whole-matrix norm from a [Nt,Nt,tb,tb] store."""
    norms = np.sqrt((tiles.astype(np.float64) ** 2).sum(axis=(2, 3)))
    nt = norms.shape[0]
    total = 0.0
    for j in range(nt):
        for i in range(j, nt):
            w = 1.0 if i == j else 2.0  # symmetric: off-diag tiles count twice
            total += w * norms[i, j] ** 2
    return norms, float(np.sqrt(total))


def tile_amax(tiles: np.ndarray) -> np.ndarray:
    """Per-tile absolute maxima [Nt, Nt] from a [Nt,Nt,tb,tb] store —
    the store-time amax record the scaled-FP8 class keys its scales on."""
    return np.abs(tiles.astype(np.float64)).max(axis=(2, 3))


def scale_table(tiles: np.ndarray, plan: PrecisionPlan) -> np.ndarray:
    """The ``[Nt, Nt]`` float32 scale table that rides alongside a tile
    store holding scaled-FP8 tiles (docs/kernels.md).

    Entry ``(i, j)`` is the power-of-two factor a scaled-FP8 tile is
    multiplied by before the e4m3 down-cast (:func:`fp8_scale` of its
    amax) and divided by on promotion; tiles of every other class carry
    the neutral 1.0.  Executors recompute the entry whenever they round a
    tile through the scaled class (amax is tracked *at store time*, so
    the table follows the factorization), which keeps the table a pure
    function of ``(tiles, plan)`` — convenient for checkpoints and tests.
    """
    amax = tile_amax(tiles)
    nt = plan.nt
    out = np.ones((nt, nt), dtype=np.float32)
    for j in range(nt):
        for i in range(nt):
            if plan.name(i, j) == "f8e4m3s":
                out[i, j] = fp8_scale(float(amax[i, j]))
    return out
