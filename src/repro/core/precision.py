"""Per-tile precision assignment (paper §IV-C, following Higham & Mary).

A tile ``A[i, j]`` may be demoted to a lower precision with unit roundoff
``eps_low`` when

    n_col_tiles * ||A_ij||_F / ||A||_F  <=  eps_target / eps_low

where ``eps_target`` is the requested accuracy level (the paper sweeps
1e-5 .. 1e-8 in Fig. 10/11) and ``n_col_tiles`` the number of tiles in the
column block.  Each tile gets the *lowest* precision in the ladder that
satisfies the inequality; diagonal tiles are pinned to the highest class
(POTRF stability — they always classify high in practice anyway).

TPU adaptation: the four-precision ladder is FP64/FP32/BF16/FP8-e4m3
(bf16 replaces fp16 — native on the MXU; see DESIGN.md §2).  The original
GPU ladder (fp16) is available via ``ladder="gpu"`` for paper-faithful
accuracy experiments.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Unit roundoffs u = 2^-(t) for each format (t = mantissa bits + 1).
EPS = {
    "f64": 2.0 ** -53,
    "f32": 2.0 ** -24,
    "f16": 2.0 ** -11,
    "bf16": 2.0 ** -8,
    "f8e4m3": 2.0 ** -4,
}

LADDERS = {
    # index 0 is highest precision; assignment picks the largest index
    # (lowest precision) whose eps satisfies the criterion.
    "tpu": ("f64", "f32", "bf16", "f8e4m3"),
    "gpu": ("f64", "f32", "f16", "f8e4m3"),
}

BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1}


@dataclasses.dataclass(frozen=True, eq=False)
class PrecisionPlan:
    """Per-tile precision classes for one factorization.

    Value-hashable (classes compared/hashed by content) so that a plan can
    key the ``(n, config)`` solver cache of :mod:`repro.core.api`.
    """

    classes: np.ndarray        # [Nt, Nt] int8, class index into `ladder`
    ladder: tuple[str, ...]    # precision names, high -> low
    eps_target: float

    def __eq__(self, other) -> bool:
        return (
            isinstance(other, PrecisionPlan)
            and self.ladder == other.ladder
            and self.eps_target == other.eps_target
            and self.classes.shape == other.classes.shape
            and np.array_equal(self.classes, other.classes)
        )

    def __hash__(self) -> int:
        return hash((self.ladder, self.eps_target, self.classes.shape,
                     self.classes.tobytes()))

    @property
    def nt(self) -> int:
        return self.classes.shape[0]

    def name(self, i: int, j: int) -> str:
        return self.ladder[int(self.classes[i, j])]

    def bytes_of(self, i: int, j: int, tb: int) -> int:
        return BYTES[self.name(i, j)] * tb * tb

    def histogram(self) -> dict[str, int]:
        out = {name: 0 for name in self.ladder}
        nt = self.nt
        for j in range(nt):
            for i in range(j, nt):
                out[self.name(i, j)] += 1
        return out


def uniform_plan(nt: int, name: str = "f64", ladder: str = "tpu") -> PrecisionPlan:
    lad = LADDERS[ladder]
    cls = np.full((nt, nt), lad.index(name), dtype=np.int8)
    return PrecisionPlan(cls, lad, eps_target=EPS[name])


def assign_precision(
    tile_norms: np.ndarray,      # [Nt, Nt] Frobenius norms of the tiles
    matrix_norm: float,          # ||A||_F
    eps_target: float,
    ladder: str = "tpu",
    max_classes: int = 4,
) -> PrecisionPlan:
    """Paper Fig. 4: pick per-tile precision from the threshold criterion."""
    lad = LADDERS[ladder][:max_classes]
    nt = tile_norms.shape[0]
    classes = np.zeros((nt, nt), dtype=np.int8)
    for j in range(nt):
        n_col = nt - j  # tiles in this column block
        for i in range(j, nt):
            if i == j:
                classes[i, j] = 0  # diagonal pinned high
                continue
            ratio = n_col * tile_norms[i, j] / max(matrix_norm, np.finfo(np.float64).tiny)
            chosen = 0
            for c in range(len(lad) - 1, 0, -1):
                if ratio <= eps_target / EPS[lad[c]]:
                    chosen = c
                    break
            classes[i, j] = chosen
    return PrecisionPlan(classes, LADDERS[ladder][:max_classes], eps_target)


def tile_norms(tiles: np.ndarray) -> tuple[np.ndarray, float]:
    """Frobenius norms per tile + whole-matrix norm from a [Nt,Nt,tb,tb] store."""
    norms = np.sqrt((tiles.astype(np.float64) ** 2).sum(axis=(2, 3)))
    nt = norms.shape[0]
    total = 0.0
    for j in range(nt):
        for i in range(j, nt):
            w = 1.0 if i == j else 2.0  # symmetric: off-diag tiles count twice
            total += w * norms[i, j] ** 2
    return norms, float(np.sqrt(total))
