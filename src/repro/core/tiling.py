"""Tile layout utilities for the tile-based Cholesky factorization.

The matrix A (n x n, SPD) is partitioned into Nt x Nt square tiles of size
tb.  Only the lower triangle is stored/computed (the paper copies only the
triangular part back to the host — Fig. 8 discussion).

Tile indexing follows the paper: A[i, j] with i >= j for the lower triangle.
The *host store* is a dense [Nt, Nt, tb, tb] array (upper tiles unused) so
that loads/stores are single dynamic slices — on TPU this buffer can live in
``pinned_host`` memory (out-of-core), see core/cholesky.py.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


def grid_owner(i: int, j: int, p: int, q: int) -> int:
    """Device id of tile ``(i, j)`` on a ``p x q`` block-cyclic grid —
    the single ownership rule shared by the schedule builder, both
    replay orders, and the multi-device executor::

        grid_owner(i, j, p, q) == (i % p) * q + (j % q)

    Devices are numbered row-major over the grid (device ``d`` sits at
    grid position ``(d // q, d % q)``); ``q = 1`` degenerates to the 1D
    tile-row rule ``i % p``.
    """
    return (i % p) * q + (j % q)


@dataclasses.dataclass(frozen=True)
class TileLayout:
    n: int          # matrix dimension
    tb: int         # tile size
    ordering: str = "left_looking"

    def __post_init__(self):
        if self.n % self.tb != 0:
            raise ValueError(f"n={self.n} must be a multiple of tb={self.tb}")

    @property
    def nt(self) -> int:
        return self.n // self.tb

    def lower_tiles(self) -> Iterator[tuple[int, int]]:
        for j in range(self.nt):
            for i in range(j, self.nt):
                yield (i, j)

    def num_lower_tiles(self) -> int:
        return self.nt * (self.nt + 1) // 2

    def owner(self, i: int, num_workers: int) -> int:
        """1D block-cyclic owner of tile-row i (paper Fig. 1b / Fig. 5a)."""
        return i % num_workers

    def panel_slots(self, lookahead: int = 0) -> int:
        """Device slots the multi-device panel region occupies above the
        cache: one ``nt``-slot bank per in-flight panel column.  The
        pipelined emitter rotates ``lookahead + 1`` banks (column ``kc``
        lands in bank ``kc % (lookahead + 1)``), so ``lookahead=0`` is
        the classic single ``nt``-slot region.  Used by the tuner's
        memory feasibility math (``reserve = panel_slots(L)``)."""
        return (lookahead + 1) * self.nt

    def owner_grid(self, i: int, j: int, grid: tuple) -> int:
        """2D block-cyclic owner of tile (i, j) on a ``p x q`` device grid.

        Devices are numbered row-major over the grid: device ``d`` sits at
        grid position ``(d // q, d % q)`` and owns every tile whose row is
        congruent to its grid row (mod p) and whose column is congruent to
        its grid column (mod q)::

            owner_grid(i, j, (p, q)) == (i % p) * q + (j % q)

        ``grid=(P, 1)`` degenerates to the 1D tile-row ownership of
        :meth:`owner` (each device owns whole rows), which is the paper's
        multi-GPU layout; a genuinely 2D grid cuts the per-device panel
        broadcast volume from O(P) to O(p + q) receivers per tile (see
        docs/multidevice.md).
        """
        p, q = grid
        return grid_owner(i, j, p, q)


def to_tiles(a: np.ndarray, tb: int) -> np.ndarray:
    """[n, n] -> [Nt, Nt, tb, tb] host tile store."""
    n = a.shape[0]
    nt = n // tb
    return (
        a.reshape(nt, tb, nt, tb).transpose(0, 2, 1, 3).copy()
    )


def from_tiles(t: np.ndarray) -> np.ndarray:
    """[Nt, Nt, tb, tb] -> [n, n]."""
    nt, _, tb, _ = t.shape
    return t.transpose(0, 2, 1, 3).reshape(nt * tb, nt * tb)


def random_spd(n: int, seed: int = 0, dtype=np.float64) -> np.ndarray:
    """Random well-conditioned SPD matrix (unit diagonal dominance bump)."""
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((n, n)).astype(dtype) / np.sqrt(n)
    a = b @ b.T + np.eye(n, dtype=dtype) * 2.0
    return 0.5 * (a + a.T)
