"""Multi-device left-looking tile Cholesky (paper §IV-D, Fig. 5/9).

TPU-native adaptation of the paper's 1D block-cyclic multi-GPU scheme:

* tile-row ``i`` is owned by device ``i % P`` (block-cyclic, Fig. 5a);
* the left-looking order makes the *panel row broadcast* the only
  communication: at column step ``k`` the owner finalizes the diagonal
  tile locally, then row ``k`` (which is final: columns < k are done)
  is broadcast once (``psum`` of a zero-masked contribution); every
  device then updates/factors its own rows of column ``k`` locally.

This mirrors the paper's claim that the lazy left-looking variant avoids
the right-looking variant's collective storm: exactly one broadcast of at
most Nt tiles per column step, everything else is device-local.

Implementation: ``shard_map`` over one mesh axis; the tile store is
row-cyclically permuted on the host so each device's shard is a dense
``[Nt/P, Nt, tb, tb]`` slab.  The k-loop is a ``lax.fori_loop``; the
update sweep is a single masked einsum (full-width contraction against
the zero-padded broadcast row), trading ≤2x redundant MXU flops for a
scan-free, layout-stable inner step.

Role in 0.3+: this shard_map einsum path is the *reference baseline* for
the multi-device executors.  The production path is the static-schedule
stack — ``schedule.build_multidevice_schedule`` (per-device op streams
with BCAST/RECV edges) replayed on real devices by
``cholesky.make_multidevice_jax_executor`` (one jitted column-segment
sequence per device, device-to-device panel transfers), with
``analytics.simulate_multi`` as its exact event model and
``cholesky.run_multidevice_numpy`` as the host-side oracle.  The
equivalence suite (``tests/test_backend_equivalence.py``) pins all of
them against each other and against LAPACK; :func:`modeled_scaling`
below ties the Fig. 9 scaling argument to the exact op streams the
executor replays.  Keep this path dependency-light and *simple* — its
value is being an independently-derived answer, not being fast.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                      # jax >= 0.6: top-level export
    from jax import shard_map
except ImportError:                       # older jax: experimental home
    from jax.experimental.shard_map import shard_map

from .tiling import to_tiles, from_tiles


def _cyclic_permute(nt: int, p: int) -> np.ndarray:
    """Global row order so that contiguous shards = block-cyclic ownership.

    Device d gets global rows [d, d+P, d+2P, ...] as its contiguous slab.
    """
    return np.concatenate([np.arange(d, nt, p) for d in range(p)])


def distributed_cholesky(a: np.ndarray, tb: int, mesh: Mesh, axis: str = "model",
                         dtype=jnp.float64) -> np.ndarray:
    """Factor SPD ``a`` across ``mesh[axis]`` devices. Returns L (host)."""
    n = a.shape[0]
    nt = n // tb
    p = mesh.shape[axis]
    if nt % p != 0:
        raise ValueError(f"Nt={nt} must be divisible by device count {p}")
    nt_loc = nt // p

    perm = _cyclic_permute(nt, p)
    inv_perm = np.argsort(perm)

    tiles = to_tiles(np.asarray(a, dtype=np.float64), tb)[perm]  # [Nt, Nt, tb, tb]
    tiles = jnp.asarray(tiles, dtype=dtype)

    @jax.jit
    def factor(tiles_sharded):
        def body(local):   # local: [Nt_loc, Nt, tb, tb]
            dev = jax.lax.axis_index(axis)

            def col_step(k, loc):
                owner = k % p
                rk = k // p                      # local row idx on owner
                # ---- 1) owner updates + factors the diagonal tile ----
                my_row = jax.lax.dynamic_index_in_dim(loc, rk, axis=0,
                                                      keepdims=False)  # [Nt, tb, tb]
                colmask = (jnp.arange(nt) < k).astype(loc.dtype)[:, None, None]
                row_m = my_row * colmask
                # SYRK sweep: A[k,k] -= sum_n<k A[k,n] A[k,n]^T (masked full width)
                delta = jnp.einsum("nab,ncb->ac", row_m, row_m,
                                   preferred_element_type=loc.dtype)
                akk = jax.lax.dynamic_index_in_dim(my_row, k, axis=0,
                                                   keepdims=False) - delta
                lkk = jnp.linalg.cholesky(0.5 * (akk + akk.T))
                # write L[k,k] back into the owner's slab (no-op elsewhere)
                new_row = jax.lax.dynamic_update_index_in_dim(my_row, lkk, k, axis=0)
                is_owner = (dev == owner)
                upd_row = jnp.where(is_owner, new_row, my_row)
                loc = jax.lax.dynamic_update_index_in_dim(loc, upd_row, rk, axis=0)

                # ---- 2) broadcast final row k (masked psum) ----
                contrib = jnp.where(is_owner, upd_row, jnp.zeros_like(upd_row))
                row_k = jax.lax.psum(contrib, axis)          # [Nt, tb, tb]

                # ---- 3) everyone updates its rows of column k ----
                row_k_m = row_k * colmask                    # zero cols >= k
                lkk_b = jax.lax.dynamic_index_in_dim(row_k, k, axis=0,
                                                     keepdims=False)
                # GEMM sweep for all local rows at once (masked full width)
                deltas = jnp.einsum("rnab,ncb->rac", loc * colmask[None],
                                    row_k_m, preferred_element_type=loc.dtype)
                cur = loc[:, k]                              # [Nt_loc, tb, tb]
                upd = cur - deltas
                # TRSM: X L^T = C  ->  L X^T = C^T
                lkk_batch = jnp.broadcast_to(lkk_b, (nt_loc,) + lkk_b.shape)
                xt = jax.scipy.linalg.solve_triangular(
                    lkk_batch, jnp.swapaxes(upd, -1, -2), lower=True)
                x = jnp.swapaxes(xt, -1, -2)
                # only rows with global index m > k take the TRSM result
                gidx = dev + p * jnp.arange(nt_loc)
                take = (gidx > k)[:, None, None]
                newcol = jnp.where(take, x, cur)
                loc = loc.at[:, k].set(newcol)
                return loc

            local = jax.lax.fori_loop(0, nt, col_step, local)
            return local

        return shard_map(
            body, mesh=mesh,
            in_specs=P(axis), out_specs=P(axis), check_rep=False,
        )(tiles_sharded)

    with mesh:
        sharded = jax.device_put(
            tiles, jax.sharding.NamedSharding(mesh, P(axis)))
        out = factor(sharded)
    out = np.asarray(out, dtype=np.float64)[inv_perm]
    return np.tril(from_tiles(out))


def panel_broadcast_bytes(nt: int, tb: int, p: int, word: int = 8) -> int:
    """Analytic per-factorization collective volume: one row-k broadcast per
    step, each (k+1) tiles to (P-1) receivers (for the roofline model).

    The static multi-device schedule reproduces this number exactly:
    ``build_multidevice_schedule(nt, tb, p).bcast_bytes()`` (uniform-f64
    plans) sums the same tiles op by op."""
    total_tiles = sum(k + 1 for k in range(nt))
    return total_tiles * tb * tb * word * (p - 1)


def grid_broadcast_bytes(nt: int, tb: int, grid: tuple,
                         word: int = 8) -> int:
    """Analytic collective volume of the ``p x q`` 2D block-cyclic
    schedule (uniform word-size tiles): at step ``k`` the panel row
    ``(k, 0..k)`` goes to the ``p - 1`` other devices of grid column
    ``k % q``, and each finalized column tile ``(m, k)``, ``m > k``,
    goes to its ``q - 1`` grid-row peers.

    ``grid=(P, 1)`` reduces to :func:`panel_broadcast_bytes`; for a true
    2D factorization of ``P >= 2`` devices the total is strictly smaller
    (roughly ``(p + q - 2) / (P - 1)`` of the 1D volume, the classic
    O(sqrt(P)) communication scaling).  The static schedule reproduces
    this number exactly:
    ``build_multidevice_schedule(nt, tb, p*q, grid=grid).bcast_bytes()``.
    """
    p, q = grid
    panel_tiles = sum(k + 1 for k in range(nt))          # column-scoped
    column_tiles = sum(nt - 1 - k for k in range(nt))    # row-scoped
    return tb * tb * word * ((p - 1) * panel_tiles
                             + (q - 1) * column_tiles)


def modeled_scaling(nt: int, tb: int, ndevs=(1, 2, 4), policy: str = "v3",
                    hw_name: str = "gh200",
                    link_bw: float | None = None,
                    grid_of=None) -> list[dict]:
    """Fig. 9 scaling rows from the *same static schedules the executors
    replay* — an exact event simulation, not a side-channel estimate.

    For each device count, builds the block-cyclic multi-device schedule
    (1D tile-row ownership by default; ``grid_of`` maps a device count
    to an explicit ``(p, q)`` grid, e.g. ``{4: (2, 2)}``), runs
    :func:`~repro.core.analytics.simulate_multi` on the named hardware
    preset (``link_bw`` overrides the interconnect), and reports
    makespan, speedup/efficiency vs the 1-device schedule, and the
    broadcast volume."""
    from .analytics import HW, simulate_multi
    from .schedule import build_multidevice_schedule

    hw = HW[hw_name]
    grid_of = grid_of or {}
    m1 = build_multidevice_schedule(nt, tb, 1, policy)
    r1 = simulate_multi(m1, hw, link_bw=link_bw)
    t1 = r1.makespan
    rows = []
    for p in ndevs:
        if p == 1:
            msched, r = m1, r1
        else:
            msched = build_multidevice_schedule(nt, tb, p, policy,
                                                grid=grid_of.get(p))
            r = simulate_multi(msched, hw, link_bw=link_bw)
        rows.append({
            "ndev": p,
            "grid": list(msched.grid),
            "hw": hw_name,
            "policy": policy,
            "makespan": r.makespan,
            "tflops": r.tflops,
            "speedup": t1 / r.makespan,
            "efficiency": t1 / (p * r.makespan),
            "compute_efficiency": r.compute_efficiency,
            "bcast_bytes": msched.bcast_bytes(),
            "link_busy": r.link_busy,
        })
    return rows
