"""Two-phase planner/executor API: ``CholeskyConfig`` -> plan -> solve.

The paper's core claim is that the schedule is *static*: built once ahead
of time, replayed for every factorization.  This module makes that the
shape of the public API instead of an implementation detail:

    import repro

    cfg = repro.CholeskyConfig(tb=256, policy="v3")
    solver = repro.plan(n, cfg).compile()   # schedule + jit, built ONCE
    for a in covariance_stream:             # amortized across calls
        l = solver.factor(a)
        x = solver.solve(b)                 # blocked fwd/back substitution

Phases:

* :class:`CholeskyConfig` — frozen, hashable description of everything
  that determines the op stream and the executor: tiling (``tb``), policy,
  precision (``eps_target``/``ladder``/explicit ``plan``), device-memory
  budget (``cache_slots``), and execution (``backend``/``compute_dtype``/
  ``use_pallas``/``block``/``ndev``).  Validation is *eager*: unsupported
  combinations raise at construction, not deep inside an executor (the old
  ``ooc_cholesky`` silently ignored four kwargs when ``ndev > 1``).
* :func:`plan` — builds the static schedule for ``(n, config)`` and caches
  the resulting :class:`CholeskyPlan` (LRU, value-keyed: two configs that
  compare equal share one plan).  The schedule is the unified
  :class:`~repro.core.schedule.MultiDeviceSchedule`; ``ndev=1`` is its
  degenerate single-stream form.
* :meth:`CholeskyPlan.compile` — builds the executor (one ``jax.jit``
  trace for the JAX backend; for ``ndev > 1`` one jitted column-segment
  sequence per device stream with device-to-device panel broadcasts —
  :class:`~repro.core.cholesky.MultiDeviceJaxExecutor`) exactly once per
  plan and returns a :class:`OOCSolver` over it.  The solver is fresh per
  ``compile()`` call — factored state is never shared between call
  sites — but every solver of a plan replays the same compiled executor.
  ``backend="auto"`` resolves multi-device configs to jax whenever the
  process sees at least ``ndev`` devices, else to the NumPy host replay.

Mixed precision: an ``eps_target`` plan depends on the matrix values
(tile norms), so a *reusable* solver needs the plan frozen up front —
``config.specialize(a)`` computes the Higham-Mary plan from a
representative matrix and returns a config with it pinned.  The one-shot
:func:`repro.core.cholesky.ooc_cholesky` shim does this per call.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Optional

import numpy as np

from .precision import LADDERS, PrecisionPlan, uniform_plan
from .schedule import (MultiDeviceSchedule, OpKind,
                       build_multidevice_schedule, build_schedule,
                       min_cache_slots)
from .tiling import TileLayout, from_tiles, to_tiles


def _obs_registry():
    """The process-wide obs metrics registry, imported lazily so the
    core planner stays importable without the obs package (and so the
    repro package __init__ never cycles through obs at import time)."""
    try:
        from repro.obs.metrics import REGISTRY
        return REGISTRY
    except Exception:
        return None

_POLICIES = ("sync", "async", "v1", "v2", "v3", "v4", "auto")
_MULTIDEV_POLICIES = ("sync", "v1", "v2", "v3")
_BACKENDS = ("auto", "jax", "numpy")
_DEFAULT_BLOCK = (4, 4)


@dataclasses.dataclass(frozen=True)
class CholeskyConfig:
    """Frozen description of one OOC Cholesky pipeline.

    Hashable by value (including the optional :class:`PrecisionPlan`), so
    it can key the plan cache: equal configs share one schedule and one
    compiled executor.  Fields group into tiling (``tb``), schedule
    policy (``policy``/``cache_slots``/``block``), precision
    (``eps_target``/``ladder``/``plan``), distribution (``ndev``/
    ``grid``), and execution (``backend``/``compute_dtype``/
    ``use_pallas``); see docs/architecture.md for the subsystem map and
    docs/schedule-format.md for what each knob does to the op stream.

    Multi-device (``ndev > 1``): ``grid=(p, q)`` with ``p*q == ndev``
    arranges the devices as a 2D block-cyclic grid (tile ``(i, j)`` is
    owned by device ``(i%p)*q + (j%q)``), which scopes the panel
    broadcast to ``p-1`` receivers and adds a ``q-1``-receiver ownership
    broadcast — strictly less interconnect traffic than 1D for every
    true 2D factorization.  ``grid=None`` means the 1D tile-row layout
    ``(ndev, 1)``, except under the autotuner, which searches every
    factorization of ``ndev`` (docs/multidevice.md).  ``lookahead=L > 0``
    pipelines up to ``L`` panel columns ahead of the trailing update
    (eager peer pushes + rotating panel regions — each depth pins one
    extra cache slot and ``nt`` extra panel slots); ``None`` means 0,
    or a searched dimension when the tuner is engaged.

    Disk tier: ``host_slots=H > 0`` bounds host residency to ``H`` tile
    slabs over a disk-backed store — the builder post-pass interleaves
    explicit ``FETCH``/``SPILL`` ops, executors replay them against a
    :class:`~repro.core.spill.DiskTileStore`, and the factorization can
    exceed host memory (docs/spill.md).  Incompatible with
    ``lookahead > 0``; ``ndev > 1`` spill schedules run on the NumPy
    replay.

    Open dimensions (0.4): ``tb=0`` and/or ``policy="auto"`` leave those
    axes to the autotuner — ``plan()`` resolves them through
    :func:`repro.tune.resolve_config` (exact-simulation search against
    the ``hw`` preset, the process default hardware, or the ``gh200``
    preset) before building the schedule.  With the tuner engaged,
    ``cache_slots=0`` means "search slot budgets" and ``grid=None``
    means "search grids" instead of the builder defaults
    (docs/tuning.md).
    """

    tb: int                                   # tile size (0 = autotune)
    policy: str = "v3"                        # sync/async/v1-v4, or "auto"
    eps_target: Optional[float] = None        # Higham-Mary accuracy level
    ladder: str = "tpu"                       # precision ladder name
    plan: Optional[PrecisionPlan] = None      # explicit per-tile classes
    cache_slots: int = 0                      # 0 = policy default/tuned
    backend: str = "auto"                     # auto -> jax if devices suffice
    compute_dtype: Any = None                 # jax backend compute dtype
    use_pallas: bool = False                  # Pallas tile kernels (jax)
    fuse_columns: bool = False                # fused column-step megakernel
                                              #   (one Pallas launch per
                                              #   column step, jax backend)
    block: tuple = _DEFAULT_BLOCK             # v4 (h, w) update block
    ndev: int = 1                             # block-cyclic devices
    grid: Optional[tuple] = None              # (p, q) device grid; None =
                                              #   1D (ndev, 1), or searched
                                              #   when the tuner is engaged
    hw: Optional[str] = None                  # analytics.HW preset name
    lookahead: Optional[int] = None           # pipelined panels ahead of the
                                              #   trailing update (ndev > 1);
                                              #   None = 0, or searched when
                                              #   the tuner is engaged
    host_slots: int = 0                       # bounded host tier over a disk
                                              #   store (0 = host-resident;
                                              #   > 0 inserts FETCH/SPILL)

    def __post_init__(self):
        object.__setattr__(self, "policy", str(self.policy).lower())
        object.__setattr__(self, "block", tuple(self.block))
        if self.tb < 0:
            raise ValueError(f"tb must be >= 1, or 0 to let the tuner "
                             f"pick it, got {self.tb}")
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"expected one of {_POLICIES}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {_BACKENDS}")
        if self.ladder not in LADDERS:
            raise ValueError(f"unknown ladder {self.ladder!r}; "
                             f"expected one of {tuple(LADDERS)}")
        if self.eps_target is not None and self.eps_target <= 0:
            raise ValueError(f"eps_target must be > 0, got {self.eps_target}")
        if self.eps_target is not None and self.plan is not None:
            raise ValueError("pass either eps_target or an explicit plan, "
                             "not both")
        if self.cache_slots < 0:
            raise ValueError(f"cache_slots must be >= 0 (0 = policy "
                             f"default), got {self.cache_slots}")
        if self.ndev < 1:
            raise ValueError(f"ndev must be >= 1, got {self.ndev}")
        if self.grid is not None:
            object.__setattr__(self, "grid", tuple(self.grid))
            if (len(self.grid) != 2
                    or any(not isinstance(x, int) or x < 1
                           for x in self.grid)):
                raise ValueError(f"grid must be two positive ints (p, q), "
                                 f"got {self.grid!r}")
            if self.grid[0] * self.grid[1] != self.ndev:
                raise ValueError(
                    f"grid={self.grid} does not factor ndev={self.ndev} "
                    f"(need p*q == ndev)")
        if self.lookahead is not None:
            if (isinstance(self.lookahead, bool)
                    or not isinstance(self.lookahead, int)
                    or self.lookahead < 0):
                raise ValueError(f"lookahead must be an int >= 0 (or None "
                                 f"to leave it to the tuner), got "
                                 f"{self.lookahead!r}")
            if self.lookahead > 0 and self.ndev < 2:
                raise ValueError(
                    f"lookahead={self.lookahead} pipelines panels across "
                    f"devices and needs ndev > 1 (got ndev={self.ndev}); "
                    f"the single-device analogue is policy='async'/'v4'")
        if (len(self.block) != 2
                or any(not isinstance(x, int) or x < 1 for x in self.block)):
            raise ValueError(f"block must be two positive ints, "
                             f"got {self.block!r}")
        if self.policy not in ("v4", "auto") and self.block != _DEFAULT_BLOCK:
            raise ValueError(
                f"block={self.block} is only meaningful for policy='v4' "
                f"(got policy={self.policy!r})")
        if self.cache_slots > 0 and self.policy != "auto":
            # eager slot-minimum validation: an unbuildable budget used to
            # surface only as a cache-thrash RuntimeError deep inside
            # schedule construction
            floor = min_cache_slots(self.policy, self.block,
                                    self.lookahead or 0)
            if self.cache_slots < floor:
                raise ValueError(
                    f"policy {self.policy!r}"
                    + (f" with block={self.block}" if self.policy == "v4"
                       else "")
                    + (f" at lookahead={self.lookahead}"
                       if self.lookahead else "")
                    + f" needs >= {floor} cache slots"
                    + (" (h*w + w + 2)" if self.policy == "v4" else
                       " (each lookahead depth pins one extra slot)"
                       if self.lookahead else "")
                    + f", got {self.cache_slots}")
        if self.ndev > 1 and self.policy not in _MULTIDEV_POLICIES \
                and self.policy != "auto":
            raise ValueError(
                f"multi-device schedules support sync/v1/v2/v3, "
                f"got {self.policy!r}")
        if self.host_slots < 0:
            raise ValueError(f"host_slots must be >= 0 (0 = host-resident "
                             f"store, no spill tier), got {self.host_slots}")
        if self.host_slots > 0:
            if (self.lookahead or 0) > 0:
                raise ValueError(
                    "host_slots > 0 (disk spill tier) is incompatible with "
                    "lookahead > 0: the spill post-pass inserts ops into "
                    "each stream, which would invalidate the pipelined "
                    "emitter's dispatch-chunk indices")
            if self.ndev > 1 and self.backend == "jax":
                raise ValueError(
                    "host_slots > 0 with ndev > 1 runs on the NumPy replay "
                    "(the multi-device JAX executor keeps full row slabs "
                    "device-resident); use backend='auto' or 'numpy'")
        if self.hw is not None:
            from .analytics import HW
            if self.hw not in HW:
                raise ValueError(f"unknown hw preset {self.hw!r}; "
                                 f"expected one of {tuple(HW)}")
            mem = HW[self.hw].mem_bytes
            if mem > 0 and self.tb > 0 and self.cache_slots > 0:
                # 8-byte (f64 compute) device tiles; the OOC constraint
                # that used to fail only at executor build time
                need = self.cache_slots * self.tb * self.tb * 8
                if need > mem:
                    raise ValueError(
                        f"cache_slots={self.cache_slots} of "
                        f"{self.tb}x{self.tb} f64 tiles needs "
                        f"{need / 1e9:.1f} GB, but hw={self.hw!r} has "
                        f"mem_bytes={mem / 1e9:.1f} GB")
        if self.use_pallas and self.resolved_backend() != "jax":
            raise ValueError("use_pallas requires the 'jax' backend, "
                             f"got backend={self.backend!r} "
                             f"(resolved {self.resolved_backend()!r})")
        if self.fuse_columns and self.resolved_backend() != "jax":
            raise ValueError("fuse_columns (the fused column-step "
                             "megakernel) requires the 'jax' backend, "
                             f"got backend={self.backend!r} "
                             f"(resolved {self.resolved_backend()!r})")
        if self.compute_dtype is not None and self.resolved_backend() != "jax":
            raise ValueError("compute_dtype is only supported on the 'jax' "
                             f"backend, got backend={self.backend!r} "
                             f"(resolved {self.resolved_backend()!r})")

    @property
    def needs_tuning(self) -> bool:
        """True when an open dimension (``tb=0`` / ``policy="auto"``)
        must be resolved by :func:`repro.tune.resolve_config` before a
        schedule can be built."""
        return self.tb == 0 or self.policy == "auto"

    def resolved_backend(self) -> str:
        """Backend ``'auto'`` actually runs on.

        Single-device resolves to ``'jax'``.  Multi-device resolves to
        ``'jax'`` whenever the process sees at least ``ndev`` JAX devices
        (the per-device executor replays the streams on real devices) and
        falls back to the ``'numpy'`` host replay otherwise.  An explicit
        ``backend='jax'`` with too few devices raises at ``compile()``
        instead of silently degrading.
        """
        if self.backend != "auto":
            return self.backend
        if self.ndev > 1 and self.host_slots > 0:
            # the multi-device spill replay is numpy-only (the jax
            # executor keeps full row slabs device-resident)
            return "numpy"
        if self.ndev == 1:
            return "jax"
        try:
            import jax
            n_visible = len(jax.devices())
        except Exception:
            return "numpy"
        return "jax" if n_visible >= self.ndev else "numpy"

    def specialize(self, a: np.ndarray) -> "CholeskyConfig":
        """Freeze the matrix-dependent precision plan into the config.

        With ``eps_target`` set, the Higham-Mary plan is computed from
        ``a``'s tile norms and pinned as ``plan``; the result is fully
        static and can be planned/compiled for reuse.  A config that is
        already static (uniform f64 or explicit plan) is returned as-is.
        """
        if self.eps_target is None:
            return self
        if self.tb == 0:
            raise ValueError(
                "specialize() tiles the matrix with tb, which is still "
                "open (tb=0): resolve the config first — e.g. "
                "repro.tune.tune(n, config, sample=a, eps_target=...) "
                "searches tb and the precision plan together")
        from .cholesky import plan_for_matrix
        a = np.asarray(a, dtype=np.float64)
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"expected a square matrix, got {a.shape}")
        pplan = plan_for_matrix(to_tiles(a, self.tb), self.eps_target,
                                self.ladder)
        return dataclasses.replace(self, eps_target=None, plan=pplan)


class OOCSolver:
    """Reusable compiled executor for one ``(n, config)`` plan.

    Created via ``repro.plan(n, config).compile()``.  ``factor(a)``
    replays the cached schedule (the JAX executor lives on the shared
    plan and is jitted exactly once across every solver of that plan —
    see ``stats``); ``solve(b)``/``solve_lower(b)``/``logdet()`` run
    blocked substitution against the factored tile store (pass
    ``factor(a, materialize=False)`` to keep the factor tiled — the OOC
    mode); ``simulate(hw)`` / ``volume()`` expose the analytics of the
    underlying plan, and ``transfer_stats()`` the executed interconnect
    counters of a multi-device jax ``factor()``.  The full walkthrough
    lives in docs/architecture.md.

    Each ``compile()`` call returns a *fresh* solver: the expensive
    artifacts (schedule, jitted executor) are shared through the plan
    cache, but the factored tile store is per-solver, so independent
    call sites holding solvers for the same ``(n, config)`` cannot
    observe (or silently consume) each other's factors.
    """

    def __init__(self, plan: "CholeskyPlan", executor: "_CompiledExecutor",
                 default_trace=None):
        self._plan = plan
        self._executor = executor
        self._tiles = None          # this solver's factored tile store (f64)
        self._factor_calls = 0
        self._solve_calls = 0
        self._default_trace = default_trace   # from compile(trace=...)
        self._last_io = None        # executed FETCH/SPILL counters

    @property
    def stats(self) -> dict:
        """``jit_traces`` is plan-wide (the amortization contract);
        ``factor_calls``/``solve_calls`` count this solver's own use.

        ``transfers`` is the *unified* movement view across all three
        executor classes: the schedule's static LOAD/STORE volumes
        (which, by the static-schedule claim, are also the executed
        volumes), overlaid — when the last ``factor()`` ran an executor
        that counts at run time — with executed BCAST/RECV counters
        (multi-device jax) and executed FETCH/SPILL counters (spill
        executors and replays)."""
        sched = self._plan.schedule
        transfers = {
            "loads": sched.count(OpKind.LOAD),
            "stores": sched.count(OpKind.STORE),
            "h2d_bytes": sched.loads_bytes(),
            "d2h_bytes": sched.stores_bytes(),
        }
        if self._plan.config.ndev > 1:
            transfers["bcast_bytes"] = sched.bcast_bytes()
            executed = self.transfer_stats()
            if executed is not None:
                transfers.update(executed)
        if sched.host_slots:
            transfers["scheduled_fetch_bytes"] = sched.fetch_bytes()
            transfers["scheduled_spill_bytes"] = sched.spill_bytes()
            if self._last_io is not None:
                transfers.update(self._last_io)
        return {"jit_traces": self._executor.jit_traces,
                "factor_calls": self._factor_calls,
                "solve_calls": self._solve_calls,
                "transfers": transfers}

    # -- two-phase surface -------------------------------------------------
    @property
    def config(self) -> CholeskyConfig:
        return self._plan.config

    @property
    def n(self) -> int:
        return self._plan.n

    @property
    def schedule(self) -> MultiDeviceSchedule:
        return self._plan.schedule

    def simulate(self, hw, link_bw=None, record_timeline: bool = False):
        return self._plan.simulate(hw, link_bw=link_bw,
                                   record_timeline=record_timeline)

    def volume(self) -> dict:
        return self._plan.volume()

    # -- execution ---------------------------------------------------------
    def factor(self, a: np.ndarray, materialize: bool = True,
               trace=None) -> np.ndarray | None:
        """Factor SPD ``a`` through the cached schedule; returns tril L.

        ``materialize=False`` skips assembling the dense n x n factor and
        returns None — the factorization stays in the tile store, where
        ``solve()``/``solve_lower()``/``logdet()`` consume it.  That is
        the out-of-core mode: at OOC scale the dense L is exactly the
        object that does not fit.

        ``trace``: an *active* :class:`repro.obs.TraceRecorder` switches
        every backend to its measured path — eager op-by-op execution
        with a ``block_until_ready`` fence per op, recording exactly one
        span per schedule op (see docs/observability.md; analyze with
        :func:`repro.obs.drift_report`).  ``None`` (or the inactive
        :data:`repro.obs.NULL`) runs the ordinary jitted path unchanged —
        bit-identical results, no extra jit traces.  A default recorder
        can be pinned at :meth:`CholeskyPlan.compile`.

        A solver holds exactly **one** factor: each ``factor()`` call
        *overwrites* the previous tile store, so pending ``solve()``
        calls against the old matrix must complete first.  This
        single-factor statefulness is why :class:`repro.serve`'s service
        pools one solver per session instead of sharing one solver
        across tenants.
        """
        a = np.asarray(a, dtype=np.float64)
        if a.shape != (self.n, self.n):
            raise ValueError(
                f"matrix shape {a.shape} does not match the plan's "
                f"n={self.n}; build a new plan for a different size")
        tiles = to_tiles(a, self._plan.config.tb)
        cfg = self._plan.config
        if trace is None:
            trace = self._default_trace
        active = trace is not None and getattr(trace, "active", False)
        if active:
            trace.meta.update({
                "n": self.n, "tb": cfg.tb, "nt": self.schedule.nt,
                "ndev": cfg.ndev, "policy": self.schedule.policy,
                "lookahead": cfg.lookahead or 0,
                "host_slots": cfg.host_slots,
                "grid": list(self.schedule.grid),
                "backend": cfg.resolved_backend(),
            })
        if self._executor.multidevice is not None:
            # per-device jitted streams + device-to-device panel broadcast
            # (or, traced, the executor's fenced op-by-op measured path)
            out = self._executor.fn(tiles, trace=trace)
        elif cfg.ndev > 1:
            if cfg.host_slots > 0:
                from .cholesky import run_multidevice_spill
                from .spill import ArrayTileStore
                store = ArrayTileStore(tiles)
                hosts = run_multidevice_spill(store, self._plan.schedule,
                                              trace=trace)
                out = store.to_tiles()
                self._last_io = {
                    "fetch_ops": sum(h.fetch_ops for h in hosts),
                    "spill_ops": sum(h.spill_ops for h in hosts),
                    "fetched_bytes": sum(h.fetched_bytes for h in hosts),
                    "spilled_bytes": sum(h.spilled_bytes for h in hosts),
                }
            else:
                from .cholesky import run_multidevice_numpy
                out = run_multidevice_numpy(tiles, self._plan.schedule,
                                            trace=trace)
        elif cfg.resolved_backend() == "numpy":
            if cfg.host_slots > 0:
                from .cholesky import run_schedule_spill
                from .spill import ArrayTileStore
                store = ArrayTileStore(tiles)
                h = run_schedule_spill(store, self._plan.single_schedule(),
                                       trace=trace)
                out = store.to_tiles()
                self._last_io = {
                    "fetch_ops": h.fetch_ops, "spill_ops": h.spill_ops,
                    "fetched_bytes": h.fetched_bytes,
                    "spilled_bytes": h.spilled_bytes,
                }
            else:
                from .cholesky import run_schedule_numpy
                out = run_schedule_numpy(tiles, self._plan.single_schedule(),
                                         trace=trace)
        elif self._executor.spill is not None:
            # segmented spill executor: host tiles stay numpy (the
            # bounded slab buffer is the only jax-resident host state)
            out = np.asarray(self._executor.fn(tiles, trace=trace),
                             dtype=np.float64)
            self._last_io = self._executor.spill.last_io_stats
        elif active:
            # per-op spans are unobservable inside the single unrolled
            # jit: traced runs execute the same op semantics eagerly
            from .cholesky import run_traced_jax
            out = run_traced_jax(self._plan.single_schedule(), tiles, trace,
                                 compute_dtype=self._executor.dtype,
                                 use_pallas=cfg.use_pallas)
        else:
            import jax.numpy as jnp
            ex = self._executor
            out = np.asarray(ex.fn(jnp.asarray(tiles, dtype=ex.dtype)),
                             dtype=np.float64)
        self._tiles = out
        self._factor_calls += 1
        reg = _obs_registry()
        if reg is not None:
            sched = self._plan.schedule
            reg.inc("repro.factor.calls")
            reg.inc("repro.factor.h2d_bytes", sched.loads_bytes())
            reg.inc("repro.factor.d2h_bytes", sched.stores_bytes())
            if sched.host_slots:
                reg.inc("repro.factor.fetch_bytes", sched.fetch_bytes())
                reg.inc("repro.factor.spill_bytes", sched.spill_bytes())
            reg.set_gauge("repro.factor.jit_traces",
                          self._executor.jit_traces)
        if not materialize:
            return None
        return np.tril(from_tiles(out))

    def _factored_tiles(self) -> np.ndarray:
        if self._tiles is None:
            raise RuntimeError("no factor available: call factor(a) before "
                               "solve()/solve_lower()/logdet()")
        return self._tiles

    def _check_rhs(self, b) -> np.ndarray:
        """Eager rhs validation: reject shape/dtype mismatches with a
        plan-aware error instead of letting them fall through to the
        blocked-substitution internals."""
        b = np.asarray(b)
        if b.dtype.kind not in "fiub":
            raise TypeError(
                f"rhs dtype {b.dtype} is not real-valued; the tiled "
                f"substitution runs in float64")
        if b.ndim not in (1, 2):
            raise ValueError(
                f"rhs must be a vector (n,) or stacked columns (n, k), "
                f"got shape {b.shape}")
        if b.shape[0] != self.n:
            raise ValueError(
                f"rhs has {b.shape[0]} rows but this solver's plan is "
                f"n={self.n}; build a plan for the rhs size or reshape")
        if b.ndim == 2 and b.shape[1] == 0:
            raise ValueError("rhs has 0 columns; nothing to solve")
        return np.asarray(b, dtype=np.float64)

    def solve(self, b: np.ndarray) -> np.ndarray:
        """Solve ``A x = b`` with the last factored ``A = L L^T``.

        ``b`` may be one vector ``(n,)`` or ``k`` stacked columns
        ``(n, k)`` — the blocked substitution sweeps the tile store once
        for the whole stack, which is what the serve batcher exploits
        to coalesce concurrent single-RHS solves.  The result is
        against this solver's *current* factor (see :meth:`factor`)."""
        from .solve import cho_solve_tiles
        x = cho_solve_tiles(self._factored_tiles(), self._check_rhs(b))
        self._solve_calls += 1
        reg = _obs_registry()
        if reg is not None:
            reg.inc("repro.solve.calls")
        return x

    def solve_lower(self, b: np.ndarray) -> np.ndarray:
        """Forward substitution ``L z = b`` (e.g. Gaussian quad forms);
        like :meth:`solve`, accepts one vector or ``(n, k)`` stacked
        columns against the current factor."""
        from .solve import solve_lower_tiles
        z = solve_lower_tiles(self._factored_tiles(), self._check_rhs(b))
        self._solve_calls += 1
        reg = _obs_registry()
        if reg is not None:
            reg.inc("repro.solve.calls")
        return z

    def logdet(self) -> float:
        """``log|A|`` of the last factored matrix, from the tile store."""
        from .solve import logdet_tiles
        return logdet_tiles(self._factored_tiles())

    def transfer_stats(self) -> Optional[dict]:
        """Executed BCAST/RECV op and byte counters of the last
        ``factor()`` on the multi-device JAX backend (None elsewhere);
        cross-check against the static schedule and the event simulator
        with :func:`repro.core.analytics.crosscheck_executed_volume`."""
        mdx = self._executor.multidevice
        return None if mdx is None else mdx.last_transfer_stats


def _resolved_dtype(cfg: CholeskyConfig):
    """Compute dtype the jax executor would use *right now* (None for
    numpy backends).  Read per compile() so a cached plan does not pin a
    float32 executor across a later jax_enable_x64 flip — the pre-0.2
    one-shot API re-read the flag on every call."""
    if cfg.resolved_backend() != "jax":
        return None
    import jax
    import jax.numpy as jnp
    return cfg.compute_dtype or (jnp.float64 if jax.config.jax_enable_x64
                                 else jnp.float32)


class _CompiledExecutor:
    """The per-plan compiled artifact: built once per compute dtype,
    shared by every solver of the plan.  Holds no factored data — only
    the jitted function(s) (JAX backend) and the trace counter.

    For ``ndev > 1`` on the JAX backend this holds a
    :class:`~repro.core.cholesky.MultiDeviceJaxExecutor` — one jitted
    column-segment sequence per device stream, BCAST/RECV edges as
    device-to-device transfers; building it verifies that enough devices
    are visible (RuntimeError otherwise)."""

    def __init__(self, plan: "CholeskyPlan"):
        self._jit_traces = 0
        self.fn = None
        self.multidevice = None    # MultiDeviceJaxExecutor (jax, ndev > 1)
        self.spill = None          # SpillJaxExecutor (jax, host_slots > 0)
        cfg = plan.config
        self.dtype = _resolved_dtype(cfg)
        if cfg.resolved_backend() != "jax":
            return
        import jax
        if cfg.ndev > 1:
            from .cholesky import make_multidevice_jax_executor
            self.multidevice = make_multidevice_jax_executor(
                plan.schedule, self.dtype, use_pallas=cfg.use_pallas,
                fuse_columns=cfg.fuse_columns)
            self.fn = self.multidevice
            return
        if cfg.host_slots > 0:
            # segmented executor over the bounded slab buffer; jits one
            # program per device segment, disk I/O driven between them
            from .cholesky import SpillJaxExecutor
            self.spill = SpillJaxExecutor(plan.single_schedule(),
                                          self.dtype,
                                          use_pallas=cfg.use_pallas,
                                          fuse_columns=cfg.fuse_columns)
            self.fn = self.spill
            return
        from .cholesky import make_jax_executor
        raw = make_jax_executor(plan.single_schedule(), self.dtype,
                                use_pallas=cfg.use_pallas,
                                fuse_columns=cfg.fuse_columns)

        def traced(host_tiles):
            # body runs only while tracing: counts jit compilations
            self._jit_traces += 1
            return raw(host_tiles)

        self.fn = jax.jit(traced)

    @property
    def jit_traces(self) -> int:
        if self.multidevice is not None:
            return self.multidevice.jit_traces
        if self.spill is not None:
            return self.spill.jit_traces
        return self._jit_traces


@dataclasses.dataclass
class CholeskyPlan:
    """Cached static schedule for one ``(n, config)``; ``compile()`` hands
    out per-call-site solvers over one shared compiled executor."""

    n: int
    config: CholeskyConfig
    schedule: MultiDeviceSchedule
    _single: Any = None            # single-device Schedule (ndev=1 only)
    _executor: Optional[_CompiledExecutor] = None
    _compile_lock: Any = dataclasses.field(default_factory=threading.Lock,
                                           repr=False, compare=False)

    def single_schedule(self):
        """The flat single-device Schedule backing the ndev=1 degenerate."""
        if self._single is None:
            self._single = self.schedule.to_single()
        return self._single

    def compile(self, trace=None) -> OOCSolver:
        """Return a fresh solver over this plan's one compiled executor.

        The executor (jit) is built on first call and reused afterwards
        (rebuilt only if the jax x64 flag changed the compute dtype in
        the meantime); the solver itself is new each time so factored
        state stays with the call site that produced it (and is freed
        with it — the plan cache never pins a factored matrix).  The
        per-plan lock makes concurrent first compiles (serve workers
        racing for a shared plan) build exactly one executor.

        ``trace``: a :class:`repro.obs.TraceRecorder` pinned as the
        solver's default — every ``factor()`` without an explicit
        ``trace=`` records into it (a per-call ``trace=`` overrides)."""
        with self._compile_lock:
            if (self._executor is None
                    or self._executor.dtype != _resolved_dtype(self.config)):
                self._executor = _CompiledExecutor(self)
            return OOCSolver(self, self._executor, default_trace=trace)

    def simulate(self, hw, link_bw=None, record_timeline: bool = False):
        """Three-engine event model (per-device + shared link for ndev>1)."""
        from . import analytics
        if self.config.ndev > 1:
            return analytics.simulate_multi(self.schedule, hw,
                                            link_bw=link_bw,
                                            record_timeline=record_timeline)
        return analytics.simulate(self.single_schedule(), hw,
                                  record_timeline=record_timeline)

    def volume(self) -> dict:
        """Exact byte-volume report of the static schedule (Fig. 8/12)."""
        from . import analytics
        if self.config.ndev > 1:
            return analytics.volume_report_multi(self.schedule)
        return analytics.volume_report(self.single_schedule())


_PLAN_CACHE: "collections.OrderedDict[tuple, CholeskyPlan]" = \
    collections.OrderedDict()
_PLAN_CACHE_MAX = 32
# One lock for every cache mutation *and* the build of a missing plan:
# concurrent plan() calls from serve workers must neither corrupt the
# OrderedDict (move_to_end/popitem race) nor duplicate a build — with
# the lock held across the miss path, N threads planning the same
# (n, config) produce exactly one schedule and share one CholeskyPlan
# (and therefore one jitted executor).  Reentrant because the tuner
# resolution path may consult planning helpers.
_PLAN_CACHE_LOCK = threading.RLock()
_SCHEDULE_BUILDS = 0     # module-wide build counter (amortization tests)
_PLAN_CACHE_HITS = 0     # served from cache (serve metrics read these)
_PLAN_CACHE_MISSES = 0   # built fresh


def schedule_build_count() -> int:
    return _SCHEDULE_BUILDS


def plan_cache_stats() -> dict:
    """Hit/miss/occupancy counters of the process-wide plan cache.

    ``hits``/``misses`` are cumulative since import (a miss is a fresh
    schedule build); ``size``/``max`` describe current occupancy.  The
    serve metrics layer snapshots this around a traffic window to report
    the cache's contribution to request latency."""
    with _PLAN_CACHE_LOCK:
        return {"hits": _PLAN_CACHE_HITS, "misses": _PLAN_CACHE_MISSES,
                "size": len(_PLAN_CACHE), "max": _PLAN_CACHE_MAX}


def clear_plan_cache() -> None:
    with _PLAN_CACHE_LOCK:
        _PLAN_CACHE.clear()


def plan(n: int, config: CholeskyConfig | None = None,
         **overrides) -> CholeskyPlan:
    """Build (or fetch) the static plan for an ``n x n`` factorization.

    ``plan(n, config)`` or the kwargs shorthand ``plan(n, tb=..., ...)``.
    Plans are cached by ``(n, config)`` value: repeated calls with equal
    configs return the *same* plan object, whose ``compile()`` reuses one
    jitted executor — schedule construction and tracing are amortized
    across every factorization of that shape.

    Configs with open dimensions (``tb=0``, ``policy="auto"``, and —
    given ``ndev > 1`` — ``grid=None`` / ``cache_slots=0``) are resolved
    through the autotuner first (:func:`repro.tune.resolve_config`,
    docs/tuning.md); ``eps_target`` configs must be frozen with
    :meth:`CholeskyConfig.specialize` before planning, because the
    precision plan depends on the matrix values.  See
    docs/architecture.md for the full planner/executor walkthrough.
    """
    global _SCHEDULE_BUILDS, _PLAN_CACHE_HITS, _PLAN_CACHE_MISSES
    if config is None:
        config = CholeskyConfig(**overrides)
    elif overrides:
        config = dataclasses.replace(config, **overrides)
    if config.eps_target is not None:
        raise ValueError(
            "eps_target makes the precision plan matrix-dependent, so it "
            "cannot be planned ahead of the data: freeze it with "
            "config.specialize(a) (or pass plan=plan_for_matrix(...)), or "
            "use the one-shot ooc_cholesky()")
    # the lock spans lookup *and* build: concurrent misses on one key
    # collapse to a single schedule construction (see _PLAN_CACHE_LOCK)
    with _PLAN_CACHE_LOCK:
        auto_key = None
        if config.needs_tuning:
            # open dimensions (tb=0 / policy="auto"): resolve through the
            # autotuner — exact-simulation search against the config's hw
            # preset (or the process default model), memoized in the tuning
            # db.  The plan is cached under the auto key too, so repeat
            # plan() calls with the same auto config skip even the db hit;
            # the key carries the resolving model's identity, so installing
            # a different default hardware model re-resolves instead of
            # serving a plan tuned for the previous one.
            from repro.tune import resolve_config, resolution_token
            auto_key = (n, config, resolution_token(config))
            cached = _PLAN_CACHE.get(auto_key)
            if cached is not None:
                _PLAN_CACHE.move_to_end(auto_key)
                _PLAN_CACHE_HITS += 1
                return cached
            config = resolve_config(n, config)
        if config.grid == (config.ndev, 1):
            # an explicit 1D grid (e.g. a tuner winner) builds the identical
            # schedule as grid=None: canonicalize so both key one cached plan
            # and one jitted executor
            config = dataclasses.replace(config, grid=None)
        if config.lookahead == 0:
            # same canonicalization for an explicit zero lookahead: the
            # emitter's L=0 streams are bit-identical to the default
            config = dataclasses.replace(config, lookahead=None)
        layout = TileLayout(n, config.tb)   # validates n % tb == 0
        key = (n, config)
        cached = _PLAN_CACHE.get(key)
        if cached is not None:
            _PLAN_CACHE.move_to_end(key)
            _PLAN_CACHE_HITS += 1
            if auto_key is not None:
                _PLAN_CACHE[auto_key] = cached
            return cached
        _SCHEDULE_BUILDS += 1
        _PLAN_CACHE_MISSES += 1
        # resolve the default plan here (not in the builders) so the
        # schedule's metadata carries the config's ladder, not a hardcoded
        # one
        pplan = config.plan or uniform_plan(layout.nt, "f64", config.ladder)
        if config.ndev > 1:
            msched = build_multidevice_schedule(
                layout.nt, config.tb, config.ndev, config.policy,
                config.cache_slots, pplan, grid=config.grid,
                lookahead=config.lookahead or 0,
                host_slots=config.host_slots)
            single = None
        else:
            single = build_schedule(layout.nt, config.tb, config.policy,
                                    config.cache_slots, pplan,
                                    block=config.block,
                                    host_slots=config.host_slots)
            msched = MultiDeviceSchedule.from_single(single)
        p = CholeskyPlan(n=n, config=config, schedule=msched, _single=single)
        _PLAN_CACHE[key] = p
        if auto_key is not None:
            _PLAN_CACHE[auto_key] = p
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
            _PLAN_CACHE.popitem(last=False)
        return p
