"""Explicit tile-task DAG + topological lookahead emitter.

Schedule construction used to be a single per-column emission loop
(``build_multidevice_schedule``).  This module splits it into the paper's
two conceptual stages:

1. :func:`build_task_dag` — the *task graph*: one node per compute task
   (POTRF / TRSM / SYRK / GEMM) with its true value dependencies
   (operand finalization) and accumulation-chain edges.  The graph is
   pure math — no devices, no slots, no transfers.
2. :func:`emit_pipelined_streams` — the *topological emitter*: walks the
   DAG in a lookahead-pipelined order and emits one op stream per device
   (LOAD / STORE / BCAST / RECV data movement realized against the
   per-device cache tables of Algorithm 3).  Every compute op is checked
   against the DAG as it is emitted: emitting a task whose predecessors
   have not been emitted raises, so a reordering bug in the emitter
   cannot silently produce a wrong-answer schedule.

Lookahead (Donfack et al., arXiv:1110.2677): with ``lookahead = L > 0``
the emitter interleaves up to ``L`` panels ahead of the trailing update.
At dispatch step ``s`` it emits

* the **final chunk** of column ``s`` — the last ``L`` update sweeps
  (``n in [s-L, s)``), the TRSM/POTRF finalizations, the panel/ownership
  broadcasts — *and*, for every finalized tile ``(m, s)`` with
  ``m - s <= L``, an **eager panel push** to column ``m``'s grid-row
  peers, so panel ``m`` never waits for its owner's POTRF step;
* the **advance chunk** of column ``s + L`` — all early updates
  (``n in [0, s)``) on that column's grid-column devices, with the
  partially-updated accumulators stored back to the host (the V4
  partial-store trick keeps the slot minimum independent of ``nt``),
  preceded by a **bulk panel push** of the already-final tiles
  ``(s+L, n < s)``.

``lookahead = 0`` reproduces the historical per-column emission loop
bit-identically (golden digests unchanged): the final chunk covers the
whole update sweep, no advance chunks exist, and the panel row is pushed
wholesale after POTRF.

In-flight panels land in *rotating panel-slot regions*: tile ``(k, n)``
received for column ``k`` occupies slot
``panel_base + (k % (L+1)) * nt + n``, so ``L+1`` panel rows can be
resident at once — which is exactly why each lookahead depth pins ``nt``
extra slots (see ``TileLayout.panel_slots`` and
``min_cache_slots(..., lookahead=...)``).

:func:`verify_dispatch` is the independent referee: it replays a built
schedule's dispatch order *symbolically* (slot contents, per-device host
slabs, broadcast wires, per-tile update counts) and asserts that no op
consumes a tile before its DAG predecessors completed and that every
task of the graph runs exactly once.
"""
from __future__ import annotations

import dataclasses

from .precision import BYTES, PrecisionPlan
from .tiling import grid_owner

POTRF, TRSM, SYRK, GEMM = "potrf", "trsm", "syrk", "gemm"


@dataclasses.dataclass(frozen=True)
class Task:
    """One compute node of the tile-Cholesky DAG.

    ``(i, j)`` is the output tile; ``n`` is the update column for
    SYRK/GEMM accumulations (``-1`` for POTRF/TRSM finalizations).
    """
    kind: str
    i: int
    j: int
    n: int = -1


def potrf(k: int) -> Task:
    return Task(POTRF, k, k)


def trsm(m: int, k: int) -> Task:
    return Task(TRSM, m, k)


def syrk(k: int, n: int) -> Task:
    return Task(SYRK, k, k, n)


def gemm(m: int, k: int, n: int) -> Task:
    return Task(GEMM, m, k, n)


class TaskDag:
    """Predecessor map over the ``O(nt^3)`` compute tasks, plus the
    completion state the emitter advances through.

    ``complete(task)`` is the topological-order contract: it raises if a
    predecessor has not completed or if the task runs twice."""

    def __init__(self, preds: dict[Task, tuple[Task, ...]]):
        self.preds = preds
        self.done: set[Task] = set()

    def __len__(self) -> int:
        return len(self.preds)

    def complete(self, task: Task) -> None:
        if task not in self.preds:
            raise AssertionError(f"unknown task {task}")
        if task in self.done:
            raise AssertionError(f"task emitted twice: {task}")
        for t in self.preds[task]:
            if t not in self.done:
                raise AssertionError(
                    f"emitter ordering bug: {task} before predecessor {t}")
        self.done.add(task)

    def all_done(self) -> bool:
        return len(self.done) == len(self.preds)


def build_task_dag(nt: int) -> TaskDag:
    """Value + accumulation dependencies of the left-looking factorization.

    * ``SYRK(k, n)``  needs ``TRSM(k, n)`` (operand final) and
      ``SYRK(k, n-1)`` (in-order accumulation into ``(k, k)``);
    * ``POTRF(k)``    needs ``SYRK(k, k-1)`` (all diagonal updates);
    * ``GEMM(m,k,n)`` needs ``TRSM(m, n)`` + ``TRSM(k, n)`` (operands)
      and ``GEMM(m, k, n-1)`` (accumulation into ``(m, k)``);
    * ``TRSM(m, k)``  needs ``POTRF(k)`` and ``GEMM(m, k, k-1)``.
    """
    preds: dict[Task, tuple[Task, ...]] = {}
    for k in range(nt):
        for n in range(k):
            dep = [trsm(k, n)]
            if n > 0:
                dep.append(syrk(k, n - 1))
            preds[syrk(k, n)] = tuple(dep)
        preds[potrf(k)] = (syrk(k, k - 1),) if k > 0 else ()
        for m in range(k + 1, nt):
            for n in range(k):
                dep = [trsm(m, n), trsm(k, n)]
                if n > 0:
                    dep.append(gemm(m, k, n - 1))
                preds[gemm(m, k, n)] = tuple(dep)
            dep = [potrf(k)]
            if k > 0:
                dep.append(gemm(m, k, k - 1))
            preds[trsm(m, k)] = tuple(dep)
    return TaskDag(preds)


def emit_pipelined_streams(nt: int, tb: int, ndev: int, policy: str,
                           cache_slots: int, plan: PrecisionPlan,
                           grid: tuple, lookahead: int):
    """Walk the task DAG and emit per-device op streams + dispatch chunks.

    Returns ``(streams, dispatch, caches)`` where ``dispatch`` is the
    list of ``(dev, start, stop, k, phase)`` chunk tuples in dispatch
    order (``None`` for ``lookahead = 0``, where the historical
    column-major order is derivable from the streams) and ``caches`` is
    the per-device cache-table list (``None`` for policies without an
    operand cache).  Called through
    :func:`repro.core.schedule.build_multidevice_schedule`; see that
    docstring for the schedule semantics.
    """
    from .schedule import Op, OpKind, _CacheTable

    p, q = grid
    L = lookahead
    operand_cache = policy in ("v2", "v3")
    reuse_accum = policy in ("v1", "v2", "v3")
    pin_diag = policy == "v3"
    panel_base = cache_slots

    dag = build_task_dag(nt)
    streams: list[list[Op]] = [[] for _ in range(ndev)]
    emits = [s.append for s in streams]
    caches = ([_CacheTable(cache_slots, emits[d], plan, tb)
               for d in range(ndev)] if operand_cache else None)
    dispatch: list[tuple] = []
    committed = [0] * ndev              # stream prefix already chunked
    pending: list[list[Op]] = [[] for _ in range(ndev)]  # queued RECVs

    def close_chunk(d, k, phase):
        end = len(streams[d])
        if end > committed[d]:
            dispatch.append((d, committed[d], end, k, phase))
            committed[d] = end

    def flush_pending(d):
        for op in pending[d]:
            emits[d](op)
        pending[d].clear()

    def pslot(kc, n):
        """Rotating panel region: column kc's RECVed tile (kc, n)."""
        return panel_base + (kc % (L + 1)) * nt + n

    def tbytes(i, j):
        cls = int(plan.classes[i, j])
        return cls, BYTES[plan.ladder[cls]] * tb * tb

    def ccls(*tiles):
        return max(int(plan.classes[i, j]) for i, j in tiles)

    def store(d, i, j, s, k):
        cls, nb = tbytes(i, j)
        emits[d](Op(OpKind.STORE, i=i, j=j, slot_c=s, cls=cls, bytes=nb, k=k))

    def naive_load(d, i, j, k, slot):
        cls, nb = tbytes(i, j)
        emits[d](Op(OpKind.LOAD, i=i, j=j, slot_c=slot, cls=cls, bytes=nb,
                    k=k))
        return slot

    def push_panel(kc, n, sender):
        """Ship finalized panel tile (kc, n) of column kc to the other
        devices of grid column ``kc % q`` (BCAST on the sender stream;
        RECVs queued so they land at the head of the receiver's next
        dispatch chunk, never inside one already being emitted)."""
        receivers = [grid_owner(r, kc, p, q) for r in range(p)
                     if r != kc % p]
        if not receivers:
            return
        cls, nb = tbytes(kc, n)
        emits[sender](Op(OpKind.BCAST, i=kc, j=n, cls=cls,
                         bytes=nb * len(receivers), k=kc, src=sender))
        for d in receivers:
            pending[d].append(Op(OpKind.RECV, i=kc, j=n, slot_c=pslot(kc, n),
                                 cls=cls, bytes=nb, k=kc, src=sender))

    def push_row_peers(k, m, d):
        """Row-scoped ownership broadcast (q > 1 only): host-slab
        coherence for the grid-row peers that later load (m, k)."""
        receivers = [grid_owner(m, c, p, q) for c in range(q) if c != k % q]
        if not receivers:
            return
        cls, nb = tbytes(m, k)
        emits[d](Op(OpKind.BCAST, i=m, j=k, cls=cls,
                    bytes=nb * len(receivers), k=k, src=d))
        for r in receivers:
            emits[r](Op(OpKind.RECV, i=m, j=k, slot_c=-1,
                        cls=cls, bytes=nb, k=k, src=d))

    def update_rows(d, kc, n_lo, n_hi, finalize):
        """Update sweep ``n in [n_lo, n_hi)`` over device d's rows of
        column kc; ``finalize`` adds TRSM + broadcasts + eager pushes
        (the final chunk), otherwise the partial accumulator is stored
        back so an advance chunk's work survives any later eviction."""
        for m in range(kc + 1, nt):
            if grid_owner(m, kc, p, q) != d:
                continue
            local = m % p == kc % p   # row-kc operands on-device vs panel
            if operand_cache:
                cache = caches[d]
                c = cache.load(m, kc, kc, pin=True)
                for n in range(n_lo, n_hi):
                    a = cache.load(m, n, kc, pin=True)
                    b = (cache.load(kc, n, kc, pin=True) if local
                         else pslot(kc, n))
                    emits[d](Op(OpKind.GEMM, slot_c=c, slot_a=a, slot_b=b,
                                k=kc, cls=ccls((m, n), (kc, n))))
                    dag.complete(gemm(m, kc, n))
                    cache.unpin(a)
                    if local:
                        cache.unpin(b)
                if finalize:
                    dslot = (cache.load(kc, kc, kc, pin=True) if local
                             else pslot(kc, kc))
                    emits[d](Op(OpKind.TRSM, slot_c=c, slot_a=dslot, k=kc,
                                cls=ccls((kc, kc), (m, kc))))
                    dag.complete(trsm(m, kc))
                    if local and not pin_diag:
                        cache.unpin(dslot)
                store(d, m, kc, c, kc)
                if finalize:
                    cache.adopt(m, kc, c)
                cache.unpin(c)
            elif reuse_accum:  # v1
                c = naive_load(d, m, kc, kc, 0)
                for n in range(n_lo, n_hi):
                    a = naive_load(d, m, n, kc, 1)
                    b = (naive_load(d, kc, n, kc, 2) if local
                         else pslot(kc, n))
                    emits[d](Op(OpKind.GEMM, slot_c=c, slot_a=a, slot_b=b,
                                k=kc, cls=ccls((m, n), (kc, n))))
                    dag.complete(gemm(m, kc, n))
                if finalize:
                    dslot = (naive_load(d, kc, kc, kc, 3) if local
                             else pslot(kc, kc))
                    emits[d](Op(OpKind.TRSM, slot_c=c, slot_a=dslot, k=kc,
                                cls=ccls((kc, kc), (m, kc))))
                    dag.complete(trsm(m, kc))
                store(d, m, kc, c, kc)
            else:  # sync
                for n in range(n_lo, n_hi):
                    c = naive_load(d, m, kc, kc, 0)
                    a = naive_load(d, m, n, kc, 1)
                    b = (naive_load(d, kc, n, kc, 2) if local
                         else pslot(kc, n))
                    emits[d](Op(OpKind.GEMM, slot_c=c, slot_a=a, slot_b=b,
                                k=kc, cls=ccls((m, n), (kc, n))))
                    dag.complete(gemm(m, kc, n))
                    store(d, m, kc, c, kc)
                if finalize:
                    c = naive_load(d, m, kc, kc, 0)
                    dslot = (naive_load(d, kc, kc, kc, 1) if local
                             else pslot(kc, kc))
                    emits[d](Op(OpKind.TRSM, slot_c=c, slot_a=dslot, k=kc,
                                cls=ccls((kc, kc), (m, kc))))
                    dag.complete(trsm(m, kc))
                    store(d, m, kc, c, kc)
            if finalize:
                push_row_peers(kc, m, d)
                if 0 < m - kc <= L:
                    # eager panel push: (m, kc) is a panel tile of a
                    # column inside the lookahead window — ship it now
                    # instead of at column m's POTRF step
                    push_panel(m, kc, d)

    def update_diag(d, kc, n_lo, n_hi, finalize):
        """Diagonal update sweep ``n in [n_lo, n_hi)`` on the owner;
        ``finalize`` adds the POTRF (the final chunk)."""
        if not finalize and n_hi <= n_lo:
            return -1
        if operand_cache:
            cache = caches[d]
            c = cache.load(kc, kc, kc, pin=True)
            for n in range(n_lo, n_hi):
                a = cache.load(kc, n, kc, pin=True)
                emits[d](Op(OpKind.SYRK, slot_c=c, slot_a=a, k=kc,
                            cls=ccls((kc, n))))
                dag.complete(syrk(kc, n))
                cache.unpin(a)
            if finalize:
                emits[d](Op(OpKind.POTRF, slot_c=c, k=kc,
                            cls=ccls((kc, kc))))
                dag.complete(potrf(kc))
            store(d, kc, kc, c, kc)
            cache.unpin(c)
            if finalize:
                cache.adopt(kc, kc, c, pin=pin_diag)
            return c
        if reuse_accum:  # v1
            c = naive_load(d, kc, kc, kc, 0)
            for n in range(n_lo, n_hi):
                a = naive_load(d, kc, n, kc, 1)
                emits[d](Op(OpKind.SYRK, slot_c=c, slot_a=a, k=kc,
                            cls=ccls((kc, n))))
                dag.complete(syrk(kc, n))
            if finalize:
                emits[d](Op(OpKind.POTRF, slot_c=c, k=kc,
                            cls=ccls((kc, kc))))
                dag.complete(potrf(kc))
            store(d, kc, kc, c, kc)
            return c
        # sync
        for n in range(n_lo, n_hi):
            c = naive_load(d, kc, kc, kc, 0)
            a = naive_load(d, kc, n, kc, 1)
            emits[d](Op(OpKind.SYRK, slot_c=c, slot_a=a, k=kc,
                        cls=ccls((kc, n))))
            dag.complete(syrk(kc, n))
            store(d, kc, kc, c, kc)
        if finalize:
            c = naive_load(d, kc, kc, kc, 0)
            emits[d](Op(OpKind.POTRF, slot_c=c, k=kc, cls=ccls((kc, kc))))
            dag.complete(potrf(kc))
            store(d, kc, kc, c, kc)
            return c
        return -1

    for s in range(nt):
        ow = grid_owner(s, s, p, q)
        # final-chunk update range: everything the advance chunk (emitted
        # L steps ago, covering n < s-L) did not already apply
        lo = max(0, s - L) if L > 0 else 0

        # ---- final chunk, owner head: last updates + POTRF + panel push
        diag_slot = update_diag(ow, s, lo, s, finalize=True)
        if L == 0:
            for n in range(s + 1):
                push_panel(s, n, ow)
        else:
            # tiles (s, n < s) were bulk/eager-pushed in earlier steps;
            # only the fresh diagonal factor remains
            push_panel(s, s, ow)
        close_chunk(ow, s, "panel")

        # ---- final chunk, grid-column workers: rows of column s ----
        workers = [grid_owner(r, s, p, q) for r in range(p)
                   if grid_owner(r, s, p, q) != ow]
        for d in [ow] + workers:
            flush_pending(d)   # panel RECVs queued for this column
            update_rows(d, s, lo, s, finalize=True)
            if d == ow and operand_cache and pin_diag:
                caches[ow].unpin(diag_slot)
            close_chunk(d, s, "update")

        # ---- row-scoped host-landing receives (q > 1 only) ----
        for d in range(ndev):
            if d != ow and d % q != s % q:
                close_chunk(d, s, "recv")

        # ---- eager panel receives queued by this column's finalizers ----
        for d in range(ndev):
            if pending[d]:
                flush_pending(d)
                close_chunk(d, s, "recv-ahead")

        # ---- advance chunk: open column s+L's window ----
        kf = s + L
        if L > 0 and kf < nt and s > 0:
            owf = grid_owner(kf, kf, p, q)
            for n in range(s):
                push_panel(kf, n, owf)   # bulk push of already-final tiles
            close_chunk(owf, kf, "push")
            peers = [grid_owner(r, kf, p, q) for r in range(p)
                     if grid_owner(r, kf, p, q) != owf]
            for d in [owf] + peers:
                flush_pending(d)
                if d == owf:
                    update_diag(owf, kf, 0, s, finalize=False)
                update_rows(d, kf, 0, s, finalize=False)
                close_chunk(d, kf, "advance")

    assert dag.all_done(), \
        f"emitter dropped {len(dag.preds) - len(dag.done)} tasks"
    assert all(not pend for pend in pending)
    assert all(committed[d] == len(streams[d]) for d in range(ndev))
    return streams, (dispatch if L > 0 else None), caches


def verify_dispatch(msched) -> int:
    """Symbolically replay a schedule's dispatch order and assert DAG
    safety: no compute op consumes a tile before its predecessors
    completed, broadcasts only ship finalized tiles, accumulations apply
    in order, and every task of the graph runs exactly once.

    Tracks per-device slot contents, per-device host slabs (the 2D-grid
    coherence surface), and broadcast wires — an independent referee for
    the emitter *and* for the dispatch order executors replay (the same
    ``iter_dispatch_order`` both the NumPy replay and the JAX executor
    follow).  Returns the number of verified compute tasks.
    """
    from .schedule import OpKind

    nt = msched.nt
    p, q = msched.grid
    dag = build_task_dag(nt)
    FINAL = "final"
    # version of a tile = number of update sweeps applied, or FINAL
    host: list[dict] = [dict() for _ in range(msched.ndev)]
    for d in range(msched.ndev):
        for i in range(nt):
            if i % p == d // q:
                for j in range(i + 1):
                    host[d][(i, j)] = 0
    slots: list[dict] = [dict() for _ in range(msched.ndev)]
    wires: dict = {}

    for d, op in msched.iter_dispatch_order():
        kind = op.kind
        if kind is OpKind.LOAD:
            slots[d][op.slot_c] = ((op.i, op.j), host[d][(op.i, op.j)])
        elif kind is OpKind.STORE:
            tile, v = slots[d][op.slot_c]
            assert tile == (op.i, op.j), (op, tile)
            host[d][tile] = v
        elif kind is OpKind.BCAST:
            wires[(op.i, op.j, op.k, op.src)] = host[op.src][(op.i, op.j)]
        elif kind is OpKind.RECV:
            v = wires[(op.i, op.j, op.k, op.src)]
            assert v == FINAL, f"broadcast of unfinalized tile: {op} ({v})"
            if op.slot_c < 0:
                host[d][(op.i, op.j)] = v
            else:
                slots[d][op.slot_c] = ((op.i, op.j), v)
        elif kind is OpKind.SYRK:
            (ci, cj), v = slots[d][op.slot_c]
            (ai, aj), av = slots[d][op.slot_a]
            assert ci == cj and ai == ci, (op, (ci, cj), (ai, aj))
            assert av == FINAL, f"SYRK reads unfinalized operand: {op}"
            assert v == aj, f"out-of-order accumulation: {op} v={v} n={aj}"
            dag.complete(syrk(ci, aj))
            slots[d][op.slot_c] = ((ci, cj), v + 1)
        elif kind is OpKind.GEMM:
            (ci, cj), v = slots[d][op.slot_c]
            (ai, aj), av = slots[d][op.slot_a]
            (bi, bj), bv = slots[d][op.slot_b]
            assert ai == ci and bi == cj and aj == bj, (op,)
            assert av == FINAL and bv == FINAL, \
                f"GEMM reads unfinalized operand: {op}"
            assert v == aj, f"out-of-order accumulation: {op} v={v} n={aj}"
            dag.complete(gemm(ci, cj, aj))
            slots[d][op.slot_c] = ((ci, cj), v + 1)
        elif kind is OpKind.POTRF:
            (ci, cj), v = slots[d][op.slot_c]
            assert ci == cj and v == ci, f"POTRF before all updates: {op}"
            dag.complete(potrf(ci))
            slots[d][op.slot_c] = ((ci, cj), FINAL)
        elif kind is OpKind.TRSM:
            (ci, cj), v = slots[d][op.slot_c]
            (ai, aj), av = slots[d][op.slot_a]
            assert (ai, aj) == (cj, cj), (op,)
            assert av == FINAL, f"TRSM reads unfinalized diagonal: {op}"
            assert v == cj, f"TRSM before all updates: {op} v={v}"
            dag.complete(trsm(ci, cj))
            slots[d][op.slot_c] = ((ci, cj), FINAL)
        # ALLOC/FREE (async single-device streams) carry no value state
    assert dag.all_done(), \
        f"{len(dag.preds) - len(dag.done)} tasks never executed"
    return len(dag.done)
