"""Exact data-movement analytics + deterministic performance model.

Because the schedule is static, the byte volume of every policy (Fig. 8,
Fig. 12) is an *exact replay*, not an estimate.  The performance model is a
three-engine event simulator (H2D copy engine, D2H copy engine, compute
engine) over the op stream — the same structure as the paper's stream
timeline (Fig. 2/7): ``sync`` serializes everything on one engine, the
``async``/V* policies let the engines run concurrently subject to the data
dependencies encoded in the slot indices.

Hardware presets carry published peak numbers; they parameterize the model
only — nothing here measures real hardware (this repo targets TPU; CPU CI).
"""
from __future__ import annotations

import dataclasses

from .schedule import OpKind, Schedule

GB = 1e9
TFLOP = 1e12


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    # peak GEMM-engine throughput per precision class name, FLOP/s
    flops: dict
    h2d_bw: float          # host->device bytes/s (per direction)
    d2h_bw: float
    alloc_overhead: float  # seconds per malloc/free pair (async policy)
    launch_overhead: float = 3e-6


HW = {
    # PCIe Gen4 x16 ~ 25 GB/s effective; A100 fp64 tensor 19.5 TF.
    "a100-pcie": HardwareModel(
        "a100-pcie",
        {"f64": 19.5 * TFLOP, "f32": 19.5 * TFLOP, "f16": 312 * TFLOP,
         "bf16": 312 * TFLOP, "f8e4m3": 312 * TFLOP},
        25 * GB, 25 * GB, 12e-6),
    # PCIe Gen5 x16 ~ 50 GB/s effective; H100 fp64 tensor ~60 TF (free clocks).
    "h100-pcie": HardwareModel(
        "h100-pcie",
        {"f64": 60 * TFLOP, "f32": 60 * TFLOP, "f16": 750 * TFLOP,
         "bf16": 750 * TFLOP, "f8e4m3": 1500 * TFLOP},
        50 * GB, 50 * GB, 12e-6),
    # NVLink-C2C: 900 GB/s bidirectional -> 450 GB/s per direction.
    "gh200": HardwareModel(
        "gh200",
        {"f64": 62 * TFLOP, "f32": 62 * TFLOP, "f16": 990 * TFLOP,
         "bf16": 990 * TFLOP, "f8e4m3": 1980 * TFLOP},
        450 * GB, 450 * GB, 12e-6),
    # TPU v5e: bf16 MXU 197 TF, fp8 394 TF; f32 via 3-pass ~ 1/4 rate;
    # f64 emulated ~ 1/32 bf16.  Host DMA over PCIe ~ 32 GB/s.
    "tpu-v5e": HardwareModel(
        "tpu-v5e",
        {"f64": 6.2 * TFLOP, "f32": 49 * TFLOP, "f16": 197 * TFLOP,
         "bf16": 197 * TFLOP, "f8e4m3": 394 * TFLOP},
        32 * GB, 32 * GB, 0.0),
}

_TASK_FLOPS = {
    OpKind.SYRK: lambda tb: tb**3,          # C -= A A^T (symmetric half)
    OpKind.GEMM: lambda tb: 2 * tb**3,
    OpKind.POTRF: lambda tb: tb**3 / 3.0,
    OpKind.TRSM: lambda tb: tb**3,
}


@dataclasses.dataclass
class SimResult:
    makespan: float
    compute_busy: float
    h2d_busy: float
    d2h_busy: float
    h2d_bytes: int
    d2h_bytes: int
    alloc_events: int
    timeline: list           # (engine, start, end, label)
    flops_useful: float      # n^3/3

    @property
    def tflops(self) -> float:
        return self.flops_useful / self.makespan / TFLOP

    @property
    def total_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes


def simulate(sched: Schedule, hw: HardwareModel, record_timeline: bool = False) -> SimResult:
    """Event-driven simulation of the op stream on a three-engine machine."""
    tb = sched.tb
    lad = sched.plan.ladder
    overlap = sched.policy != "sync"

    nslots = max(max(o.slot_c, o.slot_a, o.slot_b) for o in sched.ops) + 1
    ready = [0.0] * nslots        # time the slot's contents become valid
    t_h2d = t_d2h = t_cmp = 0.0   # engine-free times
    busy = {"h2d": 0.0, "d2h": 0.0, "cmp": 0.0}
    nbytes = {"h2d": 0, "d2h": 0}
    allocs = 0
    timeline = []

    def run_on(engine_free, dep, dur, engine, label):
        start = max(engine_free, dep)
        end = start + dur
        busy[engine] += dur
        if record_timeline:
            timeline.append((engine, start, end, label))
        return end

    for op in sched.ops:
        if op.kind is OpKind.ALLOC:
            allocs += 1
            t_cmp += hw.alloc_overhead  # cudaMalloc stalls the stream
        elif op.kind is OpKind.FREE:
            t_cmp += hw.alloc_overhead * 0.3
        elif op.kind is OpKind.LOAD:
            dur = op.bytes / hw.h2d_bw
            nbytes["h2d"] += op.bytes
            if overlap:
                t_h2d = run_on(t_h2d, 0.0, dur, "h2d", f"L{op.i},{op.j}")
                ready[op.slot_c] = t_h2d
            else:
                t_cmp = run_on(t_cmp, 0.0, dur, "h2d", f"L{op.i},{op.j}")
                t_h2d = t_cmp
                ready[op.slot_c] = t_cmp
        elif op.kind is OpKind.STORE:
            dur = op.bytes / hw.d2h_bw
            nbytes["d2h"] += op.bytes
            if overlap:
                t_d2h = run_on(t_d2h, ready[op.slot_c], dur, "d2h", f"S{op.i},{op.j}")
            else:
                t_cmp = run_on(t_cmp, ready[op.slot_c], dur, "d2h", f"S{op.i},{op.j}")
                t_d2h = t_cmp
        else:  # compute
            flops = _TASK_FLOPS[op.kind](tb)
            rate = hw.flops[lad[op.cls]]
            dur = flops / rate + hw.launch_overhead
            deps = [ready[s] for s in (op.slot_c, op.slot_a, op.slot_b) if s >= 0]
            t_cmp = run_on(t_cmp, max(deps), dur, "cmp", op.kind.value)
            ready[op.slot_c] = t_cmp

    makespan = max(t_h2d, t_d2h, t_cmp)
    return SimResult(
        makespan=makespan,
        compute_busy=busy["cmp"], h2d_busy=busy["h2d"], d2h_busy=busy["d2h"],
        h2d_bytes=nbytes["h2d"], d2h_bytes=nbytes["d2h"],
        alloc_events=allocs, timeline=timeline,
        flops_useful=sched.flops(),
    )


def volume_report(sched: Schedule) -> dict:
    """Exact C2G/G2C byte volumes (paper Fig. 8 / Fig. 12)."""
    return {
        "policy": sched.policy,
        "nt": sched.nt,
        "tb": sched.tb,
        "c2g_bytes": sched.loads_bytes(),
        "g2c_bytes": sched.stores_bytes(),
        "total_bytes": sched.loads_bytes() + sched.stores_bytes(),
        "loads": sched.count(OpKind.LOAD),
        "stores": sched.count(OpKind.STORE),
        "cache_hits": sched.hits,
        "evictions": sched.evictions,
        "allocs": sched.count(OpKind.ALLOC),
        "matrix_bytes": 8 * (sched.nt * sched.tb) ** 2,
    }


def ascii_trace(result: SimResult, width: int = 100) -> str:
    """Fig. 7-style trace: one row per engine."""
    if not result.timeline:
        return "(timeline not recorded)"
    span = result.makespan
    rows = {"h2d": [" "] * width, "cmp": [" "] * width, "d2h": [" "] * width}
    glyph = {"h2d": "o", "cmp": "#", "d2h": "g"}
    for engine, s, e, _ in result.timeline:
        a = int(s / span * (width - 1))
        b = max(a + 1, int(e / span * (width - 1)))
        for x in range(a, min(b, width)):
            rows[engine][x] = glyph[engine]
    return "\n".join(f"{name:>4s} |{''.join(row)}|"
                     for name, row in [("G2C", rows["h2d"]),
                                       ("Work", rows["cmp"]),
                                       ("C2G", rows["d2h"])])
