"""Exact data-movement analytics + deterministic performance model.

Because the schedule is static, the byte volume of every policy (Fig. 8,
Fig. 12) is an *exact replay*, not an estimate.  The performance model is a
three-engine event simulator (H2D copy engine, D2H copy engine, compute
engine) over the op stream — the same structure as the paper's stream
timeline (Fig. 2/7): ``sync`` serializes everything on one engine, the
``async``/V* policies let the engines run concurrently subject to the data
dependencies encoded in the slot indices.

:func:`simulate_multi` extends the same model to the multi-device op
streams of :func:`~repro.core.schedule.build_multidevice_schedule`: every
device gets its own H2D/D2H/compute engine triple, and the broadcasts
(the column-scoped panel BCAST/RECV pairs plus, for 2D device grids, the
row-scoped ownership broadcasts) ride one *shared* interconnect engine.
Its bandwidth defaults to the model's measured ``link_bw`` when one is
recorded (calibrated models), else the preset's host-link speed — this
is what separates the PCIe-switch platforms from NVLink-C2C in Fig. 9.

Hardware presets carry published peak numbers (``source="datasheet"``);
:func:`repro.tune.calibrate` produces *measured* models from live-backend
micro-benchmarks (``source="measured"``, per-kernel rates, device-memory
capacity, hardware fingerprint) that drive the same simulators.
"""
from __future__ import annotations

import dataclasses

from .schedule import HOST_IO, MultiDeviceSchedule, OpKind, Schedule

GB = 1e9
TFLOP = 1e12

# disk bandwidth assumed when a model records none (datasheet presets
# predate the disk tier, hand-built models may omit it): a mid-range
# NVMe doing large sequential tile I/O.
_DISK_BW_FALLBACK = 2 * GB


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    # peak GEMM-engine throughput per precision class name, FLOP/s
    flops: dict
    h2d_bw: float          # host->device bytes/s (per direction)
    d2h_bw: float
    alloc_overhead: float  # seconds per malloc/free pair (async policy)
    launch_overhead: float = 3e-6
    mem_bytes: float = 0.0   # device memory capacity (0 = unknown/unbounded)
    # device-to-device interconnect bytes/s for the multi-device broadcast
    # (0 = unknown: simulate_multi falls back to h2d_bw).  Presets leave it
    # 0; repro.tune.calibrate() measures it whenever >= 2 devices are
    # visible, so calibrated models drive simulate_multi with the real
    # link speed by default.
    link_bw: float = 0.0
    source: str = "datasheet"            # "datasheet" | "measured"
    fingerprint: str = ""    # hardware identity hash (tuning-db cache key)
    # optional per-kernel rates, FLOP/s: {"gemm": {"f64": r, ...}, ...}.
    # Measured models fill this from micro-benchmarks (repro.tune.calibrate);
    # datasheet presets leave it None and every task runs at the class peak.
    kernel_flops: dict | None = None
    # disk tier (spill schedules, host_slots > 0): sequential read/write
    # bytes/s of the tile-store device and host RAM capacity.  0 = unknown:
    # the simulators fall back to _DISK_BW_FALLBACK and treat host memory
    # as unbounded.  repro.tune.calibrate() measures all three; datasheet
    # presets leave host_mem_bytes at 0 (host RAM is a property of the
    # box, not the accelerator), so the tuner's spill axis only engages
    # on measured or explicitly capped models.
    disk_read_bw: float = 0.0
    disk_write_bw: float = 0.0
    host_mem_bytes: float = 0.0

    def task_rate(self, task: str, cls_name: str) -> float:
        """FLOP/s for one task kind (``"gemm"``/``"syrk"``/...) at one
        precision class; falls back to the per-class peak when no
        per-kernel measurement is recorded.  The scaled-FP8 class
        ``"f8e4m3s"`` runs on the same e4m3 GEMM engine as the unscaled
        class (the power-of-two scale folds into the epilogue), so
        models that predate it alias its rate to ``"f8e4m3"``."""
        if self.kernel_flops:
            per_cls = self.kernel_flops.get(task)
            if per_cls:
                if cls_name in per_cls:
                    return per_cls[cls_name]
                if cls_name == "f8e4m3s" and "f8e4m3" in per_cls:
                    return per_cls["f8e4m3"]
        if cls_name not in self.flops and cls_name == "f8e4m3s":
            return self.flops["f8e4m3"]
        return self.flops[cls_name]

    def max_cache_slots(self, tb: int, reserve_slots: int = 0) -> int:
        """Largest cache-slot budget that fits ``mem_bytes`` for tb x tb
        f64 device tiles, after reserving ``reserve_slots`` (panel region,
        ndev > 1).  Unbounded when ``mem_bytes`` is unknown (0)."""
        if self.mem_bytes <= 0:
            return 2**31 - 1
        return int(self.mem_bytes // (8 * tb * tb)) - reserve_slots

    def max_host_slots(self, tb: int) -> int:
        """Largest host-slab budget that fits ``host_mem_bytes`` for
        tb x tb f64 slabs; unbounded when the capacity is unknown (0)."""
        if self.host_mem_bytes <= 0:
            return 2**31 - 1
        return int(self.host_mem_bytes // (8 * tb * tb))


HW = {
    # PCIe Gen4 x16 ~ 25 GB/s effective; A100 fp64 tensor 19.5 TF; 80 GB HBM.
    "a100-pcie": HardwareModel(
        "a100-pcie",
        {"f64": 19.5 * TFLOP, "f32": 19.5 * TFLOP, "f16": 312 * TFLOP,
         "bf16": 312 * TFLOP, "f8e4m3": 312 * TFLOP},
        25 * GB, 25 * GB, 12e-6, mem_bytes=80 * GB,
        disk_read_bw=3.2 * GB, disk_write_bw=2.8 * GB),
    # PCIe Gen5 x16 ~ 50 GB/s effective; H100 fp64 tensor ~60 TF (free
    # clocks); 80 GB HBM3.
    "h100-pcie": HardwareModel(
        "h100-pcie",
        {"f64": 60 * TFLOP, "f32": 60 * TFLOP, "f16": 750 * TFLOP,
         "bf16": 750 * TFLOP, "f8e4m3": 1500 * TFLOP},
        50 * GB, 50 * GB, 12e-6, mem_bytes=80 * GB,
        disk_read_bw=6.5 * GB, disk_write_bw=5.0 * GB),
    # NVLink-C2C: 900 GB/s bidirectional -> 450 GB/s per direction; 96 GB.
    "gh200": HardwareModel(
        "gh200",
        {"f64": 62 * TFLOP, "f32": 62 * TFLOP, "f16": 990 * TFLOP,
         "bf16": 990 * TFLOP, "f8e4m3": 1980 * TFLOP},
        450 * GB, 450 * GB, 12e-6, mem_bytes=96 * GB,
        disk_read_bw=6.5 * GB, disk_write_bw=5.0 * GB),
    # TPU v5e: bf16 MXU 197 TF, fp8 394 TF; f32 via 3-pass ~ 1/4 rate;
    # f64 emulated ~ 1/32 bf16.  Host DMA over PCIe ~ 32 GB/s; 16 GB HBM2.
    "tpu-v5e": HardwareModel(
        "tpu-v5e",
        {"f64": 6.2 * TFLOP, "f32": 49 * TFLOP, "f16": 197 * TFLOP,
         "bf16": 197 * TFLOP, "f8e4m3": 394 * TFLOP},
        32 * GB, 32 * GB, 0.0, mem_bytes=16 * GB,
        disk_read_bw=2.0 * GB, disk_write_bw=1.2 * GB),
}

_TASK_FLOPS = {
    OpKind.SYRK: lambda tb: tb**3,          # C -= A A^T (symmetric half)
    OpKind.GEMM: lambda tb: 2 * tb**3,
    OpKind.POTRF: lambda tb: tb**3 / 3.0,
    OpKind.TRSM: lambda tb: tb**3,
}


@dataclasses.dataclass
class SimResult:
    makespan: float
    compute_busy: float
    h2d_busy: float
    d2h_busy: float
    h2d_bytes: int
    d2h_bytes: int
    alloc_events: int
    timeline: list           # (engine, start, end, label)
    flops_useful: float      # n^3/3
    # disk lane (spill schedules only; zero for host_slots == 0)
    disk_busy: float = 0.0
    fetch_bytes: int = 0
    spill_bytes: int = 0

    @property
    def tflops(self) -> float:
        return self.flops_useful / self.makespan / TFLOP

    @property
    def total_bytes(self) -> int:
        return self.h2d_bytes + self.d2h_bytes


def _as_single(sched) -> Schedule:
    """Accept the unified MultiDeviceSchedule in its ndev=1 degenerate form
    (the type the planner API returns) wherever a flat Schedule is wanted;
    ndev>1 raises, pointing at simulate_multi/volume_report_multi."""
    if isinstance(sched, MultiDeviceSchedule):
        return sched.to_single()
    return sched


def simulate(sched: Schedule, hw: HardwareModel, record_timeline: bool = False) -> SimResult:
    """Event-driven simulation of the op stream on a three-engine machine.

    Spill schedules (``host_slots > 0``) add a fourth engine: the disk
    lane.  FETCH occupies it for ``bytes / disk_read_bw`` (a binding
    fetch, ``bytes == 0``, only rebinds the slab), SPILL for
    ``bytes / disk_write_bw``; LOAD/STORE pick up RAW/WAR hazards on the
    host slab the schedule bound their tile to, so host-tier contention
    shows up in the makespan exactly like device-tier contention does.
    """
    sched = _as_single(sched)
    tb = sched.tb
    lad = sched.plan.ladder
    overlap = sched.policy != "sync"
    spill = sched.host_slots > 0
    read_bw = hw.disk_read_bw or _DISK_BW_FALLBACK
    write_bw = hw.disk_write_bw or _DISK_BW_FALLBACK

    nslots = max(max(o.slot_c, o.slot_a, o.slot_b)
                 for o in sched.ops if o.kind not in HOST_IO) + 1
    ready = [0.0] * nslots        # time the slot's contents become valid
    reads = [0.0] * nslots        # time the slot's pending reads complete
    t_h2d = t_d2h = t_cmp = 0.0   # engine-free times
    t_dsk = 0.0
    busy = {"h2d": 0.0, "d2h": 0.0, "cmp": 0.0, "dsk": 0.0}
    nbytes = {"h2d": 0, "d2h": 0, "fetch": 0, "spill": 0}
    allocs = 0
    timeline = []
    # host tier: slab validity/read hazards + the static tile->slab map,
    # replayed from the FETCH records exactly as the executors replay it
    hready = [0.0] * sched.host_slots
    hreads = [0.0] * sched.host_slots
    tile_at = [None] * sched.host_slots
    hslot_of = {}                 # (i, j) -> slab
    disk_ready = {}               # (i, j) -> time the disk copy is valid

    def run_on(engine_free, dep, dur, engine, label):
        start = max(engine_free, dep)
        end = start + dur
        busy[engine] += dur
        if record_timeline:
            timeline.append((engine, start, end, label))
        return end

    for op in sched.ops:
        if op.kind is OpKind.FETCH:
            s = op.slot_c
            if tile_at[s] is not None:
                del hslot_of[tile_at[s]]
            dur = op.bytes / read_bw
            nbytes["fetch"] += op.bytes
            dep = max(hreads[s], hready[s],
                      disk_ready.get((op.i, op.j), 0.0))
            if overlap:
                t_dsk = run_on(t_dsk, dep, dur, "dsk", f"F{op.i},{op.j}")
                end = t_dsk
            else:
                t_cmp = run_on(t_cmp, dep, dur, "dsk", f"F{op.i},{op.j}")
                t_dsk = end = t_cmp
            hready[s] = end
            tile_at[s] = (op.i, op.j)
            hslot_of[(op.i, op.j)] = s
        elif op.kind is OpKind.SPILL:
            s = op.slot_c
            dur = op.bytes / write_bw
            nbytes["spill"] += op.bytes
            if overlap:
                t_dsk = run_on(t_dsk, hready[s], dur, "dsk",
                               f"W{op.i},{op.j}")
                end = t_dsk
            else:
                t_cmp = run_on(t_cmp, hready[s], dur, "dsk",
                               f"W{op.i},{op.j}")
                t_dsk = end = t_cmp
            disk_ready[(op.i, op.j)] = end
            hreads[s] = max(hreads[s], end)
        elif op.kind is OpKind.ALLOC:
            allocs += 1
            t_cmp += hw.alloc_overhead  # cudaMalloc stalls the stream
            # a fresh buffer: the recycled slot id carries no hazards
            reads[op.slot_c] = ready[op.slot_c] = 0.0
        elif op.kind is OpKind.FREE:
            t_cmp += hw.alloc_overhead * 0.3
        elif op.kind is OpKind.LOAD:
            dur = op.bytes / hw.h2d_bw
            nbytes["h2d"] += op.bytes
            # a LOAD overwrites the slot: it must wait for pending reads
            # (WAR — e.g. a STORE still draining the slot) and for any
            # in-flight write of the previous contents (WAW)
            dep = max(reads[op.slot_c], ready[op.slot_c])
            hs = hslot_of.get((op.i, op.j)) if spill else None
            if hs is not None:      # RAW on the host slab's FETCH
                dep = max(dep, hready[hs])
            if overlap:
                t_h2d = run_on(t_h2d, dep, dur, "h2d", f"L{op.i},{op.j}")
                ready[op.slot_c] = t_h2d
            else:
                t_cmp = run_on(t_cmp, dep, dur, "h2d", f"L{op.i},{op.j}")
                t_h2d = t_cmp
                ready[op.slot_c] = t_cmp
            if hs is not None:
                hreads[hs] = max(hreads[hs], ready[op.slot_c])
        elif op.kind is OpKind.STORE:
            dur = op.bytes / hw.d2h_bw
            nbytes["d2h"] += op.bytes
            dep = ready[op.slot_c]
            hs = hslot_of.get((op.i, op.j)) if spill else None
            if hs is not None:      # WAR on the target host slab
                dep = max(dep, hreads[hs])
            if overlap:
                t_d2h = run_on(t_d2h, dep, dur, "d2h", f"S{op.i},{op.j}")
                end = t_d2h
            else:
                t_cmp = run_on(t_cmp, dep, dur, "d2h", f"S{op.i},{op.j}")
                t_d2h = t_cmp
                end = t_cmp
            reads[op.slot_c] = max(reads[op.slot_c], end)
            if hs is not None:
                hready[hs] = end
        else:  # compute
            flops = _TASK_FLOPS[op.kind](tb)
            rate = hw.task_rate(op.kind.value, lad[op.cls])
            dur = flops / rate + hw.launch_overhead
            deps = [ready[s] for s in (op.slot_c, op.slot_a, op.slot_b) if s >= 0]
            deps.append(reads[op.slot_c])   # WAR: output slot still being read
            t_cmp = run_on(t_cmp, max(deps), dur, "cmp", op.kind.value)
            ready[op.slot_c] = t_cmp
            for s in (op.slot_a, op.slot_b):
                if s >= 0 and s != op.slot_c:
                    reads[s] = max(reads[s], t_cmp)

    makespan = max(t_h2d, t_d2h, t_cmp, t_dsk)
    return SimResult(
        makespan=makespan,
        compute_busy=busy["cmp"], h2d_busy=busy["h2d"], d2h_busy=busy["d2h"],
        h2d_bytes=nbytes["h2d"], d2h_bytes=nbytes["d2h"],
        alloc_events=allocs, timeline=timeline,
        flops_useful=sched.flops(),
        disk_busy=busy["dsk"],
        fetch_bytes=nbytes["fetch"], spill_bytes=nbytes["spill"],
    )


def volume_report(sched: Schedule) -> dict:
    """Exact C2G/G2C byte volumes (paper Fig. 8 / Fig. 12)."""
    sched = _as_single(sched)
    rep = {
        "policy": sched.policy,
        "nt": sched.nt,
        "tb": sched.tb,
        "c2g_bytes": sched.loads_bytes(),
        "g2c_bytes": sched.stores_bytes(),
        "total_bytes": sched.loads_bytes() + sched.stores_bytes(),
        "loads": sched.count(OpKind.LOAD),
        "stores": sched.count(OpKind.STORE),
        "cache_hits": sched.hits,
        "evictions": sched.evictions,
        "allocs": sched.count(OpKind.ALLOC),
        "matrix_bytes": 8 * (sched.nt * sched.tb) ** 2,
    }
    if sched.host_slots:
        rep.update({
            "host_slots": sched.host_slots,
            "host_bytes": 8 * sched.host_slots * sched.tb ** 2,
            "fetch_bytes": sched.fetch_bytes(),
            "spill_bytes": sched.spill_bytes(),
            "fetches": sched.count(OpKind.FETCH),
            "spills": sched.count(OpKind.SPILL),
        })
    return rep


# ---------------------------------------------------------------------------
# Multi-device event simulation (paper Fig. 9)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceSimStats:
    compute_busy: float
    h2d_busy: float
    d2h_busy: float
    h2d_bytes: int
    d2h_bytes: int
    recv_bytes: int
    finish: float          # when this device's last engine goes idle
    fetch_bytes: int = 0   # disk lane (spill schedules only)
    spill_bytes: int = 0


@dataclasses.dataclass
class MultiSimResult:
    makespan: float
    devices: list          # DeviceSimStats per device
    link_busy: float
    link_bytes: int
    flops_useful: float
    timeline: list         # (engine, start, end, label); engine "d<k>:h2d" etc.
    # shared disk lane (spill schedules only; zero for host_slots == 0)
    disk_busy: float = 0.0
    fetch_bytes: int = 0
    spill_bytes: int = 0

    @property
    def tflops(self) -> float:
        return self.flops_useful / self.makespan / TFLOP

    @property
    def compute_efficiency(self) -> float:
        """Fraction of the run the compute engines are busy, averaged over
        devices — the Fig. 9 scaling metric (1.0 = perfect overlap of the
        broadcast and OOC traffic behind compute)."""
        busy = sum(d.compute_busy for d in self.devices)
        return busy / (len(self.devices) * self.makespan)


def simulate_multi(msched: MultiDeviceSchedule, hw: HardwareModel,
                   link_bw: float | None = None,
                   record_timeline: bool = False) -> MultiSimResult:
    """Event simulation of the per-device op streams + shared interconnect.

    Every device runs the same three-engine model as :func:`simulate`
    (its own H2D/D2H/compute engines, slot RAW/WAR tracking); both
    broadcast kinds — the column-scoped panel broadcast and, for 2D
    grids, the row-scoped ownership broadcast — ride one *shared* link
    engine of bandwidth ``link_bw``.  The default is the hardware
    model's measured ``hw.link_bw`` when it has one (calibrated models,
    see :func:`repro.tune.calibrate`), else ``hw.h2d_bw`` (PCIe-switch
    platforms share a slow link, NVLink-C2C a fast one).  Broadcasts are
    staged through the sender's host-coherent copy, so each RECV waits
    until that copy exists (the sender's STORE, or the host-landing RECV
    that delivered it to the sender), then occupies the link for its own
    ingress bytes — a per-receiver-copy collective on a shared medium.
    A host-landing RECV (``slot_c < 0``) updates the receiver's host-slab
    coherence instead of a device slot: later LOADs of that tile on the
    receiver wait for it.

    Streams are replayed in :meth:`MultiDeviceSchedule.dispatch_chunks`
    order — column-by-column owner-first for ``lookahead=0`` (exactly
    the partial order the BCAST/RECV edges impose), and the emitter's
    interleaved final/advance waves for pipelined schedules, where the
    advance chunk of column ``k+lookahead`` overlaps the other grid
    columns' trailing updates.  With ``record_timeline`` and
    ``lookahead > 0`` an extra ``d{d}:pipe`` lane per device tags every
    compute span ``ahead:`` (lookahead-panel work: push/advance phases)
    or ``trail:`` (trailing-update work) so the overlap is visible in
    :func:`chrome_trace`.
    """
    if link_bw is None:
        link_bw = hw.link_bw or hw.h2d_bw
    tb, lad, ndev = msched.tb, msched.plan.ladder, msched.ndev
    overlap = msched.policy != "sync"
    spill = msched.host_slots > 0
    read_bw = hw.disk_read_bw or _DISK_BW_FALLBACK
    write_bw = hw.disk_write_bw or _DISK_BW_FALLBACK

    ready = [[0.0] * msched.stream_nslots(d) for d in range(ndev)]
    reads = [[0.0] * msched.stream_nslots(d) for d in range(ndev)]
    # host tier (spill schedules): per-device slab hazards + tile->slab
    # maps, one *shared* disk engine (the stores all target one device)
    hready = [[0.0] * msched.host_slots for _ in range(ndev)]
    hreads = [[0.0] * msched.host_slots for _ in range(ndev)]
    tile_at = [[None] * msched.host_slots for _ in range(ndev)]
    hslot_of = [{} for _ in range(ndev)]
    disk_ready = {}
    t_dsk = 0.0
    disk_busy = 0.0
    # (i, j) -> time the tile's final value is available in device d's
    # host slab (its own STOREs + host-landing RECVs); recv_host is the
    # RECV-delivered subset, the only tiles whose LOAD must wait (a
    # device's own STOREs keep the 1D model's engine-FIFO approximation)
    host_avail = [{} for _ in range(ndev)]
    recv_host = [{} for _ in range(ndev)]
    t_h2d = [0.0] * ndev
    t_d2h = [0.0] * ndev
    t_cmp = [0.0] * ndev
    t_link = 0.0
    busy = [{"h2d": 0.0, "d2h": 0.0, "cmp": 0.0} for _ in range(ndev)]
    nbytes = [{"h2d": 0, "d2h": 0, "recv": 0, "fetch": 0, "spill": 0}
              for _ in range(ndev)]
    link_busy = 0.0
    link_bytes = 0
    timeline = []

    def span(engine, start, end, label):
        if record_timeline:
            timeline.append((engine, start, end, label))

    # phases emitted ahead of the trailing update (lookahead pipeline)
    _AHEAD_PHASES = {"push", "recv-ahead", "advance"}
    pipe_lane = record_timeline and msched.lookahead > 0

    def run_op(d, op, phase="update"):
        nonlocal t_link, link_busy, link_bytes, t_dsk, disk_busy
        if op.kind is OpKind.FETCH:
            s = op.slot_c
            if tile_at[d][s] is not None:
                del hslot_of[d][tile_at[d][s]]
            dur = op.bytes / read_bw
            nbytes[d]["fetch"] += op.bytes
            dep = max(hreads[d][s], hready[d][s],
                      disk_ready.get((op.i, op.j), 0.0))
            if not overlap:
                dep = max(dep, t_cmp[d])
            start = max(t_dsk, dep)
            t_dsk = start + dur
            disk_busy += dur
            if not overlap:
                t_cmp[d] = t_dsk
            hready[d][s] = t_dsk
            tile_at[d][s] = (op.i, op.j)
            hslot_of[d][(op.i, op.j)] = s
            # the fetched slab is this device's host copy of the tile
            host_avail[d][(op.i, op.j)] = max(
                host_avail[d].get((op.i, op.j), 0.0), t_dsk)
            span("dsk", start, t_dsk, f"F{op.i},{op.j}@d{d}")
        elif op.kind is OpKind.SPILL:
            s = op.slot_c
            dur = op.bytes / write_bw
            nbytes[d]["spill"] += op.bytes
            dep = hready[d][s]
            if not overlap:
                dep = max(dep, t_cmp[d])
            start = max(t_dsk, dep)
            t_dsk = start + dur
            disk_busy += dur
            if not overlap:
                t_cmp[d] = t_dsk
            disk_ready[(op.i, op.j)] = t_dsk
            hreads[d][s] = max(hreads[d][s], t_dsk)
            span("dsk", start, t_dsk, f"W{op.i},{op.j}@d{d}")
        elif op.kind is OpKind.LOAD:
            dur = op.bytes / hw.h2d_bw
            nbytes[d]["h2d"] += op.bytes
            dep = max(reads[d][op.slot_c], ready[d][op.slot_c],
                      recv_host[d].get((op.i, op.j), 0.0))
            hs = hslot_of[d].get((op.i, op.j)) if spill else None
            if hs is not None:      # RAW on the host slab's FETCH
                dep = max(dep, hready[d][hs])
            if overlap:
                start = max(t_h2d[d], dep)
                t_h2d[d] = start + dur
                end = t_h2d[d]
            else:
                start = max(t_cmp[d], dep)
                t_cmp[d] = start + dur
                t_h2d[d] = end = t_cmp[d]
            busy[d]["h2d"] += dur
            ready[d][op.slot_c] = end
            if hs is not None:
                hreads[d][hs] = max(hreads[d][hs], end)
            span(f"d{d}:h2d", start, end, f"L{op.i},{op.j}")
        elif op.kind is OpKind.STORE:
            dur = op.bytes / hw.d2h_bw
            nbytes[d]["d2h"] += op.bytes
            dep = ready[d][op.slot_c]
            hs = hslot_of[d].get((op.i, op.j)) if spill else None
            if hs is not None:      # WAR on the target host slab
                dep = max(dep, hreads[d][hs])
            if overlap:
                start = max(t_d2h[d], dep)
                t_d2h[d] = start + dur
                end = t_d2h[d]
            else:
                start = max(t_cmp[d], dep)
                t_cmp[d] = start + dur
                t_d2h[d] = end = t_cmp[d]
            busy[d]["d2h"] += dur
            reads[d][op.slot_c] = max(reads[d][op.slot_c], end)
            host_avail[d][(op.i, op.j)] = end
            if hs is not None:
                hready[d][hs] = end
            span(f"d{d}:d2h", start, end, f"S{op.i},{op.j}")
        elif op.kind is OpKind.BCAST:
            pass    # availability tracked via host_avail; RECVs carry cost
        elif op.kind is OpKind.RECV:
            dur = op.bytes / link_bw
            nbytes[d]["recv"] += op.bytes
            link_bytes += op.bytes
            # the sender's host-coherent copy must exist before the wire
            dep = (host_avail[op.src].get((op.i, op.j), 0.0)
                   if op.src >= 0 else 0.0)
            if op.slot_c >= 0:      # panel-slot landing (WAR/WAW on slot)
                dep = max(dep, reads[d][op.slot_c], ready[d][op.slot_c])
            if not overlap:
                dep = max(dep, t_cmp[d])   # sync: one engine per device
            start = max(t_link, dep)
            t_link = start + dur
            link_busy += dur
            if not overlap:
                t_cmp[d] = t_link
            if op.slot_c >= 0:
                ready[d][op.slot_c] = t_link
            else:                   # host-landing: receiver slab coherence
                host_avail[d][(op.i, op.j)] = t_link
                recv_host[d][(op.i, op.j)] = t_link
                if spill:           # the landing writes a bound host slab
                    hs = hslot_of[d].get((op.i, op.j))
                    if hs is not None:
                        hready[d][hs] = t_link
            span("link", start, t_link, f"B{op.i},{op.j}->d{d}")
        else:  # compute
            flops = _TASK_FLOPS[op.kind](tb)
            dur = (flops / hw.task_rate(op.kind.value, lad[op.cls])
                   + hw.launch_overhead)
            deps = [ready[d][s]
                    for s in (op.slot_c, op.slot_a, op.slot_b) if s >= 0]
            deps.append(reads[d][op.slot_c])
            start = max(t_cmp[d], max(deps))
            t_cmp[d] = start + dur
            busy[d]["cmp"] += dur
            ready[d][op.slot_c] = t_cmp[d]
            for s in (op.slot_a, op.slot_b):
                if s >= 0 and s != op.slot_c:
                    reads[d][s] = max(reads[d][s], t_cmp[d])
            span(f"d{d}:cmp", start, t_cmp[d], op.kind.value)
            if pipe_lane:
                tag = "ahead" if phase in _AHEAD_PHASES else "trail"
                span(f"d{d}:pipe", start, t_cmp[d],
                     f"{tag}:{op.kind.value}")

    # replay in dispatch-chunk order (owner-first per column at
    # lookahead=0; the emitter's interleaved waves for lookahead>0)
    for d, op, phase in msched.iter_dispatch_order(with_phase=True):
        run_op(d, op, phase)

    devices = [
        DeviceSimStats(
            compute_busy=busy[d]["cmp"], h2d_busy=busy[d]["h2d"],
            d2h_busy=busy[d]["d2h"], h2d_bytes=nbytes[d]["h2d"],
            d2h_bytes=nbytes[d]["d2h"], recv_bytes=nbytes[d]["recv"],
            finish=max(t_h2d[d], t_d2h[d], t_cmp[d]),
            fetch_bytes=nbytes[d]["fetch"], spill_bytes=nbytes[d]["spill"])
        for d in range(ndev)
    ]
    makespan = max([t_link, t_dsk] + [dv.finish for dv in devices])
    return MultiSimResult(
        makespan=makespan, devices=devices,
        link_busy=link_busy, link_bytes=link_bytes,
        flops_useful=msched.flops(), timeline=timeline,
        disk_busy=disk_busy,
        fetch_bytes=sum(n["fetch"] for n in nbytes),
        spill_bytes=sum(n["spill"] for n in nbytes),
    )


def volume_report_multi(msched: MultiDeviceSchedule) -> dict:
    """Per-device + aggregate byte volumes of a multi-device schedule."""
    per_device = []
    for d in range(msched.ndev):
        per_device.append({
            "device": d,
            "c2g_bytes": msched.loads_bytes(d),
            "g2c_bytes": msched.stores_bytes(d),
            "recv_bytes": sum(o.bytes for o in msched.streams[d]
                              if o.kind is OpKind.RECV),
            "loads": msched.count(OpKind.LOAD, d),
            "stores": msched.count(OpKind.STORE, d),
            "cache_hits": msched.hits[d] if msched.hits else 0,
            "evictions": msched.evictions[d] if msched.evictions else 0,
        })
    rep = {
        "policy": msched.policy,
        "nt": msched.nt,
        "tb": msched.tb,
        "ndev": msched.ndev,
        "grid": list(msched.grid),
        "c2g_bytes": msched.loads_bytes(),
        "g2c_bytes": msched.stores_bytes(),
        "bcast_bytes": msched.bcast_bytes(),
        "matrix_bytes": 8 * (msched.nt * msched.tb) ** 2,
        "per_device": per_device,
    }
    if msched.host_slots:
        rep.update({
            "host_slots": msched.host_slots,
            "fetch_bytes": msched.fetch_bytes(),
            "spill_bytes": msched.spill_bytes(),
        })
        for dev in per_device:
            d = dev["device"]
            dev["fetch_bytes"] = msched.fetch_bytes(d)
            dev["spill_bytes"] = msched.spill_bytes(d)
    return rep


def crosscheck_executed_volume(msched: MultiDeviceSchedule, executed: dict,
                               hw: HardwareModel | None = None) -> dict:
    """Check an executor's *executed* transfer counters against the model.

    ``executed`` is the counter dict a real executor reports after a run
    (:attr:`MultiDeviceJaxExecutor.last_transfer_stats` /
    ``OOCSolver.transfer_stats()``): BCAST/RECV op counts and the bytes
    that actually crossed the interconnect.  The static-schedule claim is
    that these are knowable ahead of time — so they must equal, exactly,
    the op stream's own accounting and (when ``hw`` is given) the bytes
    :func:`simulate_multi` pushes through its shared link engine.

    Returns ``{"match": bool, "expected": ..., "executed": ...,
    "mismatches": {field: (expected, executed)}}``.  Note the byte-level
    check assumes the executor's wire format is the tile class (true with
    x64 enabled; with x64 off the f64 class degrades to 4-byte words and
    the byte fields will report a mismatch — the op counts still hold).
    """
    if executed is None:
        raise ValueError(
            "no executed transfer counters: the last factor() did not run "
            "the multi-device jax executor (transfer_stats() is None on "
            "the numpy replay and single-device backends)")
    expected = {
        "bcast_ops": msched.count(OpKind.BCAST),
        "recv_ops": msched.count(OpKind.RECV),
        "bcast_bytes": sum(o.bytes for s in msched.streams for o in s
                           if o.kind is OpKind.BCAST),
        "recv_bytes": msched.bcast_bytes(),
    }
    if hw is not None:
        expected["simulated_link_bytes"] = simulate_multi(msched, hw).link_bytes
        executed = dict(executed,
                        simulated_link_bytes=executed.get("recv_bytes"))
    mismatches = {k: (v, executed.get(k)) for k, v in expected.items()
                  if executed.get(k) != v}
    return {"match": not mismatches, "expected": expected,
            "executed": executed, "mismatches": mismatches}


def chrome_trace(result, path=None) -> dict:
    """Export a recorded timeline as chrome://tracing ("Trace Event") JSON.

    Works for both :class:`SimResult` and :class:`MultiSimResult` (any
    object with a ``timeline`` of ``(engine, start, end, label)`` spans
    and a ``makespan``); each engine becomes one named track ("thread"),
    every span a complete ``"X"`` event with microsecond timestamps.
    Load the file at chrome://tracing or https://ui.perfetto.dev.

    Multi-device timelines recorded from a ``lookahead > 0`` schedule
    carry per-device ``d{d}:pipe`` "panel pipeline" lanes whose spans
    are prefixed ``ahead:`` / ``trail:``; those get distinct chrome
    colors (``cname``) so lookahead-panel work is visually separable
    from the trailing update it overlaps.

    Returns the trace dict; with ``path`` given it is also written there
    as JSON.  Simulations must be run with ``record_timeline=True``.
    """
    if not result.timeline:
        raise ValueError("timeline not recorded: simulate with "
                         "record_timeline=True before exporting a trace")
    engines = []
    for engine, _, _, _ in result.timeline:
        if engine not in engines:
            engines.append(engine)
    events = [
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": t,
         "args": {"name": engine}}
        for t, engine in enumerate(engines)
    ]
    tids = {engine: t for t, engine in enumerate(engines)}
    for engine, start, end, label in result.timeline:
        ev = {
            "name": label, "cat": engine, "ph": "X",
            "ts": start * 1e6, "dur": (end - start) * 1e6,
            "pid": 0, "tid": tids[engine],
        }
        if engine.endswith(":pipe"):
            ev["cname"] = ("thread_state_running"
                           if label.startswith("ahead:")
                           else "grey")
        events.append(ev)
    trace = {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {"makespan_s": result.makespan,
                     "tflops": result.tflops},
    }
    if path is not None:
        import json
        with open(path, "w") as f:
            json.dump(trace, f)
    return trace


def ascii_trace(result: SimResult, width: int = 100) -> str:
    """Fig. 7-style trace: one row per engine."""
    if not result.timeline:
        return "(timeline not recorded)"
    span = result.makespan
    rows = {"h2d": [" "] * width, "cmp": [" "] * width,
            "d2h": [" "] * width, "dsk": [" "] * width}
    glyph = {"h2d": "o", "cmp": "#", "d2h": "g", "dsk": "d"}
    seen_dsk = False
    for engine, s, e, _ in result.timeline:
        seen_dsk = seen_dsk or engine == "dsk"
        a = int(s / span * (width - 1))
        b = max(a + 1, int(e / span * (width - 1)))
        for x in range(a, min(b, width)):
            rows[engine][x] = glyph[engine]
    lanes = [("G2C", rows["h2d"]), ("Work", rows["cmp"]),
             ("C2G", rows["d2h"])]
    if seen_dsk:
        lanes.append(("Disk", rows["dsk"]))
    return "\n".join(f"{name:>4s} |{''.join(row)}|"
                     for name, row in lanes)
