"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408,
vocab=151936, qk_norm.  [hf:Qwen/Qwen3-14B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936,
    qk_norm=True, rope_theta=1000000.0, mlp_act="silu", scan_group=1,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab=128,
    qk_norm=True, mlp_act="silu", scan_group=1, dtype="float32",
)
