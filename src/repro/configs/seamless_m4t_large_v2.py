"""seamless-m4t-large-v2 [audio]: enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192, vocab=256206; speech frontend is a
STUB providing precomputed frame embeddings.  [arXiv:2308.11596]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256206,
    enc_layers=24, cross_attention=True,
    frontend="audio", frontend_tokens=1024,
    mlp_act="gelu", scan_group=1,
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab=128,
    enc_layers=2, cross_attention=True,
    frontend="audio", frontend_tokens=8,
    mlp_act="gelu", scan_group=1, dtype="float32",
)
