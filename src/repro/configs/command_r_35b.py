"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528,
vocab=256000, no-bias, tied embeddings.  [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    num_layers=40, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22528, vocab=256000,
    mlp_act="silu", tie_embeddings=True, rope_theta=8000000.0, scan_group=1,
)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab=128,
    mlp_act="silu", tie_embeddings=True, scan_group=1, dtype="float32",
)
