"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1, head_dim 256)
d_ff=6912, vocab=262144, 5:1 local(512-window):global.
[hf:google/gemma-3-1b-pt]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144,
    sliding_window=512, local_global_ratio=5,
    qk_norm=True, rope_theta=1000000.0, mlp_act="gelu",
    tie_embeddings=True, scan_group=6,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    num_layers=8, d_model=64, num_heads=2, num_kv_heads=1, head_dim=32,
    d_ff=128, vocab=128,
    sliding_window=8, local_global_ratio=5,
    qk_norm=True, mlp_act="gelu", tie_embeddings=True,
    scan_group=6, dtype="float32",
)
