"""deepseek-v2-lite-16b [moe]: 27L d_model=2048, MLA kv_lora=512,
2 shared + 64 routed experts top-6 (expert_ff=1408), first layer dense.
[arXiv:2405.04434]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=10944, vocab=102400,
    mla=True, kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    moe_every=1, first_dense=1, mlp_act="silu", scan_group=1,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="moe",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab=128,
    mla=True, kv_lora_rank=32, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16,
    n_experts=4, n_shared_experts=1, top_k=2, moe_d_ff=32,
    moe_every=1, first_dense=1, mlp_act="silu", scan_group=1, dtype="float32", moe_capacity=8.0,
)
