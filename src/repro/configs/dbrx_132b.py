"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) expert_ff=10752,
vocab=100352, 16 experts top-4.  [hf:databricks/dbrx-base]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    n_experts=16, top_k=4, moe_every=1, mlp_act="silu",
    rope_theta=500000.0, scan_group=1,
)

SMOKE = ModelConfig(
    name="dbrx-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=96, vocab=128,
    n_experts=4, top_k=2, moe_every=1, mlp_act="silu",
    scan_group=1, dtype="float32", moe_capacity=8.0,
)
