"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728,
vocab=256000, squared-ReLU MLP.  [arXiv:2402.16819]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab=256000,
    mlp_act="squared_relu", scan_group=1,
)

SMOKE = ModelConfig(
    name="nemotron-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=192, vocab=128,
    mlp_act="squared_relu", scan_group=1, dtype="float32",
)
