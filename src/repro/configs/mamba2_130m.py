"""mamba2-130m [ssm]: 24L d_model=768, attn-free SSD, vocab=50280,
d_state=128.  [arXiv:2405.21060]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True, scan_group=1,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab=128,
    ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    tie_embeddings=True, scan_group=1, dtype="float32",
)
