"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480,
vocab=64000 text backbone; anyres vision frontend is a STUB providing
precomputed patch embeddings.  [hf:llava-hf/llava-v1.6-34b-hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000,
    frontend="vision", frontend_tokens=576,
    mlp_act="silu", rope_theta=5000000.0, scan_group=1,
)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab=128,
    frontend="vision", frontend_tokens=8,
    mlp_act="silu", scan_group=1, dtype="float32",
)
