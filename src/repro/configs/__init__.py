"""Architecture registry: the 10 assigned architectures + the paper's own
Cholesky/geospatial application config.

Each module defines ``CONFIG`` (published numbers) and ``SMOKE`` (reduced,
same family — used by the per-arch CPU smoke tests).  ``get_config`` is the
single lookup used by the launcher, dry-run and tests.
"""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "mamba2_130m",
    "dbrx_132b",
    "deepseek_v2_lite_16b",
    "qwen3_14b",
    "gemma3_1b",
    "nemotron_4_340b",
    "command_r_35b",
    "llava_next_34b",
    "seamless_m4t_large_v2",
    "jamba_1_5_large_398b",
]

# canonical dashed ids (CLI) -> module names
_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    key = name.replace("-", "_")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ALIASES)}")
    return key


def get_config(name: str, smoke: bool = False):
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE if smoke else mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shapes_for(cfg) -> dict:
    """Applicable shapes for an arch (long_500k only when sub-quadratic —
    DESIGN.md §4); skipped cells are still reported by the dry-run."""
    out = {}
    for name, spec in SHAPES.items():
        if name == "long_500k" and not cfg.sub_quadratic():
            continue
        out[name] = spec
    return out
