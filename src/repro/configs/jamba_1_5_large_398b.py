"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, vocab=65536; Mamba:attn 7:1 interleave, MoE 16e top-2 every
other layer.  [arXiv:2403.19887]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    n_experts=16, top_k=2, moe_every=2,
    attn_every=8, ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    mlp_act="silu", scan_group=8,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab=128,
    n_experts=4, top_k=2, moe_every=2,
    attn_every=4, ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
    mlp_act="silu", scan_group=4, dtype="float32", moe_capacity=8.0,
)
