"""Error-feedback int8 gradient compression for cross-pod all-reduce.

The paper's thesis — move the minimum acceptable bytes per word — applied
to the slowest link in a multi-pod job (DCN between pods, ~an order of
magnitude slower than ICI).  Gradients crossing the "pod" axis are
quantized to int8 with per-128-block scales (4x fewer wire bytes than
f32); the quantization residual is fed back into the next step's
gradient (error feedback), which preserves SGD-class convergence
(Karimireddy et al., 2019) and keeps AdamW stable in practice.

Protocol per block: (1) agree on a common scale with a tiny pmax,
(2) psum the int8 payloads, (3) dequantize with the common scale.
Outside a bound axis name (single-pod, or pjit without shard_map) the
collective degrades to the identity and only the quantize/dequantize
numerics (and the EF residual) apply.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .quantized import BLOCK


def ef_init(grads_like) -> Any:
    """Zero error-feedback residual tree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def _blockify(x):
    last = x.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x.reshape(*x.shape[:-1], -1, BLOCK), last


def _deblockify(b, last):
    out = b.reshape(*b.shape[:-2], -1)
    return out[..., :last]


def compress_pod_gradients(grads, ef_state, axis: str = "pod",
                           mean: bool = True):
    """(grads, ef_state) -> (reduced_grads, new_ef_state).

    Wire format per tensor: int8 payload (original shape) + one f32
    scale per 128-element block — 4x fewer DCN bytes than f32 grads.
    """
    def one(g, err):
        target = g.astype(jnp.float32) + err
        blocks, last = _blockify(target)
        scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
        n = 1
        try:
            scale = jax.lax.pmax(scale, axis)     # tiny: 1/128 of payload
            n = jax.lax.psum(1, axis)
        except NameError:
            pass
        safe = jnp.where(scale == 0, 1.0, scale)
        q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127)
        local_hat = q * safe[..., None]           # what the wire carries
        new_err = target - _deblockify(local_hat, last)
        summed = q
        if n != 1:
            summed = jax.lax.psum(q, axis)        # int8-payload all-reduce
        out = summed * safe[..., None]
        if mean and n != 1:
            out = out / n
        return _deblockify(out, last).astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
