"""AdamW with optionally int8-quantized moments (per-block scales).

Pure-pytree implementation (no optax dependency).  With ``quantize=True``
the first/second moments are stored as Q8 (int8 + per-128-block f32 scale):
~4x less optimizer HBM than fp32 moments — the difference between fitting
and not fitting the 340B-class configs on a 16 GB/chip v5e pod slice
(EXPERIMENTS.md §Dry-run).  Update math always runs in f32.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .quantized import (Q8, dequantize_q8, dequantize_q8_root4, quantize_q8,
                        quantize_q8_root4)


class OptState(NamedTuple):
    step: jax.Array
    m: Any            # pytree of f32 arrays or Q8
    v: Any


def _zeros_like_maybe_q8(p, quantize: bool):
    z = jnp.zeros(p.shape, jnp.float32)
    return quantize_q8(z) if quantize else z


def adamw_init(params, quantize: bool = False) -> OptState:
    m = jax.tree.map(lambda p: _zeros_like_maybe_q8(p, quantize), params)
    v = jax.tree.map(lambda p: _zeros_like_maybe_q8(p, quantize), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=m, v=v)


def adamw_update(params, grads, state: OptState, lr: float = 1e-4,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.01, quantize: bool = False):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        mf = dequantize_q8(m) if isinstance(m, Q8) else m
        vf = dequantize_q8_root4(v) if isinstance(v, Q8) else v
        mf = b1 * mf + (1.0 - b1) * g
        vf = b2 * vf + (1.0 - b2) * g * g
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        if quantize:
            return new_p, quantize_q8(mf), quantize_q8_root4(vf)
        return new_p, mf, vf

    is_q8 = lambda x: isinstance(x, Q8)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state.m, is_leaf=is_q8)[0]
    flat_v = jax.tree.flatten(state.v, is_leaf=is_q8)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, m=new_m, v=new_v)
