from .adamw import adamw_init, adamw_update, OptState
from .quantized import quantize_q8, dequantize_q8, Q8

__all__ = ["adamw_init", "adamw_update", "OptState",
           "quantize_q8", "dequantize_q8", "Q8"]
