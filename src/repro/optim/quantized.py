"""Block-quantized (int8 + f32 scale) tensor storage.

The paper's thesis — move/store the minimum acceptable bytes per word —
applied to optimizer state and gradient collectives: Adam moments and
cross-pod gradient payloads are stored as int8 with one f32 scale per
128-element block of the trailing dimension (symmetric absmax scaling).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 128


class Q8(NamedTuple):
    q: jax.Array        # int8 payload, original shape
    scale: jax.Array    # f32, shape [..., ceil(last/BLOCK)]


def _pad_to_block(x):
    last = x.shape[-1]
    pad = (-last) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def quantize_q8(x: jax.Array) -> Q8:
    orig_last = x.shape[-1]
    xp, pad = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(*xp.shape[:-1], -1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127, 127).astype(jnp.int8)
    q = q.reshape(*xp.shape[:-1], -1)[..., :orig_last]
    return Q8(q, scale)


def dequantize_q8(t: Q8, dtype=jnp.float32) -> jax.Array:
    q = t.q.astype(jnp.float32)
    orig_last = q.shape[-1]
    qp, pad = _pad_to_block(q)
    blocks = qp.reshape(*qp.shape[:-1], -1, BLOCK)
    out = blocks * t.scale[..., None]
    return out.reshape(*qp.shape[:-1], -1)[..., :orig_last].astype(dtype)


# ---------------------------------------------------------------------------
# Fourth-root coding for non-negative second moments.
#
# Linear int8 flushes small v entries in a block to 0, which explodes the
# Adam update m/(sqrt(v)+eps).  Quantizing u = v^(1/4) compresses the
# dynamic range (a 1e8 spread in v becomes 1e2 in u), bounding the
# relative error of sqrt(v) at ~2/127 per block — the same trick as
# dynamic-code 8-bit Adam, in closed form.

def quantize_q8_root4(v: jax.Array) -> Q8:
    return quantize_q8(jnp.sqrt(jnp.sqrt(jnp.maximum(v, 0.0))))


def dequantize_q8_root4(t: Q8, dtype=jnp.float32) -> jax.Array:
    u = dequantize_q8(t, jnp.float32)
    return jnp.square(jnp.square(u)).astype(dtype)
