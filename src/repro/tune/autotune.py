"""`repro.tune` orchestration: model selection -> db lookup -> search.

Two entry points:

* :func:`tune` — the user-facing campaign: pick (or calibrate) a hardware
  model, enumerate and score every feasible schedule, persist the winner,
  return the full ranked :class:`~repro.tune.search.TuneResult` table.
* :func:`resolve_config` — the planner hook: ``repro.plan(n, config)``
  calls this when the config has open dimensions (``tb=0`` /
  ``policy="auto"``) and needs a concrete one.  Simulation-only scoring
  against a preset model by default (never calibrates implicitly), so a
  CPU CI run is fast and bit-deterministic.

Hardware-model resolution order (first match wins):

  1. an explicit ``hw`` argument (a :class:`HardwareModel` or a preset
     name);
  2. the config's own ``hw`` preset tag;
  3. the process default set by :func:`set_default_hardware` — e.g. a
     calibrated model, after which every auto config in the process is
     tuned for the measured machine;
  4. the ``gh200`` datasheet preset (the paper's flagship platform).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core.analytics import HW, HardwareModel
from repro.core.api import CholeskyConfig
from repro.core.precision import assign_precision, tile_norms
from repro.core.tiling import to_tiles

from .calibrate import calibrate
from .db import TuningDB, default_db_path
from .search import TuneResult, search

DEFAULT_HW_PRESET = "gh200"

_default_hw: Optional[HardwareModel] = None
_process_db: Optional[TuningDB] = None


def set_default_hardware(hw: Union[HardwareModel, str, None]) -> None:
    """Install the model auto configs resolve against in this process
    (a calibrated :class:`HardwareModel`, a preset name, or None to
    restore the ``gh200`` preset default)."""
    global _default_hw
    _default_hw = HW[hw] if isinstance(hw, str) else hw


def _resolve_hw(hw: Union[HardwareModel, str, None],
                config: Optional[CholeskyConfig]) -> HardwareModel:
    if isinstance(hw, str):
        if hw not in HW:
            raise ValueError(f"unknown hardware preset {hw!r}; "
                             f"expected one of {tuple(HW)}")
        return HW[hw]
    if hw is not None:
        return hw
    if config is not None and config.hw is not None:
        return HW[config.hw]
    return _default_hw if _default_hw is not None else HW[DEFAULT_HW_PRESET]


def _db_fingerprint(hw: HardwareModel) -> str:
    return hw.fingerprint if hw.fingerprint else f"preset:{hw.name}"


def resolution_token(config: CholeskyConfig) -> str:
    """Identity of the hardware model :func:`resolve_config` would score
    ``config`` against right now.  ``repro.plan()`` folds this into its
    auto-config cache key so a later :func:`set_default_hardware` (e.g.
    installing a calibrated model) is not masked by a plan tuned for the
    previous model."""
    return _db_fingerprint(_resolve_hw(None, config))


def _process_tuning_db() -> TuningDB:
    """Lazy process-wide db: file-backed iff ``REPRO_TUNE_DB`` is set."""
    global _process_db
    if _process_db is None:
        _process_db = TuningDB(default_db_path())
    return _process_db


def clear_tuning_cache() -> None:
    """Drop the process-wide tuning db (tests / after recalibration)."""
    global _process_db
    _process_db = None


def _mxp_plans_by_tb(n: int, sample: np.ndarray, eps_target: float,
                     ladder: str, tbs_needed) -> dict:
    """Per-tile-size Higham-Mary precision plans from a representative
    matrix: the precision dimension of the search (paper §IV-C)."""
    sample = np.asarray(sample, dtype=np.float64)
    if sample.shape != (n, n):
        raise ValueError(f"sample matrix shape {sample.shape} does not "
                         f"match n={n}")
    plans = {}
    for tb in tbs_needed:
        norms, total = tile_norms(to_tiles(sample, tb))
        plans[tb] = assign_precision(norms, total, eps_target, ladder)
    return plans


def tune(n: int,
         config: CholeskyConfig | None = None,
         hw: Union[HardwareModel, str, None] = None,
         run_calibration: bool = False,
         db: TuningDB | None = None,
         sample: np.ndarray | None = None,
         eps_target: Optional[float] = None,
         use_db: bool = True) -> TuneResult:
    """Pick the schedule for this machine (or the given model) at size n.

    ``config`` pins any dimensions you have opinions about (see
    :func:`repro.tune.search.search`); the default searches everything —
    tile size, policy, slot budget, and (for ``ndev > 1``) the device
    grid ``(p, q)``.  ``run_calibration=True`` measures the live backend
    first (:func:`repro.tune.calibrate.calibrate`, including the
    device-to-device ``link_bw`` the multi-device simulator rides) and
    scores against the measured model instead of a datasheet preset.
    ``sample`` + ``eps_target`` add the mixed-precision dimension:
    per-tb Higham-Mary plans are computed from the sample's tile norms
    and scored exactly like everything else.  docs/tuning.md is the
    narrative version of this docstring.

    Returns the ranked result; ``result.config`` is ready for
    ``repro.plan(n, result.config)``.  Winners are memoized in ``db``
    (the process db by default) keyed by hardware fingerprint and
    ``(n, ndev, eps_target)``.
    """
    if run_calibration and hw is None:
        hw = calibrate()
    hw_model = _resolve_hw(hw, config)
    base = config if config is not None else CholeskyConfig(
        tb=0, policy="auto")
    if base.eps_target is not None:
        # fold a config-side accuracy level into the search's precision
        # dimension (the search attaches explicit per-tile plans instead)
        if eps_target is not None and eps_target != base.eps_target:
            raise ValueError("conflicting eps_target in config and tune()")
        eps_target = base.eps_target
        base = dataclasses.replace(base, eps_target=None)
    if eps_target is not None and base.plan is not None:
        raise ValueError("pass either eps_target (with a sample matrix) "
                         "or a config with an explicit plan, not both")

    plans_by_tb = None
    if eps_target is not None:
        if sample is None:
            raise ValueError(
                "eps_target precision plans depend on the matrix tile "
                "norms: pass a representative `sample` matrix to tune()")
        from .search import feasible_tbs
        tbs = ([base.tb] if base.tb > 0
               else feasible_tbs(n, hw_model, base.ndev))
        plans_by_tb = _mxp_plans_by_tb(n, sample, eps_target,
                                       base.ladder, tbs)

    result = search(n, hw_model, base, plans_by_tb=plans_by_tb,
                    eps_target=eps_target)
    if use_db:
        the_db = db if db is not None else _process_tuning_db()
        the_db.put(_db_fingerprint(hw_model), n, base.ndev, eps_target,
                   result.config, result.best.makespan,
                   hw_name=hw_model.name, hw_source=hw_model.source)
    return result


def resolve_config(n: int, config: CholeskyConfig,
                   hw: Union[HardwareModel, str, None] = None,
                   db: TuningDB | None = None) -> CholeskyConfig:
    """Resolve an auto config (``tb=0`` / ``policy="auto"``) to a
    concrete one — the hook ``repro.plan()`` calls.

    Pure simulation against the resolved hardware model (no calibration,
    no jit, no device work): deterministic and cheap enough for the
    planner path, with repeat calls served from the tuning db.
    """
    if not config.needs_tuning:
        return config
    hw_model = _resolve_hw(hw, config)
    the_db = db if db is not None else _process_tuning_db()
    fp = _db_fingerprint(hw_model)
    cached = the_db.get(fp, n, config.ndev, config.eps_target)
    if cached is not None and _matches_pins(cached, config, n):
        return cached
    result = tune(n, config, hw=hw_model, db=the_db)
    return result.config


def _matches_pins(cached: CholeskyConfig, requested: CholeskyConfig,
                  n: int) -> bool:
    """A db hit only counts if it honours the requested pinned axes
    (the db key does not encode them)."""
    if n % max(cached.tb, 1):
        return False
    if requested.tb > 0 and cached.tb != requested.tb:
        return False
    if requested.policy != "auto" and cached.policy != requested.policy:
        return False
    if (requested.cache_slots > 0
            and cached.cache_slots != requested.cache_slots):
        return False
    if requested.ladder != cached.ladder or requested.ndev != cached.ndev:
        return False
    if requested.grid is not None and cached.grid != requested.grid:
        # the grid is a searched dimension when open (None); a pinned
        # request must get exactly its layout back
        return False
    if (requested.lookahead is not None
            and cached.lookahead != requested.lookahead):
        # same contract for the pipeline depth: open (None) accepts any
        # searched winner, a pinned depth must be honoured exactly
        return False
    if (requested.host_slots > 0
            and cached.host_slots != requested.host_slots):
        # a pinned host-slab budget must come back exactly; 0 leaves the
        # spill tier to the search (engaged only when the full store
        # overflows the model's host memory)
        return False
    if requested.block != cached.block:
        # a non-default block changes the v4 candidates the cached search
        # saw (and a cached v4 winner with another block violates the
        # pin outright): re-search
        return False
    if requested.plan is not None and cached.plan != requested.plan:
        return False
    if (requested.backend, requested.compute_dtype, requested.use_pallas) \
            != (cached.backend, cached.compute_dtype, cached.use_pallas):
        return False
    return True


def default_config(n: int, ndev: int = 1,
                   target_nt: int = 32) -> CholeskyConfig:
    """The hand-picked pre-tuner baseline: V3, builder-default slots, and
    the tile size the repo's benchmarks reach for (a grid of ~32 tiles
    per side).  The tuner's acceptance bar — and the ``bench_tune``
    tuned-vs-default trajectory — is measured against this.
    """
    nt = target_nt
    while nt > 1 and n % nt:
        nt -= 1
    return CholeskyConfig(tb=n // nt, policy="v3", ndev=ndev)
