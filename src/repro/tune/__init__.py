"""repro.tune — hardware calibration + cost-model autotuner.

The paper's benchmarking campaign (tile size, cache capacity, OOC policy,
precision ladder — swept by hand per A100/H100/GH200 platform) is a
tuning problem the static scheduler makes automatable: every candidate
schedule has an exact, deterministic cost under a hardware model.  This
subsystem closes the loop in three layers:

  1. **calibration** (:mod:`repro.tune.calibrate`) — micro-benchmarks on
     the live backend produce a *measured* ``HardwareModel`` (per-kernel
     per-class rates, link bandwidth, launch/alloc overheads, device
     memory, hardware fingerprint);
  2. **search** (:mod:`repro.tune.search`) — enumerate every feasible
     ``(tb, policy, cache_slots, precision plan)`` candidate and rank
     them by exact event simulation;
  3. **persistence + planner integration** (:mod:`repro.tune.db`,
     :mod:`repro.tune.autotune`) — winners memoized by hardware
     fingerprint; ``repro.plan(n, CholeskyConfig(tb=0, policy="auto"))``
     resolves through :func:`resolve_config` transparently.

Quickstart::

    import repro
    from repro import tune

    # fully automatic: plan() tunes tb/policy/cache_slots for you
    solver = repro.plan(n, repro.CholeskyConfig(tb=0, policy="auto",
                                                hw="gh200")).compile()

    # explicit campaign against the measured machine
    model = tune.calibrate()                  # micro-benchmark this host
    result = tune.tune(n, hw=model)           # ranked candidate table
    solver = repro.plan(n, result.config).compile()
"""
from .autotune import (DEFAULT_HW_PRESET, clear_tuning_cache, default_config,
                       resolution_token, resolve_config,
                       set_default_hardware, tune)
from .calibrate import (calibrate, hardware_fingerprint, model_from_dict,
                        model_to_dict, refine_from_trace)
from .db import TuningDB, config_from_dict, config_to_dict, default_db_path
from .search import (Candidate, TuneResult, feasible_tbs, is_feasible,
                     score_config, search, slot_candidates)

__all__ = [
    "tune", "resolve_config", "resolution_token", "default_config",
    "set_default_hardware", "clear_tuning_cache", "DEFAULT_HW_PRESET",
    "calibrate", "hardware_fingerprint", "model_to_dict", "model_from_dict",
    "refine_from_trace",
    "TuningDB", "config_to_dict", "config_from_dict", "default_db_path",
    "search", "TuneResult", "Candidate", "feasible_tbs", "is_feasible",
    "slot_candidates", "score_config",
]
