"""Hardware calibration: micro-benchmarks -> a *measured* HardwareModel.

The datasheet presets in :data:`repro.core.analytics.HW` carry published
peaks; the simulator is only as predictive as those numbers are honest for
the backend actually running (Le Fèvre et al. make the same point for
A64FX Cholesky: measured kernel rates, not published ones, make a cost
model transferable).  This module times, on the live JAX backend:

  * tb x tb POTRF / TRSM / SYRK / GEMM kernels per precision class
    (the exact kernel fns the executors replay, so the measured rate
    includes the cast-through-class behaviour of the real pipeline);
  * host<->device transfer bandwidth (``jax.device_put`` up, host
    ``np.asarray`` readback down) at several transfer sizes, keeping the
    steady-state large-transfer rate;
  * device-to-device interconnect bandwidth (``link_bw``; measured when
    >= 2 devices are visible) — the default the multi-device broadcast
    model :func:`repro.core.analytics.simulate_multi` rides;
  * jit launch overhead and buffer-allocation overhead;
  * device memory capacity (``memory_stats()`` where the backend exposes
    it, a conservative fallback otherwise);
  * disk sequential read/write bandwidth (tmpfile probe on the spill
    tier's filesystem) and physical host RAM — the lanes/capacity the
    disk-tier simulation and the tuner's ``host_slots`` axis consume;

and returns a frozen :class:`HardwareModel` with ``source="measured"``
and a :func:`hardware_fingerprint` identity hash that keys the tuning
database: re-tuning on the same machine is a dict lookup, moving to a
different machine invalidates the cache automatically.

Everything runs in seconds at the default ``tb=256`` — small enough for
the CPU CI smoke leg, honest enough to rank schedule candidates.
"""
from __future__ import annotations

import hashlib
import os
import tempfile
import time

import numpy as np

from repro.core.analytics import GB, HardwareModel
from repro.core.precision import BYTES, LADDERS

# classes measured by default: every precision name any ladder can assign
_ALL_CLASSES = ("f64", "f32", "f16", "bf16", "f8e4m3", "f8e4m3s")

# fallback device-memory capacity when the backend reports none (CPU CI):
# deliberately small so OOC feasibility filtering stays exercised.
_FALLBACK_MEM_BYTES = 8 * GB

_TASK_FLOP_COUNT = {
    "gemm": lambda tb: 2 * tb**3,
    "syrk": lambda tb: tb**3,
    "trsm": lambda tb: tb**3,
    "potrf": lambda tb: tb**3 / 3.0,
}


def hardware_fingerprint() -> str:
    """Identity hash of the live backend (tuning-db cache key).

    Folds in everything that changes measured rates or the executor's
    numerics: platform, device kind and count, jax version, and the x64
    flag (with x64 off the f64 class degrades to f32 end to end).
    """
    import jax
    dev = jax.devices()[0]
    ident = "|".join([
        jax.default_backend(),
        getattr(dev, "device_kind", type(dev).__name__),
        str(jax.device_count()),
        jax.__version__,
        f"x64={bool(jax.config.jax_enable_x64)}",
    ])
    return hashlib.sha256(ident.encode()).hexdigest()[:12]


def _best_seconds(fn, repeats: int) -> float:
    """Min-of-repeats wall time of ``fn()`` (result blocked on)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return max(best, 1e-9)


def _class_dtype(cls_name: str):
    """jnp dtype a class's tiles are cast through on the live backend
    (the executor's `_jx_round` semantics: f64 degrades to f32 with x64
    off; every class casts back to the compute dtype for the kernel)."""
    import jax
    from repro.core.cholesky import _JNP_DTYPES
    import jax.numpy as jnp
    if cls_name == "f64" and not jax.config.jax_enable_x64:
        return jnp.float32
    return _JNP_DTYPES[cls_name]


def _measure_kernels(tb: int, classes, repeats: int) -> dict:
    """Time the executor's own kernel fns per (task, class) and return
    ``{task: {class: flop_rate}}``.

    The kernel runs exactly as the executor would: operands round-trip
    through the class dtype, the arithmetic runs in the compute dtype.
    So a "bf16-class GEMM" here is cast-to-bf16 + matmul — the honest
    rate of that class on *this* backend, which is what the simulator
    needs to rank schedules (not the MXU's marketing number).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.cholesky import _make_kernel_fns

    compute_dtype = (jnp.float64 if jax.config.jax_enable_x64
                     else jnp.float32)
    kf = _make_kernel_fns(use_pallas=False, interpret=True)
    rng = np.random.default_rng(0)
    spd = np.eye(tb) * (2.0 * tb)
    spd += rng.standard_normal((tb, tb)) @ rng.standard_normal((tb, tb)).T / tb
    c_host = jnp.asarray(spd, dtype=compute_dtype)
    l_host = jnp.asarray(np.linalg.cholesky(spd), dtype=compute_dtype)
    a_host = jnp.asarray(rng.standard_normal((tb, tb)), dtype=compute_dtype)
    b_host = jnp.asarray(rng.standard_normal((tb, tb)), dtype=compute_dtype)

    from repro.core.cholesky import _jx_round

    rates: dict = {task: {} for task in _TASK_FLOP_COUNT}
    for cls_name in classes:

        def through(x):
            # class round-trip: what LOAD does to every operand tile
            # (the scaled-FP8 class applies its per-tile amax scale
            # around the cast — _jx_round is the executor's own path)
            return _jx_round(x, cls_name, compute_dtype)

        jobs = {
            "gemm": jax.jit(lambda c, a, b: kf["gemm"](
                through(c), through(a), through(b))),
            "syrk": jax.jit(lambda c, a: kf["syrk"](through(c), through(a))),
            "trsm": jax.jit(lambda l, c: kf["trsm"](through(l), through(c))),
            "potrf": jax.jit(lambda c: kf["potrf"](through(c))),
        }
        args = {
            "gemm": (c_host, a_host, b_host),
            "syrk": (c_host, a_host),
            "trsm": (l_host, b_host),
            "potrf": (c_host,),
        }
        for task, fn in jobs.items():
            try:
                fn(*args[task]).block_until_ready()       # compile/warm
                dt = _best_seconds(lambda: fn(*args[task]), repeats)
            except Exception:
                # dtype unsupported by this backend's kernels: fall back
                # to the compute-dtype rate (what execution would do too)
                rates[task][cls_name] = rates[task].get(
                    "f64", _TASK_FLOP_COUNT[task](tb) / 1e-3)
                continue
            rates[task][cls_name] = _TASK_FLOP_COUNT[task](tb) / dt
    return rates


def _measure_fused(tb: int, classes, repeats: int,
                   r_tiles: int = 4, k_hist: int = 2) -> dict:
    """Time the fused column-step megakernel per class and return
    ``{"fused_column": {class: flop_rate}}``.

    One launch runs the whole column step (update wave + POTRF + row
    TRSMs with the epilogue cast fused in), so its rate is directly
    comparable to the sum of the unfused per-op rates — the simulator
    and :mod:`benchmarks.roofline` use exactly this comparison to decide
    whether ``fuse_columns`` wins on the calibrated backend.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.precision import LADDERS as _LADS
    from repro.kernels.fused_column import fused_column_step

    compute_dtype = (jnp.float64 if jax.config.jax_enable_x64
                     else jnp.float32)
    rng = np.random.default_rng(0)
    spd = np.eye(tb) * (2.0 * tb)
    spd += rng.standard_normal((tb, tb)) @ rng.standard_normal((tb, tb)).T / tb
    c_stack = jnp.asarray(
        np.stack([spd] + [rng.standard_normal((tb, tb))
                          for _ in range(r_tiles - 1)]), dtype=compute_dtype)
    hist = jnp.asarray(rng.standard_normal((r_tiles, k_hist, tb, tb)) / tb,
                       dtype=compute_dtype)
    bhist = hist[0]
    l_kk = jnp.zeros((tb, tb), dtype=compute_dtype)
    # FLOPs of the whole step: R*K tile GEMMs + POTRF + (R-1) TRSMs
    flops = (r_tiles * k_hist * 2 * tb**3 + tb**3 / 3.0
             + (r_tiles - 1) * tb**3)

    rates: dict = {}
    for cls_name in classes:
        # the class's position in whichever ladder carries it (the
        # epilogue is ladder-indexed)
        lad = next((l for l in _LADS.values() if cls_name in l), None)
        if lad is None:
            continue
        cls_ids = jnp.full((r_tiles,), lad.index(cls_name), dtype=jnp.int32)

        def run():
            return fused_column_step(c_stack, hist, bhist, l_kk, cls_ids,
                                     ladder=lad, with_diag=True,
                                     interpret=True)
        try:
            run().block_until_ready()                      # compile/warm
            dt = _best_seconds(run, repeats)
        except Exception:
            continue
        rates[cls_name] = flops / dt
    return {"fused_column": rates} if rates else {}


def _measure_bandwidth(sizes_mb, repeats: int) -> tuple[float, float]:
    """Steady-state host->device / device->host bytes per second."""
    import jax
    import jax.numpy as jnp
    dev = jax.devices()[0]
    h2d = d2h = 0.0
    for mb in sizes_mb:
        nbytes = int(mb * 1e6)
        host = np.zeros(nbytes // 4, dtype=np.float32)
        dt_up = _best_seconds(lambda: jax.device_put(host, dev), repeats)
        x = jax.device_put(host, dev)
        x.block_until_ready()
        dt_down = _best_seconds(lambda: np.asarray(x), repeats)
        # keep the best (largest-transfer) rate: small transfers are
        # latency-bound and would understate the link
        h2d = max(h2d, nbytes / dt_up)
        d2h = max(d2h, nbytes / dt_down)
    return h2d, d2h


def _measure_link_bandwidth(sizes_mb, repeats: int) -> float:
    """Steady-state device-to-device bytes/s (``jax.device_put`` between
    the first two visible devices) — the interconnect the multi-device
    broadcasts ride.  Returns 0.0 when fewer than two devices are
    visible (``simulate_multi`` then falls back to ``h2d_bw``)."""
    import jax
    devs = jax.devices()
    if len(devs) < 2:
        return 0.0
    best = 0.0
    for mb in sizes_mb:
        nbytes = int(mb * 1e6)
        x = jax.device_put(np.zeros(nbytes // 4, dtype=np.float32), devs[0])
        x.block_until_ready()
        dt = _best_seconds(lambda: jax.device_put(x, devs[1]), repeats)
        best = max(best, nbytes / dt)
    return best


def _measure_overheads(repeats: int) -> tuple[float, float]:
    """(jit launch overhead, buffer alloc overhead) in seconds/event."""
    import jax
    import jax.numpy as jnp
    tiny = jnp.zeros((8, 8))
    f = jax.jit(lambda x: x + 1.0)
    f(tiny).block_until_ready()          # compile
    n = 50
    t0 = time.perf_counter()
    y = tiny
    for _ in range(n):
        y = f(y)
    y.block_until_ready()
    launch = max((time.perf_counter() - t0) / n, 1e-8)
    alloc = _best_seconds(lambda: jnp.zeros((256, 256)), repeats)
    return launch, alloc


def _measure_disk_bandwidth(sizes_mb, repeats: int,
                            directory: str | None = None
                            ) -> tuple[float, float]:
    """Sequential (read_bw, write_bw) bytes/s of the filesystem holding
    the spill tier's tile store.

    Writes fsync to make the number honest for SPILL durability; reads
    go through the page cache (so the measured read rate is the *replay's*
    effective rate — a FETCH of a recently spilled tile is usually warm —
    not the device's cold-read floor).  ``directory`` targets the
    filesystem the :class:`~repro.core.spill.DiskTileStore` will live on
    (default: the system tmpdir)."""
    read_bw = write_bw = 0.0
    with tempfile.TemporaryDirectory(dir=directory) as td:
        path = os.path.join(td, "disk_probe.bin")
        for mb in sizes_mb:
            nbytes = int(mb * 1e6)
            buf = bytes(nbytes)

            def wr():
                with open(path, "wb") as f:
                    f.write(buf)
                    f.flush()
                    os.fsync(f.fileno())

            def rd():
                with open(path, "rb") as f:
                    return f.read()

            write_bw = max(write_bw, nbytes / _best_seconds(wr, repeats))
            read_bw = max(read_bw, nbytes / _best_seconds(rd, repeats))
    return read_bw, write_bw


def _host_mem_bytes() -> float:
    """Physical host RAM (``os.sysconf``); 0.0 where unavailable —
    the search then treats host memory as unbounded."""
    try:
        return float(os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES"))
    except (AttributeError, OSError, ValueError):
        return 0.0


def _device_mem_bytes() -> float:
    """Device memory capacity, from the backend when it reports one."""
    import jax
    try:
        stats = jax.devices()[0].memory_stats()
        if stats and stats.get("bytes_limit", 0) > 0:
            return float(stats["bytes_limit"])
    except Exception:
        pass
    return float(_FALLBACK_MEM_BYTES)


def refine_from_trace(trace, base: HardwareModel | None = None,
                      name: str | None = None) -> HardwareModel:
    """Refit a :class:`HardwareModel` from a *measured* execution trace.

    ``trace`` is a :class:`repro.obs.TraceRecorder` filled by a traced
    ``OOCSolver.factor(a, trace=...)`` (its ``meta`` must carry ``tb``).
    Per-op fenced spans are the honest record of what this machine did
    on the *actual* factorization ops — better calibration data than any
    micro-benchmark, because tile shapes, precision round-trips, and
    dispatch overhead are all the real pipeline's:

    * compute spans refit ``kernel_flops[task][class]`` as
      ``task_flops(tb) / median(duration)``;
    * LOAD/STORE spans refit ``h2d_bw``/``d2h_bw`` as the median of
      ``bytes / duration``; RECV spans refit ``link_bw``; FETCH/SPILL
      spans refit the disk bandwidths;
    * everything the trace did not exercise keeps ``base``'s value
      (default: the ``a100-pcie`` datasheet preset).

    The returned model is the drift feedback loop closed: re-simulating
    the same schedule with it reduces the total predicted-vs-measured
    error of :func:`repro.obs.drift_report` (docs/tuning.md).
    """
    import dataclasses
    import statistics

    from repro.core.analytics import HW

    spans = trace.spans
    if not spans:
        raise ValueError("refine_from trace is empty: run "
                         "factor(..., trace=recorder) first")
    meta = getattr(trace, "meta", {}) or {}
    tb = meta.get("tb")
    if not tb:
        raise ValueError(
            "trace.meta carries no 'tb': refine from a trace recorded by "
            "OOCSolver.factor(a, trace=...) (which stamps run metadata), "
            "or set trace.meta['tb'] yourself")
    if base is None:
        base = HW["a100-pcie"]

    by_task: dict = {}
    bw: dict = {"load": [], "store": [], "recv": [], "fetch": [], "spill": []}
    for s in spans:
        dur = s.duration_s
        if dur <= 0:
            continue
        if s.kind in _TASK_FLOP_COUNT:
            by_task.setdefault((s.kind, s.cls or "f64"), []).append(dur)
        elif s.kind in bw and s.bytes > 0:
            bw[s.kind].append(s.bytes / dur)
    if not by_task and not any(bw.values()):
        raise ValueError("trace contains no compute or transfer spans to "
                         "refine from")

    kernel_flops = {task: dict(per)
                    for task, per in (base.kernel_flops or {}).items()}
    for (task, cls_name), durs in by_task.items():
        rate = _TASK_FLOP_COUNT[task](tb) / statistics.median(durs)
        kernel_flops.setdefault(task, {})[cls_name] = rate
    # class peaks follow the measured GEMM rates (the dominant kernel),
    # exactly as the micro-benchmark calibration does
    flops = dict(base.flops)
    flops.update(kernel_flops.get("gemm", {}))

    def med(rates, fallback):
        return statistics.median(rates) if rates else fallback

    return dataclasses.replace(
        base,
        name=name or f"refined-{base.name}",
        flops=flops,
        kernel_flops=kernel_flops,
        h2d_bw=med(bw["load"], base.h2d_bw),
        d2h_bw=med(bw["store"], base.d2h_bw),
        link_bw=med(bw["recv"], base.link_bw),
        disk_read_bw=med(bw["fetch"], base.disk_read_bw),
        disk_write_bw=med(bw["spill"], base.disk_write_bw),
        source="measured",
        fingerprint=hardware_fingerprint(),
    )


def calibrate(tb: int = 256,
              classes=None,
              repeats: int = 3,
              transfer_sizes_mb=(1, 8, 32),
              mem_bytes: float | None = None,
              name: str | None = None,
              disk_dir: str | None = None,
              refine_from=None,
              base: HardwareModel | None = None) -> HardwareModel:
    """Measure the live backend and return a ``source="measured"`` model.

    The result plugs into everything the datasheet presets do —
    ``simulate``/``simulate_multi``, the tuner's candidate search — but
    with per-kernel, per-class rates measured through the executor's own
    kernel fns, real host-link *and* (whenever at least two devices are
    visible) device-to-device interconnect bandwidth — ``link_bw``,
    which ``simulate_multi`` then uses by default for the multi-device
    broadcasts — and the device's actual memory capacity (``mem_bytes``
    overrides detection, e.g. to model a smaller slot budget than the
    hardware has).

    ``refine_from``: instead of running micro-benchmarks, refit the
    model from a measured execution trace
    (:class:`repro.obs.TraceRecorder`) — see :func:`refine_from_trace`;
    ``base`` seeds the un-exercised fields (default ``a100-pcie``).
    """
    if refine_from is not None:
        return refine_from_trace(refine_from, base=base, name=name)
    import jax
    classes = tuple(classes) if classes is not None else _ALL_CLASSES
    for c in classes:
        if c not in BYTES:
            raise ValueError(f"unknown precision class {c!r}; "
                             f"expected a subset of {_ALL_CLASSES}")
    kernel_flops = _measure_kernels(tb, classes, repeats)
    # the fused column-step megakernel, timed as one launch: rates land
    # under kernel_flops["fused_column"] next to the per-op kernels, so
    # fused-vs-unfused comparisons ride the same measured model
    kernel_flops.update(_measure_fused(tb, classes, repeats))
    h2d_bw, d2h_bw = _measure_bandwidth(transfer_sizes_mb, repeats)
    link_bw = _measure_link_bandwidth(transfer_sizes_mb, repeats)
    disk_read_bw, disk_write_bw = _measure_disk_bandwidth(
        transfer_sizes_mb, repeats, directory=disk_dir)
    launch, alloc = _measure_overheads(repeats)
    fp = hardware_fingerprint()
    dev = jax.devices()[0]
    if name is None:
        kind = getattr(dev, "device_kind", jax.default_backend())
        name = f"measured-{str(kind).lower().replace(' ', '-')}-{fp[:6]}"
    return HardwareModel(
        name=name,
        # class peaks = the measured GEMM rate (the dominant kernel);
        # per-kernel detail rides in kernel_flops for the simulator
        flops={c: kernel_flops["gemm"][c] for c in classes},
        h2d_bw=h2d_bw,
        d2h_bw=d2h_bw,
        link_bw=link_bw,
        alloc_overhead=alloc,
        launch_overhead=launch,
        mem_bytes=float(mem_bytes) if mem_bytes else _device_mem_bytes(),
        source="measured",
        fingerprint=fp,
        kernel_flops=kernel_flops,
        disk_read_bw=disk_read_bw,
        disk_write_bw=disk_write_bw,
        host_mem_bytes=_host_mem_bytes(),
    )


def model_to_dict(hw: HardwareModel) -> dict:
    """JSON-serializable form of a model (see :func:`model_from_dict`)."""
    import dataclasses
    return dataclasses.asdict(hw)


def model_from_dict(d: dict) -> HardwareModel:
    return HardwareModel(**d)
