"""Candidate search: enumerate feasible configs, score by exact simulation.

Because the schedule is static, every candidate ``(tb, policy,
cache_slots, precision plan, ndev)`` has an *exact* deterministic cost
under a hardware model — :func:`repro.core.analytics.simulate` /
:func:`simulate_multi` replay the op stream event by event.  The search
is therefore a plain enumerate-build-simulate loop; no noisy on-device
trials, no search heuristics, and the same code path scores datasheet
presets (CPU CI) and calibrated measured models.

Feasibility is enforced *before* scoring, mirroring exactly what the
builders/executors would reject later:

  * ``tb | n`` (the tile grid must cover the matrix);
  * per-policy slot minimums (:func:`repro.core.schedule.min_cache_slots`);
  * the OOC device-memory cap: ``(cache_slots + panel slots) * tb^2 * 8
    <= hw.mem_bytes`` — at large ``n`` this is the constraint that rules
    out cache-everything configs and forces real policy selection.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.analytics import HW, HardwareModel, simulate, simulate_multi
from repro.core.api import _DEFAULT_BLOCK, CholeskyConfig
from repro.core.precision import PrecisionPlan, uniform_plan
from repro.core.schedule import (build_multidevice_schedule, build_schedule,
                                 default_cache_slots, min_cache_slots)
from repro.core.tiling import TileLayout

# lookahead depths worth scoring (ndev > 1): 0 is today's column loop,
# deeper pipelines trade panel slots for overlap; past 2 the emitter's
# extra in-flight panels stop changing the simulated makespan on every
# preset we model (the panel critical path is already hidden)
_LOOKAHEADS = (0, 1, 2)

# search-space bounds: nt below 2 is in-core (no schedule to tune), nt
# above NT_MAX makes candidate *scoring* itself the bottleneck (schedule
# construction is O(nt^3) ops) without changing the ranking — past ~48
# tiles per side the per-op overheads are amortized and bigger grids only
# move more bytes.
NT_MIN = 2
NT_MAX = 48
TB_MIN = 8

_SINGLE_POLICIES = ("sync", "async", "v1", "v2", "v3", "v4")
_MULTI_POLICIES = ("sync", "v1", "v2", "v3")
_POLICY_RANK = {p: i for i, p in enumerate(_SINGLE_POLICIES)}


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One scored point of the search space."""
    config: CholeskyConfig
    makespan: float
    tflops: float
    loads_bytes: int
    stores_bytes: int
    link_bytes: int = 0          # interconnect volume (ndev > 1)
    footprint_bytes: int = 0     # device slot-buffer bytes the config needs
    fetch_bytes: int = 0         # disk lane volume (host_slots > 0)
    spill_bytes: int = 0

    def row(self) -> dict:
        """Flat machine-readable record (bench JSON / TuneResult table)."""
        c = self.config
        return {
            "tb": c.tb, "policy": c.policy, "cache_slots": c.cache_slots,
            "ndev": c.ndev,
            "grid": list(c.grid) if c.grid else [c.ndev, 1],
            "lookahead": c.lookahead or 0,
            "host_slots": c.host_slots,
            "makespan_s": self.makespan,
            "tflops": self.tflops, "loads_bytes": self.loads_bytes,
            "stores_bytes": self.stores_bytes,
            "link_bytes": self.link_bytes,
            "footprint_bytes": self.footprint_bytes,
            "fetch_bytes": self.fetch_bytes,
            "spill_bytes": self.spill_bytes,
        }


@dataclasses.dataclass
class TuneResult:
    """Ranked outcome of one search: ``config`` is the winner, ``table``
    the full predicted makespan/volume comparison."""
    n: int
    ndev: int
    hw: HardwareModel
    candidates: list        # Candidate, ranked best-first
    eps_target: Optional[float] = None

    @property
    def config(self) -> CholeskyConfig:
        return self.candidates[0].config

    @property
    def best(self) -> Candidate:
        return self.candidates[0]

    def table(self) -> list[dict]:
        return [c.row() for c in self.candidates]


def feasible_tbs(n: int, hw: HardwareModel, ndev: int = 1,
                 policies=_SINGLE_POLICIES) -> list[int]:
    """Tile sizes whose grid covers ``n`` and whose *minimum* working set
    fits the device (largest tb first: fewer, bigger tiles are the cheap
    end of the search)."""
    out = []
    for nt in range(max(NT_MIN, ndev), NT_MAX + 1):
        if n % nt:
            continue
        tb = n // nt
        if tb < TB_MIN:
            break
        reserve = TileLayout(n, tb).panel_slots(0) if ndev > 1 else 0
        least = min(min_cache_slots(p) for p in policies)
        if hw.max_cache_slots(tb, reserve) >= least:
            out.append(tb)
    return out


def slot_candidates(policy: str, nt: int, tb: int, hw: HardwareModel,
                    ndev: int = 1, block: tuple = (4, 4),
                    lookahead: int = 0) -> list[int]:
    """Feasible cache-slot budgets worth scoring for one (policy, tb).

    Three probes bound the interesting range: the policy minimum (the
    thrash-iest feasible point), the builder default, and the
    memory-capped maximum (cache as much as the device holds).  Slot
    counts only change the op stream for the cache-table policies; the
    fixed-slot policies get their single minimum.  ``lookahead`` lifts
    both the minimum (one extra pinned slot per depth) and the panel
    reserve (one extra ``nt``-slot bank per in-flight panel).
    """
    reserve = (TileLayout(nt * tb, tb).panel_slots(lookahead)
               if ndev > 1 else 0)
    cap = hw.max_cache_slots(tb, reserve)
    mn = min_cache_slots(policy, block, lookahead)
    if cap < mn:
        return []
    if policy in ("sync", "async", "v1"):
        return [mn]
    default = default_cache_slots(policy, nt, block, multidevice=ndev > 1,
                                  lookahead=lookahead)
    # nt*(nt+1)//2 + 1 slots hold every lower tile at once: beyond that,
    # extra slots cannot change a single cache decision
    useful_max = min(cap, nt * (nt + 1) // 2 + 1 + lookahead)
    return sorted({max(s, mn) for s in (mn, min(default, cap), useful_max)})


def host_slot_candidates(nt: int, tb: int, hw: HardwareModel) -> list[int]:
    """Host-slab budgets worth scoring for one tile grid.

    ``[0]`` (host-resident store, no spill tier) whenever the full
    ``[nt, nt]`` tile store fits ``host_mem_bytes`` (or the capacity is
    unknown); once it overflows, spilling is mandatory and two probes
    bound the interesting range: a lean column working set (``nt + 2``
    slabs — the panel streams through, updates thrash) and the
    memory-capped maximum (as host-resident as the machine allows).
    Empty when not even one slab fits — no feasible config at this tb.
    """
    store_bytes = 8 * (nt * tb) ** 2
    if hw.host_mem_bytes <= 0 or store_bytes <= hw.host_mem_bytes:
        return [0]
    cap = hw.max_host_slots(tb)
    if cap < 1:
        return []
    # nt*(nt+1)//2 slabs hold every lower tile: past that, extra slabs
    # cannot remove a single FETCH
    return sorted({min(nt + 2, cap), min(cap, nt * (nt + 1) // 2)})


def is_feasible(n: int, config: CholeskyConfig, hw: HardwareModel) -> bool:
    """The exact predicate the search promises of every returned config."""
    if config.tb < 1 or n % config.tb:
        return False
    nt = n // config.tb
    la = config.lookahead or 0
    if la >= nt:
        return False
    if config.cache_slots < min_cache_slots(config.policy, config.block, la):
        return False
    if config.host_slots > 0:
        # eager config validation already rejects host_slots with
        # lookahead; here only the host-memory cap can fail
        if config.host_slots > hw.max_host_slots(config.tb):
            return False
    elif hw.host_mem_bytes > 0 and 8 * n * n > hw.host_mem_bytes:
        # no spill tier and the full tile store overflows host memory
        return False
    reserve = (TileLayout(n, config.tb).panel_slots(la)
               if config.ndev > 1 else 0)
    return config.cache_slots <= hw.max_cache_slots(config.tb, reserve)


def _score(n, tb, policy, slots, pplan, ndev, hw, base: CholeskyConfig,
           grid=None, lookahead=0, host_slots=0):
    nt = n // tb
    if ndev > 1:
        msched = build_multidevice_schedule(nt, tb, ndev, policy, slots,
                                            pplan, grid=grid,
                                            lookahead=lookahead,
                                            host_slots=host_slots)
        r = simulate_multi(msched, hw)
        loads, stores = msched.loads_bytes(), msched.stores_bytes()
        link = r.link_bytes
        nslots = max(msched.stream_nslots(d) for d in range(ndev))
    else:
        sched = build_schedule(nt, tb, policy, slots, pplan,
                               block=base.block, host_slots=host_slots)
        r = simulate(sched, hw)
        loads, stores = sched.loads_bytes(), sched.stores_bytes()
        link = 0
        nslots = slots
    cfg = dataclasses.replace(
        base, tb=tb, policy=policy, cache_slots=slots, ndev=ndev,
        grid=grid if ndev > 1 else None,
        # the winner pins the searched depth (0 included) so a db
        # round-trip replays the same schedule; ndev=1 has no pipeline
        lookahead=lookahead if ndev > 1 else None,
        host_slots=host_slots,
        # a custom v4 block must not ride along into non-v4 candidates
        block=base.block if policy == "v4" else _DEFAULT_BLOCK,
        plan=pplan if pplan is not None and not _is_uniform_f64(pplan)
        else base.plan)
    return Candidate(config=cfg, makespan=r.makespan, tflops=r.tflops,
                     loads_bytes=loads, stores_bytes=stores,
                     link_bytes=link,
                     footprint_bytes=nslots * tb * tb * 8,
                     fetch_bytes=r.fetch_bytes, spill_bytes=r.spill_bytes)


def _is_uniform_f64(pplan: PrecisionPlan) -> bool:
    return bool((pplan.classes == 0).all())


def score_config(n: int, config: CholeskyConfig,
                 hw: HardwareModel) -> Candidate:
    """Exact simulated cost of one *pinned* config, as the builders would
    run it (``cache_slots=0`` resolves to the builder default) — no
    feasibility filtering.  This is the honest baseline for
    tuned-vs-default comparisons: a hand-picked config is scored exactly
    as written even where the tuner would have rejected it (e.g. a slot
    budget overflowing ``mem_bytes``)."""
    if config.tb < 1 or n % config.tb:
        raise ValueError(f"tb={config.tb} does not tile n={n}")
    nt = n // config.tb
    slots = config.cache_slots or default_cache_slots(
        config.policy, nt, config.block, multidevice=config.ndev > 1,
        lookahead=config.lookahead or 0)
    pplan = config.plan or uniform_plan(nt, "f64", config.ladder)
    return _score(n, config.tb, config.policy, slots, pplan, config.ndev,
                  hw, config, grid=config.grid,
                  lookahead=config.lookahead or 0,
                  host_slots=config.host_slots)


def search(n: int,
           hw: HardwareModel,
           config: CholeskyConfig | None = None,
           plans_by_tb: dict | None = None,
           eps_target: Optional[float] = None) -> TuneResult:
    """Enumerate + score every feasible candidate; return them ranked.

    ``config`` pins the non-searched dimensions and declares which are
    open: ``tb=0`` searches tile sizes, ``policy="auto"`` searches
    policies, ``cache_slots=0`` searches slot budgets, and (for
    ``ndev > 1``) ``grid=None`` searches every ``(p, q)`` factorization
    of ``ndev`` while ``lookahead=None`` searches pipeline depths
    ``{0, 1, 2}``; a concrete value freezes that axis.  The disk tier is
    its own axis: ``host_slots=0`` scores host-resident candidates
    unless the full tile store overflows ``hw.host_mem_bytes``, in which
    case spill budgets are probed (:func:`host_slot_candidates`); a
    pinned ``host_slots > 0`` is honoured exactly.  ``plans_by_tb``
    optionally maps tile size -> :class:`PrecisionPlan` (built from a
    representative matrix by :func:`repro.tune.tune`) to score
    mixed-precision candidates; absent entries score uniform f64.

    Deterministic by construction: candidates are scored by an exact
    event simulation and ranked by ``(makespan, fewer bytes, policy
    order, larger tb, fewer slots, shallower lookahead, grid)`` — equal
    inputs always return the identical ranking.
    """
    base = config if config is not None else CholeskyConfig(
        tb=0, policy="auto")
    if base.hw is not None and HW.get(base.hw) is not hw:
        # scored against a different model than the config names (e.g. a
        # calibrated one): drop the tag so the returned configs validate
        # against the model that actually ranked them
        base = dataclasses.replace(base, hw=None)
    ndev = base.ndev
    policy_space = _MULTI_POLICIES if ndev > 1 else _SINGLE_POLICIES
    policies = (policy_space if base.policy == "auto"
                else (base.policy,))
    for p in policies:
        if p not in policy_space:
            raise ValueError(f"policy {p!r} unsupported for ndev={ndev}")

    if base.tb > 0:
        if n % base.tb:
            raise ValueError(f"tb={base.tb} does not divide n={n}")
        tbs = [base.tb]
    else:
        if base.plan is not None:
            # an explicit per-tile plan fixes the grid to its nt
            if n % base.plan.nt:
                raise ValueError(
                    f"explicit precision plan has nt={base.plan.nt}, "
                    f"which does not tile n={n}")
            tbs = [n // base.plan.nt]
        else:
            tbs = feasible_tbs(n, hw, ndev, policies)
    if not tbs:
        raise ValueError(
            f"no feasible tile size for n={n} on {hw.name} "
            f"(mem_bytes={hw.mem_bytes:.3g}): every divisor in "
            f"nt=[{NT_MIN}, {NT_MAX}] either leaves tb < {TB_MIN} or "
            f"overflows device memory at the policy minimum slot count")

    if ndev == 1:
        grids = [None]
    elif base.grid is not None:
        grids = [base.grid]
    else:
        # the grid dimension: every (p, q) factorization of ndev, the 1D
        # tile-row layout (ndev, 1) among them
        grids = [(d, ndev // d) for d in range(1, ndev + 1) if ndev % d == 0]

    if ndev == 1:
        lookaheads = [0]
    elif base.lookahead is not None:
        lookaheads = [base.lookahead]
    else:
        lookaheads = list(_LOOKAHEADS)

    candidates = []
    for tb in tbs:
        nt = n // tb
        if base.plan is not None and base.plan.nt == nt:
            pplan = base.plan
        elif plans_by_tb and tb in plans_by_tb:
            pplan = plans_by_tb[tb]
        else:
            pplan = uniform_plan(nt, "f64", base.ladder)
        if base.host_slots > 0:
            hs_opts = ([base.host_slots]
                       if base.host_slots <= hw.max_host_slots(tb) else [])
        else:
            # the spill tier engages only when the full tile store
            # overflows the model's host memory (otherwise [0])
            hs_opts = host_slot_candidates(nt, tb, hw)
        for policy in policies:
            for la in lookaheads:
                if la >= nt:
                    continue        # the builder rejects lookahead >= nt
                if base.cache_slots > 0:
                    # primitive feasibility probe: constructing a config
                    # here would re-run eager validation and *raise* on
                    # the very combinations this filter exists to skip
                    # (e.g. a pinned budget below v4's minimum while
                    # policy="auto")
                    blk = base.block if policy == "v4" else _DEFAULT_BLOCK
                    reserve = (TileLayout(n, tb).panel_slots(la)
                               if ndev > 1 else 0)
                    ok = (base.cache_slots
                          >= min_cache_slots(policy, blk, la)
                          and base.cache_slots
                          <= hw.max_cache_slots(tb, reserve))
                    slot_opts = [base.cache_slots] if ok else []
                else:
                    slot_opts = slot_candidates(policy, nt, tb, hw, ndev,
                                                base.block, lookahead=la)
                for hs in hs_opts:
                    if hs > 0 and la > 0:
                        continue    # spill post-pass excludes pipelining
                    for slots in slot_opts:
                        for grid in grids:
                            candidates.append(
                                _score(n, tb, policy, slots, pplan, ndev,
                                       hw, base, grid=grid, lookahead=la,
                                       host_slots=hs))
    if not candidates:
        raise ValueError(
            f"no feasible (policy, cache_slots) candidate for n={n} on "
            f"{hw.name}: the pinned dimensions of {base} violate the "
            f"slot minimums or the device-memory cap")
    candidates.sort(key=lambda c: (
        c.makespan,
        c.loads_bytes + c.stores_bytes + c.link_bytes
        + c.fetch_bytes + c.spill_bytes,
        _POLICY_RANK[c.config.policy],
        -c.config.tb,
        c.config.cache_slots,
        c.config.lookahead or 0,     # shallower pipeline on ties
        c.config.host_slots,         # leaner host tier on ties
        c.config.grid or (c.config.ndev, 1),
    ))
    return TuneResult(n=n, ndev=ndev, hw=hw, candidates=candidates,
                      eps_target=eps_target)
