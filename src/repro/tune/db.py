"""Persistent tuning database: ``(fingerprint, n, ndev, eps_target)`` ->
winning config.

The search is deterministic but not free (it builds and simulates dozens
of op streams), so winners are memoized.  The key is the hardware
fingerprint — :func:`repro.tune.calibrate.hardware_fingerprint` for
measured models, ``"preset:<name>"`` for datasheet presets — plus the
problem shape; moving the db file to a different machine invalidates
nothing by accident and hits nothing by accident.

Two storage modes:

  * ``TuningDB(path)`` — a human-readable JSON file, written atomically
    (tmp file + rename) so concurrent readers never see a torn write;
  * ``TuningDB(None)`` — in-memory only.  This is the default inside
    ``repro.plan()``: auto-config resolution stays instant within a
    process and hermetic across them, unless the user opts into a file
    via the ``REPRO_TUNE_DB`` environment variable.

Records store the full resolved config (including an explicit per-tile
precision plan, serialized tile-class matrix and all) plus the predicted
makespan and the model's name/source for provenance.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Optional

import numpy as np

from repro.core.analytics import HW
from repro.core.api import CholeskyConfig
from repro.core.precision import PrecisionPlan

ENV_DB_PATH = "REPRO_TUNE_DB"
_SCHEMA = 1


def config_to_dict(config: CholeskyConfig) -> dict:
    """JSON-serializable form of a config (round-trips through
    :func:`config_from_dict`)."""
    d = dataclasses.asdict(config)
    d["block"] = list(config.block)
    if config.grid is not None:
        d["grid"] = list(config.grid)
    if config.plan is not None:
        d["plan"] = {
            "classes": config.plan.classes.tolist(),
            "ladder": list(config.plan.ladder),
            "eps_target": config.plan.eps_target,
        }
    if config.compute_dtype is not None:
        d["compute_dtype"] = np.dtype(config.compute_dtype).name
    return d


def config_from_dict(d: dict) -> CholeskyConfig:
    d = dict(d)
    d["block"] = tuple(d.get("block", (4, 4)))
    if d.get("grid") is not None:
        d["grid"] = tuple(d["grid"])
    if d.get("plan") is not None:
        p = d["plan"]
        d["plan"] = PrecisionPlan(
            classes=np.asarray(p["classes"], dtype=np.int8),
            ladder=tuple(p["ladder"]),
            eps_target=p["eps_target"])
    if d.get("compute_dtype") is not None:
        d["compute_dtype"] = np.dtype(d["compute_dtype"])
    if d.get("hw") is not None and d["hw"] not in HW:
        # a measured model registered in some other process: the rates
        # are gone, only the choice survives — drop the dangling tag
        d["hw"] = None
    return CholeskyConfig(**d)


def default_db_path() -> Optional[str]:
    """File path from ``REPRO_TUNE_DB`` (None = stay in-memory)."""
    return os.environ.get(ENV_DB_PATH) or None


class TuningDB:
    """Tiny persistent (or in-memory) map of tuning winners."""

    def __init__(self, path: Optional[str] = None):
        self.path = os.path.expanduser(path) if path else None
        self._mem: dict[str, dict] = {}
        if self.path and os.path.exists(self.path):
            self._mem = self._read()

    @staticmethod
    def key(fingerprint: str, n: int, ndev: int,
            eps_target: Optional[float]) -> str:
        return f"{fingerprint}|n={n}|ndev={ndev}|eps={eps_target}"

    def _read(self) -> dict:
        try:
            with open(self.path) as f:
                blob = json.load(f)
        except (json.JSONDecodeError, OSError):
            return {}
        if blob.get("schema") != _SCHEMA:
            return {}
        return blob.get("records", {})

    def _write(self) -> None:
        if not self.path:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d or ".", prefix=".tune-db-")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump({"schema": _SCHEMA, "records": self._mem}, f,
                          indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            os.unlink(tmp)
            raise

    def get(self, fingerprint: str, n: int, ndev: int,
            eps_target: Optional[float]) -> Optional[CholeskyConfig]:
        rec = self._mem.get(self.key(fingerprint, n, ndev, eps_target))
        return None if rec is None else config_from_dict(rec["config"])

    def get_record(self, fingerprint: str, n: int, ndev: int,
                   eps_target: Optional[float]) -> Optional[dict]:
        return self._mem.get(self.key(fingerprint, n, ndev, eps_target))

    def put(self, fingerprint: str, n: int, ndev: int,
            eps_target: Optional[float], config: CholeskyConfig,
            predicted_makespan: float, hw_name: str = "",
            hw_source: str = "") -> None:
        self._mem[self.key(fingerprint, n, ndev, eps_target)] = {
            "config": config_to_dict(config),
            "predicted_makespan_s": predicted_makespan,
            "hw_name": hw_name,
            "hw_source": hw_source,
        }
        self._write()

    def __len__(self) -> int:
        return len(self._mem)

    def clear(self) -> None:
        self._mem.clear()
        self._write()
