from .manager import CheckpointManager
from .restart import (JournaledTileStore, RestartableFactorization,
                      TileJournal)

__all__ = ["CheckpointManager", "JournaledTileStore",
           "RestartableFactorization", "TileJournal"]
