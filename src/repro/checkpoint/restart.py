"""Restartable disk-tier factorization over the checkpoint manager.

A disk-scale factorization runs for hours; this module makes the NumPy
replay of a spill schedule resumable from the latest checkpoint with a
**bit-identical** final factor.  Three pieces compose:

* the static spill schedule (``host_slots > 0``): every host-tier
  residency decision is in the op stream, so the bounded host cache is
  *reconstructible* at any op index from the schedule alone
  (:func:`repro.core.spill.host_residency_at`) — a checkpoint never
  saves the host slabs, it flushes them to disk and re-fetches on
  resume;
* the repaired :class:`~repro.checkpoint.manager.CheckpointManager`:
  at column boundaries the runner saves the device slot buffer plus
  ``{digest, op_index, column}`` — the digest keys the checkpoint to
  the exact schedule, so resuming under a different schedule fails loudly
  instead of silently corrupting the factor;
* a :class:`TileJournal` undo log: the replay *keeps mutating the disk
  store between checkpoints* (SPILLs of partial accumulators), and tile
  updates are not idempotent — resuming from checkpoint ``C`` after a
  mid-column kill must first roll the store back to its state at ``C``.
  Every first overwrite of a tile since the last checkpoint journals the
  old bytes; on resume the journal of the restored checkpoint's epoch is
  rolled back before replay continues.

Crash-window audit (kill at any point):

* during post-checkpoint replay — restore ``C``, roll back epoch-``C``
  journal entries, continue from ``C``'s op index;
* during the next checkpoint's flush — the flush writes are journaled
  under epoch ``C``, so the same rollback undoes the partial flush;
* between the checkpoint's atomic rename and its first journaled write —
  the new epoch's journal is empty; rollback is a no-op.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Optional

import numpy as np

from repro.core.cholesky import _device_nslots, _np_interpret_op
from repro.core.schedule import MultiDeviceSchedule, OpKind, Schedule
from repro.core.spill import SpilledHostStore, host_residency_at

from .manager import CheckpointManager


class TileJournal:
    """Per-epoch undo log of disk-tile overwrites.

    ``journal(i, j, old)`` records a tile's pre-overwrite bytes the first
    time it is written in the current epoch (one ``.npy`` per tile, under
    ``<dir>/epoch_<e>/``); :meth:`rollback` restores every journaled tile
    of an epoch to the store.  An epoch corresponds to the interval
    after one checkpoint and up to (and including) the flush writes of
    the next — exactly the writes a resume from that checkpoint must
    undo.
    """

    def __init__(self, directory: str, epoch: int = -1):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.epoch = epoch
        os.makedirs(self._epoch_dir(epoch), exist_ok=True)
        self._seen: set = set()

    def _epoch_dir(self, epoch: int) -> str:
        return os.path.join(self.dir, f"epoch_{epoch + 1:08d}")

    def _tile_path(self, epoch: int, i: int, j: int) -> str:
        return os.path.join(self._epoch_dir(epoch), f"t_{i}_{j}.npy")

    def journal(self, i: int, j: int, old: np.ndarray):
        if (i, j) in self._seen:
            return
        path = self._tile_path(self.epoch, i, j)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.save(f, np.asarray(old, dtype=np.float64))
        os.replace(tmp, path)
        self._seen.add((i, j))

    def begin_epoch(self, epoch: int):
        """Start journaling under ``epoch`` (called right after the
        checkpoint for step ``epoch`` has been atomically committed);
        older epochs' entries are no longer needed and are dropped."""
        for name in os.listdir(self.dir):
            if name.startswith("epoch_") and name != \
                    os.path.basename(self._epoch_dir(epoch)):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)
        self.epoch = epoch
        os.makedirs(self._epoch_dir(epoch), exist_ok=True)
        self._seen = set()

    def rollback(self, store, epoch: int) -> int:
        """Restore every tile journaled under ``epoch``; returns count."""
        d = self._epoch_dir(epoch)
        count = 0
        if not os.path.isdir(d):
            return 0
        for name in os.listdir(d):
            if not name.endswith(".npy") or name.endswith(".tmp"):
                continue
            _, i, j = name[:-4].split("_")
            store.write_tile(int(i), int(j), np.load(os.path.join(d, name)))
            count += 1
        store.flush()
        return count


class JournaledTileStore:
    """Tile-store wrapper that journals the first overwrite per epoch."""

    def __init__(self, store, journal: TileJournal):
        self.store = store
        self.journal = journal
        self.nt = store.nt
        self.tb = store.tb

    def read_tile(self, i: int, j: int) -> np.ndarray:
        return self.store.read_tile(i, j)

    def write_tile(self, i: int, j: int, value: np.ndarray):
        if (i, j) not in self.journal._seen:
            self.journal.journal(i, j, self.store.read_tile(i, j))
        self.store.write_tile(i, j, value)

    def flush(self):
        self.store.flush()


class RestartableFactorization:
    """Drive a spill schedule over a disk store with resumable progress.

    ``run()`` replays the op stream with the NumPy interpreter (the
    bit-deterministic executor) against the disk-backed store, saving a
    checkpoint every ``checkpoint_every`` completed columns (and at a
    pending ``manager.should_save_now`` signal request).  A fresh
    ``run()`` on the same (manager dir, store, schedule) after a kill —
    at *any* point, mid-column included — resumes from the latest
    checkpoint and produces a factor bit-identical to an uninterrupted
    run.  A checkpoint from a different schedule digest raises.

    The per-checkpoint state is tiny: the device slot buffer (the only
    state not reconstructible from schedule + disk) plus
    ``{digest, op_index, column}``; host-tier residency is rebuilt
    statically and slab contents re-fetched from the (flushed,
    rolled-back) disk store.
    """

    def __init__(self, sched: Schedule | MultiDeviceSchedule,
                 store, manager: CheckpointManager,
                 checkpoint_every: int = 1):
        if isinstance(sched, MultiDeviceSchedule):
            sched = sched.to_single()
        if sched.host_slots < 1:
            raise ValueError(
                "RestartableFactorization needs a spill schedule "
                "(host_slots > 0): only then is the host tier "
                "reconstructible from the schedule + disk store")
        if checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1, got {checkpoint_every}")
        self.sched = sched
        self.digest = sched.digest()
        self.manager = manager
        self.checkpoint_every = checkpoint_every
        self.journal = TileJournal(os.path.join(manager.dir, "journal"))
        self.store = JournaledTileStore(store, self.journal)
        self._nslots = max(_device_nslots(sched.ops), 1)

    # ---- checkpoint plumbing ----
    def _save(self, host: SpilledHostStore, slots: np.ndarray,
              op_index: int, column: int):
        # flush journals under the *previous* epoch (a resume from the
        # previous checkpoint must be able to undo a partial flush),
        # then commit atomically, then open the new epoch
        host.flush_residents()
        self.manager.save(column, {"slots": slots},
                          extra={"digest": self.digest,
                                 "op_index": op_index,
                                 "column": column,
                                 "complete": op_index >= len(self.sched.ops)})
        self.journal.begin_epoch(column)

    def _restore(self):
        """Return ``(start_index, slots, host)`` — fresh or resumed."""
        step = self.manager.latest_step()
        if step is None:
            self.journal.rollback(self.store.store, -1)
            self.journal.begin_epoch(-1)
            return 0, np.zeros((self._nslots, self.sched.tb, self.sched.tb),
                               dtype=np.float64), self._fresh_host()
        tree, extra = self.manager.restore(
            {"slots": np.zeros((self._nslots, self.sched.tb, self.sched.tb),
                               dtype=np.float64)}, step=step)
        if extra is None or extra.get("digest") != self.digest:
            raise ValueError(
                f"checkpoint step {step} in {self.manager.dir!r} was saved "
                f"for schedule digest {extra.get('digest') if extra else None!r}, "
                f"but this factorization runs digest {self.digest!r}; "
                "refusing to resume mid-stream under a different schedule")
        # undo disk writes made after this checkpoint, then rebuild the
        # host tier: residency from the schedule prefix, contents from disk
        self.journal.rollback(self.store.store, extra["column"])
        self.journal.epoch = extra["column"]
        self.journal._seen = set()
        host = self._fresh_host()
        for tile, slab in host_residency_at(self.sched.ops,
                                            extra["op_index"]).items():
            host.tile_of[slab] = tile
            host.where[tile] = slab
        host.refetch_residents()
        return int(extra["op_index"]), tree["slots"], host

    def _fresh_host(self) -> SpilledHostStore:
        return SpilledHostStore(self.store, self.sched.host_slots)

    # ---- driving loop ----
    def run(self, stop_after_column: Optional[int] = None,
            stop_after_ops: Optional[int] = None) -> bool:
        """Replay until done (True) or until a simulated kill point
        (False).  Both stops abort *without* saving — a hard kill:
        ``stop_after_column=k`` aborts once column ``k`` has completed,
        ``stop_after_ops=m`` aborts after interpreting ``m`` more ops
        (mid-column kills exercise the journal rollback).
        """
        ops = self.sched.ops
        lad = self.sched.plan.ladder
        idx, slots, host = self._restore()
        if idx >= len(ops):
            return True
        column = ops[idx].k
        done = 0
        for i in range(idx, len(ops)):
            op = ops[i]
            if op.k > column:
                # column boundary: ops[:i] completed columns <= `column`
                if stop_after_column is not None \
                        and column >= stop_after_column:
                    return False
                if (column % self.checkpoint_every
                        == self.checkpoint_every - 1) \
                        or self.manager.should_save_now:
                    self._save(host, slots, i, column)
                column = op.k
            if stop_after_ops is not None and done >= stop_after_ops:
                return False
            _np_interpret_op(host, slots, op, lad)
            done += 1
        host.flush_residents()   # scheduled SPILLs already flushed dirty
        #                          slabs; this settles clean residents too
        #                          (no-op values) and syncs the mmap
        self._save(host, slots, len(ops), self.sched.nt - 1)
        return True

    def result_tiles(self) -> np.ndarray:
        return self.store.store.to_tiles()
