"""Fault-tolerant checkpointing: atomic writes, retention, preemption path.

* **atomicity** — every process writes into ``step_<n>.tmp/``; process 0
  then ``os.replace``s it to ``step_<n>/`` (removing a stale ``step_<n>/``
  from an earlier save of the same step first).  A crash mid-write never
  corrupts the latest checkpoint: ``latest_step`` ignores ``.tmp``
  leftovers.
* **per-process files** — each process saves its host-local view of every
  leaf (``jax.device_get``) as ``host_<p>.npz``; restore reads the
  process's own file and casts each array back to the target leaf's
  dtype.  No resharding is attempted: on restore the caller receives
  host numpy arrays and is responsible for any ``device_put`` into a
  target sharding.  On the single-process CPU CI this is one file.
* **preemption** — ``save_on_signal`` installs a SIGTERM handler that
  requests an immediate save at the next step boundary (the driving loop
  polls ``should_save_now``).
* **retention** — keep the newest ``keep`` checkpoints (``keep >= 1``),
  delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import signal

import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        if keep < 1:
            raise ValueError(
                f"keep must be >= 1 (the newest checkpoint is always "
                f"retained), got {keep}")
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._save_requested = False

    # ---- preemption handling ----
    def save_on_signal(self, signum=signal.SIGTERM):
        def handler(_sig, _frm):
            self._save_requested = True
        signal.signal(signum, handler)

    @property
    def should_save_now(self) -> bool:
        return self._save_requested

    # ---- save/restore ----
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, tree, extra: dict | None = None):
        proc = jax.process_index()
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        # every process writes its own host_<p>.npz into tmp, so every
        # process must be able to create it (first writer wins)
        os.makedirs(tmp, exist_ok=True)
        leaves = _flatten_with_paths(tree)
        arrays, meta = {}, {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key.replace("/", "__")] = arr
            meta[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        np.savez(os.path.join(tmp, f"host_{proc}.npz"), **arrays)
        if proc == 0:
            # shared metadata is written once, by process 0 only
            if extra is not None:
                with open(os.path.join(tmp, "extra.json"), "w") as f:
                    json.dump(extra, f)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            # barrier-equivalent on multi-host would sync here; then one
            # atomic rename.  Re-saving a step (resume, then checkpoint
            # the same boundary again) must not trip over the old dir:
            # os.replace raises OSError for non-empty directory targets.
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()
        self._save_requested = False

    def restore(self, tree_like, step: int | None = None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        d = self._step_dir(step)
        proc = jax.process_index()
        path = os.path.join(d, f"host_{proc}.npz")
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"no checkpoint for step {step}: {path!r} does not exist "
                f"(expected checkpoint directory {d!r})")
        data = np.load(path)
        leaves = _flatten_with_paths(tree_like)
        restored = {}
        for key in leaves:
            restored[key] = data[key.replace("/", "__")]
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        new_leaves = []
        for path_, leaf in flat:
            key = "/".join(str(p) for p in path_)
            arr = restored[key]
            tgt_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            new_leaves.append(np.asarray(arr, dtype=tgt_dtype))
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        extra = None
        ep = os.path.join(d, "extra.json")
        if os.path.exists(ep):
            with open(ep) as f:
                extra = json.load(f)
        return tree, extra

    def latest_step(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
