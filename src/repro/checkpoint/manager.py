"""Fault-tolerant checkpointing: atomic writes, retention, preemption path.

* **atomicity** — write to ``step_<n>.tmp/`` then ``os.replace`` to
  ``step_<n>/``; a crash mid-write never corrupts the latest checkpoint.
* **sharded-aware** — each host saves only the addressable shards of every
  array (``.addressable_shards``), one ``.npz`` per host; restore reads the
  host's own file and device_puts into the (possibly different) target
  sharding — this is what makes **elastic restart** work: the on-disk
  layout is mesh-shape-agnostic (global arrays are reassembled from shard
  index metadata).  On the single-process CPU CI this degrades to one file.
* **preemption** — ``save_on_signal`` installs a SIGTERM handler that
  requests an immediate save at the next step boundary (the train loop
  polls ``should_save_now``).
* **retention** — keep the newest ``keep`` checkpoints, delete older.
"""
from __future__ import annotations

import json
import os
import shutil
import signal

import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._save_requested = False

    # ---- preemption handling ----
    def save_on_signal(self, signum=signal.SIGTERM):
        def handler(_sig, _frm):
            self._save_requested = True
        signal.signal(signum, handler)

    @property
    def should_save_now(self) -> bool:
        return self._save_requested

    # ---- save/restore ----
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def save(self, step: int, tree, extra: dict | None = None):
        proc = jax.process_index()
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        if proc == 0:
            os.makedirs(tmp, exist_ok=True)
        leaves = _flatten_with_paths(tree)
        arrays, meta = {}, {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key.replace("/", "__")] = arr
            meta[key] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
        np.savez(os.path.join(tmp, f"host_{proc}.npz"), **arrays)
        if extra is not None and proc == 0:
            with open(os.path.join(tmp, "extra.json"), "w") as f:
                json.dump(extra, f)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        # barrier-equivalent on multi-host would sync here; then atomic rename
        os.replace(tmp, final)
        self._save_requested = False
        self._gc()

    def restore(self, tree_like, step: int | None = None):
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        d = self._step_dir(step)
        proc = jax.process_index()
        data = np.load(os.path.join(d, f"host_{proc}.npz"))
        leaves = _flatten_with_paths(tree_like)
        restored = {}
        for key in leaves:
            restored[key] = data[key.replace("/", "__")]
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        new_leaves = []
        for path, leaf in flat:
            key = "/".join(str(p) for p in path)
            arr = restored[key]
            tgt_dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            new_leaves.append(np.asarray(arr, dtype=tgt_dtype))
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        extra = None
        ep = os.path.join(d, "extra.json")
        if os.path.exists(ep):
            with open(ep) as f:
                extra = json.load(f)
        return tree, extra

    def latest_step(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
