"""Shared building blocks: param init helpers, norms, MLPs, rope, embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays).  Every init
function returns ``(params, axes)`` where ``axes`` mirrors the params tree
with a tuple of *logical axis names* per array dimension; the distributed
runtime (``repro.distributed.sharding``) maps logical names onto mesh axes.

Logical axes used across the stack:
  vocab   — vocabulary dim            (TP: sharded over "model")
  embed   — d_model dim               (FSDP: sharded over "data")
  heads   — flattened attention heads (TP)
  kv      — kv-head dim               (TP when divisible, else replicated)
  mlp     — FFN hidden dim            (TP)
  expert  — MoE expert dim            (EP over "model")
  inner   — SSM inner dim             (TP)
  lora    — MLA compressed dim        (replicated)
  stack   — scan-stacked layer dim    (never sharded)
  None    — replicated
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


import contextvars

# When set, Builders emit jax.ShapeDtypeStruct leaves instead of arrays:
# used for (a) the dry-run's allocation-free param trees and (b) computing
# the logical-axes tree without touching device memory.
ABSTRACT_INIT = contextvars.ContextVar("abstract_init", default=False)


def normal_init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


class Builder:
    """Tiny helper that threads an rng key and collects (params, axes)."""

    def __init__(self, key, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    @property
    def abstract(self):
        return ABSTRACT_INIT.get()

    def key(self):
        if self.abstract:
            return None
        self._key, k = jax.random.split(self._key)
        return k

    def dense(self, name, shape, axes, fan_in=None):
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(shape, self.dtype)
        else:
            fan_in = fan_in or shape[0]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            self.params[name] = normal_init(self.key(), shape, scale, self.dtype)
        self.axes[name] = axes
        return self

    def const(self, name, shape, axes, value=0.0):
        if self.abstract:
            self.params[name] = jax.ShapeDtypeStruct(shape, self.dtype)
        else:
            self.params[name] = jnp.full(shape, value, dtype=self.dtype)
        self.axes[name] = axes
        return self

    def child(self, name, params, axes):
        self.params[name] = params
        self.axes[name] = axes
        return self

    def build(self):
        return self.params, self.axes


# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + weight.astype(jnp.float32))).astype(dt)


def init_norm(key, d, dtype):
    return jnp.zeros((d,), dtype=dtype), ("embed",)


# ---------------------------------------------------------------------------
# MLP

def init_mlp(key, d, f, act: str, dtype):
    b = Builder(key, dtype)
    gated = act in ("silu", "gelu")
    if gated:
        b.dense("wi", (d, f), ("embed", "mlp"))
        b.dense("wg", (d, f), ("embed", "mlp"))
    else:
        b.dense("wi", (d, f), ("embed", "mlp"))
    b.dense("wo", (f, d), ("mlp", "embed"), fan_in=f)
    return b.build()


def apply_mlp(p, x, act: str):
    h = x @ p["wi"].astype(x.dtype)
    if act == "silu":
        g = x @ p["wg"].astype(x.dtype)
        h = jax.nn.silu(g) * h
    elif act == "gelu":
        g = x @ p["wg"].astype(x.dtype)
        h = jax.nn.gelu(g) * h
    elif act == "squared_relu":
        r = jax.nn.relu(h)
        h = r * r
    else:
        raise ValueError(act)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding

def init_embedding(key, vocab, d, dtype):
    b = Builder(key, dtype)
    b.dense("tok", (vocab, d), ("vocab", "embed"), fan_in=d)
    return b.build()


def embed_tokens(p, tokens, dtype):
    return p["tok"].astype(dtype)[tokens]


def unembed(p_out, x):
    return x @ p_out.astype(x.dtype)


def init_unembed(key, d, vocab, dtype):
    b = Builder(key, dtype)
    b.dense("out", (d, vocab), ("embed", "vocab"))
    return b.build()
