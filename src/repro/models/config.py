"""Unified model configuration covering the 10 assigned architectures.

One dataclass parameterizes every family (dense / MoE / MLA / SSM / hybrid /
enc-dec / VLM-backbone); per-arch files in ``repro/configs`` instantiate it
with the published numbers and a reduced smoke variant.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None        # default d_model // num_heads

    # --- attention flavour ---
    qk_norm: bool = False                 # qwen3
    use_bias: bool = False                # command-r: no-bias (default off anyway)
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # gemma3 local layers
    local_global_ratio: int = 0           # gemma3: N local per 1 global
    attn_logit_softcap: Optional[float] = None
    mlp_act: str = "silu"                 # silu | squared_relu | gelu

    # --- MLA (deepseek) ---
    mla: bool = False
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None        # expert hidden size (deepseek fine-grained)
    moe_every: int = 1                    # apply MoE every k-th layer (jamba: 2)
    first_dense: int = 0                  # leading dense layers (deepseek: 1)
    moe_capacity: float = 1.25            # expert capacity factor

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0                    # d_state; 0 = no ssm layers
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_kernel: int = 4
    attn_every: int = 0                   # hybrid (jamba): 1 attn per k layers; 0 = per family

    # --- enc-dec (seamless backbone) ---
    enc_layers: int = 0                   # >0 => encoder-decoder
    cross_attention: bool = False

    # --- modality frontend stubs ---
    frontend: Optional[str] = None        # None | "vision" | "audio"
    frontend_tokens: int = 576            # patches / frames prepended (vlm/audio)

    # --- numerics / runtime ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    use_flash_attention: bool = False   # Pallas flash kernel (TPU target;
                                        # interpret mode on CPU)
    scan_group: int = 1                   # layers per scan body (pattern period)
    remat: bool = True
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 2048 (16-way TP x 128 MXU lanes);
        logits beyond ``vocab`` are masked in ``logits_from_hidden``."""
        m = 2048
        return (self.vocab + m - 1) // m * m

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def layer_kind(self, idx: int) -> str:
        """'attn' or 'ssm' for decoder layer idx (hybrid interleave)."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_every > 0:
            # jamba: 1 attention layer per attn_every layers (1:7 => every 8th)
            return "attn" if (idx % self.attn_every) == (self.attn_every - 1) else "ssm"
        return "attn"

    def layer_is_moe(self, idx: int) -> bool:
        if idx < self.first_dense:
            return False
        return self.is_moe and (idx % self.moe_every) == (self.moe_every - 1)

    def layer_window(self, idx: int) -> Optional[int]:
        """Sliding window for layer idx (gemma3 5:1 local:global)."""
        if self.sliding_window is None:
            return None
        if self.local_global_ratio <= 0:
            return self.sliding_window
        period = self.local_global_ratio + 1
        return None if (idx % period) == (period - 1) else self.sliding_window

    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §4)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # mostly-local attention (gemma3) qualifies: global KV is 1/period
        return self.sliding_window is not None and self.local_global_ratio > 0

    # ---- parameter counting (for roofline MODEL_FLOPS) ----
    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter counts, embeddings included."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        total = active = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
            active += v * d

        def attn_params():
            if self.mla:
                q = d * (self.num_heads * (self.qk_nope_dim + self.qk_rope_dim))
                kv = d * (self.kv_lora_rank + self.qk_rope_dim)
                up = self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                o = self.num_heads * self.v_head_dim * d
                return q + kv + up + o
            q = d * self.num_heads * hd
            k = d * self.num_kv_heads * hd
            vv = d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + k + vv + o

        def mlp_params(ff):
            mult = 3 if self.mlp_act in ("silu", "gelu") else 2  # gated vs plain
            return mult * d * ff

        def ssm_params():
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            in_proj = d * (2 * d_in + 2 * self.ssm_state + nheads)
            conv = (d_in + 2 * self.ssm_state) * self.ssm_conv_kernel
            out = d_in * d
            return in_proj + conv + out + 2 * nheads  # + A, D, dt bias

        n_dec = self.num_layers
        for i in range(n_dec):
            kind = self.layer_kind(i)
            t = attn_params() if kind == "attn" else ssm_params()
            a = t
            if self.layer_is_moe(i):
                e = mlp_params(self.expert_d_ff)
                t += self.n_experts * e + self.n_shared_experts * e
                t += d * self.n_experts  # router
                a += (self.top_k + self.n_shared_experts) * e + d * self.n_experts
            else:
                t += mlp_params(f)
                a += mlp_params(f)
            total += t
            active += a
        if self.is_encdec:
            enc = self.enc_layers * (attn_params() + mlp_params(f))
            cross = n_dec * attn_params()
            total += enc + cross
            active += enc + cross
        return total, active
