"""Mixture-of-Experts FFN with sort-based (dropless-ish) token dispatch.

TPU-native dispatch: assignments are sorted by expert id and placed into a
capacity-bounded [E, C, d] buffer with gather/scatter (no [T, E, C] one-hot
— that tensor is quadratic in tokens and kills the 32k-seq shapes).  Under
pjit the buffer is sharded (expert -> "model", capacity -> "data"), which
lowers the dispatch/combine into all-to-alls — the GShard pattern.

Shared experts (DeepSeek) run densely on every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Builder, apply_mlp
from repro.distributed.sharding import moe_group_count, shard_act


def init_moe(key, cfg):
    d = cfg.d_model
    f = cfg.expert_d_ff
    e = cfg.n_experts
    b = Builder(key, jnp.dtype(cfg.param_dtype))
    b.dense("router", (d, e), ("embed", None))
    gated = cfg.mlp_act in ("silu", "gelu")
    if gated:
        b.dense("wi", (e, d, f), ("expert", "embed", "mlp"), fan_in=d)
        b.dense("wg", (e, d, f), ("expert", "embed", "mlp"), fan_in=d)
    else:
        b.dense("wi", (e, d, f), ("expert", "embed", "mlp"), fan_in=d)
    b.dense("wo", (e, f, d), ("expert", "mlp", "embed"), fan_in=f)
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        if gated:
            b.dense("shared_wi", (d, fs), ("embed", "mlp"))
            b.dense("shared_wg", (d, fs), ("embed", "mlp"))
        else:
            b.dense("shared_wi", (d, fs), ("embed", "mlp"))
        b.dense("shared_wo", (fs, d), ("mlp", "embed"), fan_in=fs)
    return b.build()


def _expert_ffn(p, h, act):
    """h: [E, C, d] -> [E, C, d] batched over experts."""
    dt = h.dtype
    up = jnp.einsum("ecd,edf->ecf", h, p["wi"].astype(dt))
    if act in ("silu", "gelu"):
        gate = jnp.einsum("ecd,edf->ecf", h, p["wg"].astype(dt))
        up = (jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)) * up
    else:
        r = jax.nn.relu(up)
        up = r * r
    return jnp.einsum("ecf,efd->ecd", up, p["wo"].astype(dt))


def _dispatch_group(xg, idx, gates, e, cap):
    """Shard-local dispatch for one token group.

    xg: [Tl, d]; idx/gates: [Tl, k].  Returns (hidden_in [e, cap, d],
    st, sg, keep, slot) for the combine step.
    """
    tl, d = xg.shape
    k = idx.shape[-1]
    a = tl * k
    flat_e = idx.reshape(a)
    flat_t = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
    flat_g = gates.reshape(a)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    start = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype), side="left")
    pos = jnp.arange(a, dtype=jnp.int32) - start[se].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos, a + e * cap)

    buf = jnp.zeros((e * cap, d), dtype=xg.dtype)
    gathered = xg[st] * keep[:, None].astype(xg.dtype)
    buf = buf.at[slot].set(gathered, mode="drop")
    return buf.reshape(e, cap, d), st, sg, keep, slot


def _combine_group(hidden, st, sg, keep, slot, tl):
    """Inverse of _dispatch_group: [e, cap, d] -> [Tl, d]."""
    e, cap, d = hidden.shape
    flat = hidden.reshape(e * cap, d)
    back = flat.at[slot].get(mode="fill", fill_value=0.0)
    back = back * (sg * keep)[:, None].astype(hidden.dtype)
    return jnp.zeros((tl, d), dtype=hidden.dtype).at[st].add(back)


def apply_moe(p, cfg, x, capacity_factor: float | None = None):
    """x: [B, S, d] -> [B, S, d].

    Grouped dispatch: tokens are split into G = |data| groups so that the
    sort / capacity / scatter of every group is local to its data shard
    (a global argsort would force XLA to all-reduce the full [E,C,d]
    buffer each layer — measured 25x collective blow-up on dbrx).  The
    grouped buffer [G,E,C,d] is sharded (data, model, -, -); moving
    tokens from their data shard to their expert's model shard lowers to
    the GShard all-to-all pair.
    """
    bsz, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = bsz * s
    xt = x.reshape(t, d)

    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity
    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)   # [T,k]
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    g = moe_group_count(t)
    tl = t // g
    cap = int(max(1, round(tl * k * capacity_factor / e)))
    # pad for layout only (the capacity dim is not mesh-sharded: the
    # group dim carries "data"); 128-padding would 16x decode-time work
    cap = (cap + 7) // 8 * 8

    xg = shard_act(xt.reshape(g, tl, d), "moe_tokens")
    idx_g = idx.reshape(g, tl, k)
    gates_g = gates.reshape(g, tl, k)

    hidden_in, st, sg, keep, slot = jax.vmap(
        lambda xx, ii, gg: _dispatch_group(xx, ii, gg, e, cap)
    )(xg, idx_g, gates_g)

    hidden_in = shard_act(hidden_in, "moe_buf")   # -> (data, model, -, -)
    hidden = jax.vmap(lambda h: _expert_ffn(p, h, cfg.mlp_act))(hidden_in)
    hidden = shard_act(hidden, "moe_buf")

    out_g = jax.vmap(_combine_group, in_axes=(0, 0, 0, 0, 0, None))(
        hidden, st, sg, keep, slot, tl)
    out = shard_act(out_g, "moe_tokens").reshape(t, d)

    if cfg.n_shared_experts:
        sp = {"wi": p["shared_wi"], "wo": p["shared_wo"]}
        if "shared_wg" in p:
            sp["wg"] = p["shared_wg"]
        out = out + apply_mlp(sp, xt, cfg.mlp_act)
    return out.reshape(bsz, s, d)
