"""Model assembly: layer stacks for every family, train/prefill/decode paths.

Layer stacking: the decoder is split into ``prefix`` (unrolled, e.g.
deepseek's leading dense layer), a scanned region of identical groups of
``cfg.scan_group`` layers (``lax.scan`` over stacked params — keeps HLO
size O(1) in depth and gives XLA a natural overlap pipeline), and an
unrolled ``remainder`` (e.g. gemma3's 26 = 4x6 + 2).  Layer *kinds* inside
a group follow the periodic pattern (jamba 7 ssm : 1 attn, gemma 5 local :
1 global, jamba MoE every 2nd), so every scanned group is structurally
identical by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ModelConfig
from .layers import (ABSTRACT_INIT, Builder, apply_mlp, embed_tokens,
                     init_embedding, init_mlp, init_unembed, rms_norm,
                     unembed)
from repro.distributed.sharding import residual_barrier, shard_act


def init_model_abstract(cfg: ModelConfig):
    """Allocation-free param tree (ShapeDtypeStruct leaves) + logical axes."""
    tok = ABSTRACT_INIT.set(True)
    try:
        return init_model(cfg, None)
    finally:
        ABSTRACT_INIT.reset(tok)


# ---------------------------------------------------------------------------
# Single layer

def init_layer(key, cfg: ModelConfig, idx: int):
    kind = cfg.layer_kind(idx)
    b = Builder(key, jnp.dtype(cfg.param_dtype))
    b.const("ln1", (cfg.d_model,), ("embed",))
    if kind == "ssm":
        p, a = ssm_mod.init_ssm(b.key(), cfg)
        b.child("ssm", p, a)
    elif cfg.mla:
        p, a = attn.init_mla(b.key(), cfg)
        b.child("attn", p, a)
    else:
        p, a = attn.init_gqa(b.key(), cfg)
        b.child("attn", p, a)
    if cfg.is_encdec:
        b.const("cross_ln", (cfg.d_model,), ("embed",))
        p, a = attn.init_cross(b.key(), cfg)
        b.child("cross", p, a)
    if cfg.layer_is_moe(idx):
        b.const("ln2", (cfg.d_model,), ("embed",))
        p, a = moe_mod.init_moe(b.key(), cfg)
        b.child("moe", p, a)
    elif cfg.d_ff > 0:
        b.const("ln2", (cfg.d_model,), ("embed",))
        p, a = init_mlp(b.key(), cfg.d_model, cfg.d_ff, cfg.mlp_act,
                        jnp.dtype(cfg.param_dtype))
        b.child("mlp", p, a)
    return b.build()


def apply_layer(p, cfg: ModelConfig, idx: int, x, positions, enc_out=None):
    kind = cfg.layer_kind(idx)
    x = shard_act(x, "hidden")
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "ssm":
        h = ssm_mod.apply_ssm(p["ssm"], cfg, h)
    elif cfg.mla:
        h = attn.apply_mla(p["attn"], cfg, h, positions)
    else:
        h = attn.apply_gqa(p["attn"], cfg, h, positions,
                           window=cfg.layer_window(idx))
    x = x + h
    if cfg.is_encdec and enc_out is not None:
        h = rms_norm(x, p["cross_ln"], cfg.norm_eps)
        kv = attn.cross_kv(p["cross"], enc_out)
        x = x + attn.apply_cross(p["cross"], cfg, h, kv)
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + moe_mod.apply_moe(p["moe"], cfg, h)
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_act)
    return residual_barrier(x)


def init_layer_cache(cfg: ModelConfig, idx: int, batch, max_len, dtype):
    kind = cfg.layer_kind(idx)
    if kind == "ssm":
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    if cfg.mla:
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    return attn.init_gqa_cache(cfg, batch, max_len, dtype,
                               window=cfg.layer_window(idx))


def decode_layer(p, cfg: ModelConfig, idx: int, x, cache, pos, enc_out=None):
    kind = cfg.layer_kind(idx)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "ssm":
        h, cache = ssm_mod.decode_ssm(p["ssm"], cfg, h, cache)
    elif cfg.mla:
        h, cache = attn.decode_mla(p["attn"], cfg, h, cache, pos)
    else:
        h, cache = attn.decode_gqa(p["attn"], cfg, h, cache, pos,
                                   window=cfg.layer_window(idx))
    x = x + h
    if cfg.is_encdec and enc_out is not None:
        hh = rms_norm(x, p["cross_ln"], cfg.norm_eps)
        kv = attn.cross_kv(p["cross"], enc_out)
        x = x + attn.apply_cross(p["cross"], cfg, hh, kv)
    if "moe" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + moe_mod.apply_moe(p["moe"], cfg, h)
    elif "mlp" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_act)
    return x, cache


# ---------------------------------------------------------------------------
# Model

def _regions(cfg: ModelConfig):
    """(prefix_idxs, n_groups, group_idxs_fn, remainder_idxs)."""
    pre = list(range(cfg.first_dense))
    rest = cfg.num_layers - cfg.first_dense
    g = cfg.scan_group
    n_groups = rest // g
    rem_start = cfg.first_dense + n_groups * g
    rem = list(range(rem_start, cfg.num_layers))
    return pre, n_groups, rem


def init_model(cfg: ModelConfig, key):
    b = Builder(key, jnp.dtype(cfg.param_dtype))
    p, a = init_embedding(b.key(), cfg.padded_vocab, cfg.d_model,
                          jnp.dtype(cfg.param_dtype))
    b.child("embed", p, a)

    pre, n_groups, rem = _regions(cfg)
    prefix, prefix_a = [], []
    for i in pre:
        pp, aa = init_layer(b.key(), cfg, i)
        prefix.append(pp)
        prefix_a.append(aa)
    b.child("prefix", prefix, prefix_a)

    if n_groups > 0:
        base = cfg.first_dense

        def init_group(k):
            ks = (jax.random.split(k, cfg.scan_group)
                  if k is not None else [None] * cfg.scan_group)
            ps, aas = [], []
            for j in range(cfg.scan_group):
                pp, aa = init_layer(ks[j], cfg, base + j)
                ps.append(pp)
                aas.append(aa)
            return ps, aas

        # axes (and abstract shapes) from one structure-only pass
        tok = ABSTRACT_INIT.set(True)
        try:
            abs_params, group_axes = init_group(None)
        finally:
            ABSTRACT_INIT.reset(tok)
        stack_axes = jax.tree.map(lambda ax: ("stack",) + tuple(ax), group_axes,
                                  is_leaf=lambda x: isinstance(x, tuple))
        if ABSTRACT_INIT.get():
            stacked = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_groups,) + s.shape, s.dtype),
                abs_params)
        else:
            keys = jax.random.split(b.key(), n_groups)
            stacked = jax.vmap(lambda k: init_group(k)[0])(keys)
        b.child("stack", stacked, stack_axes)
    else:
        b.child("stack", None, None)

    rem_p, rem_a = [], []
    for i in rem:
        pp, aa = init_layer(b.key(), cfg, i)
        rem_p.append(pp)
        rem_a.append(aa)
    b.child("remainder", rem_p, rem_a)

    b.const("final_norm", (cfg.d_model,), ("embed",))
    if not cfg.tie_embeddings:
        p, a = init_unembed(b.key(), cfg.d_model, cfg.padded_vocab,
                            jnp.dtype(cfg.param_dtype))
        b.child("unembed", p, a)

    if cfg.is_encdec:
        enc_layers, enc_axes = [], []
        for i in range(cfg.enc_layers):
            bb = Builder(b.key(), jnp.dtype(cfg.param_dtype))
            bb.const("ln1", (cfg.d_model,), ("embed",))
            pp, aa = attn.init_gqa(bb.key(), cfg)
            bb.child("attn", pp, aa)
            bb.const("ln2", (cfg.d_model,), ("embed",))
            pp, aa = init_mlp(bb.key(), cfg.d_model, cfg.d_ff, cfg.mlp_act,
                              jnp.dtype(cfg.param_dtype))
            bb.child("mlp", pp, aa)
            lp, la = bb.build()
            enc_layers.append(lp)
            enc_axes.append(la)
        b.child("encoder", enc_layers, enc_axes)
        b.const("enc_final_norm", (cfg.d_model,), ("embed",))
    return b.build()


def _apply_encoder(params, cfg, enc_embeds):
    x = enc_embeds
    positions = jnp.arange(x.shape[1])[None, :]
    for lp in params["encoder"]:
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn.apply_bidir(lp["attn"], cfg, h, positions)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + apply_mlp(lp["mlp"], h, cfg.mlp_act)
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _stack_scan(params, cfg, x, positions, enc_out, remat=True):
    """Scan over stacked layer groups."""
    base = cfg.first_dense

    def group_body(carry, group_params):
        h = carry
        for j in range(cfg.scan_group):
            h = apply_layer(group_params[j], cfg, base + j, h, positions, enc_out)
        return h, None

    body = jax.checkpoint(group_body) if (remat and cfg.remat) else group_body
    x, _ = jax.lax.scan(body, x, params["stack"])
    return x


def forward(params, cfg: ModelConfig, tokens, frontend_embeds=None,
            enc_embeds=None):
    """Train/prefill forward pass -> final hidden states [B, S, d]."""
    dtype = jnp.dtype(cfg.dtype)
    x = shard_act(embed_tokens(params["embed"], tokens, dtype), "hidden")
    if frontend_embeds is not None:
        # modality stub: frontend embeddings overwrite the leading positions
        n = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(dtype), x[:, n:]], axis=1)
    enc_out = None
    if cfg.is_encdec:
        assert enc_embeds is not None
        enc_out = _apply_encoder(params, cfg, enc_embeds.astype(dtype))
    positions = jnp.arange(x.shape[1])[None, :]

    for i, lp in zip(range(cfg.first_dense), params["prefix"]):
        x = apply_layer(lp, cfg, i, x, positions, enc_out)
    if params["stack"] is not None:
        x = _stack_scan(params, cfg, x, positions, enc_out)
    pre, n_groups, rem = _regions(cfg)
    for i, lp in zip(rem, params["remainder"]):
        x = apply_layer(lp, cfg, i, x, positions, enc_out)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def logits_from_hidden(params, cfg, hidden):
    out = params["unembed"]["out"] if not cfg.tie_embeddings else params["embed"]["tok"].T
    logits = unembed(out, hidden)
    if cfg.padded_vocab != cfg.vocab:
        # mask the padding columns (never predicted, zero softmax mass)
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))
    return shard_act(logits, "logits")


# ---------------------------------------------------------------------------
# Decode

def init_cache(cfg: ModelConfig, batch, max_len, dtype):
    pre, n_groups, rem = _regions(cfg)
    base = cfg.first_dense
    prefix = [init_layer_cache(cfg, i, batch, max_len, dtype) for i in pre]
    stack = None
    if n_groups > 0:
        def one(j):
            return init_layer_cache(cfg, base + j, batch, max_len, dtype)
        per_pos = [one(j) for j in range(cfg.scan_group)]
        stack = jax.tree.map(
            lambda c: jnp.broadcast_to(c, (n_groups,) + c.shape).copy(), per_pos)
    remainder = [init_layer_cache(cfg, i, batch, max_len, dtype) for i in rem]
    return {"prefix": prefix, "stack": stack, "remainder": remainder}


def decode_step(params, cfg: ModelConfig, token, cache, pos, enc_out=None):
    """token: [B, 1] int32; pos: scalar int32. Returns (logits, new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"], token, dtype)
    pre, n_groups, rem = _regions(cfg)
    base = cfg.first_dense

    new_prefix = []
    for i, lp, c in zip(pre, params["prefix"], cache["prefix"]):
        x, c = decode_layer(lp, cfg, i, x, c, pos, enc_out)
        new_prefix.append(c)

    new_stack = cache["stack"]
    if params["stack"] is not None:
        def group_body(carry, scanned):
            h = carry
            gp, gc = scanned
            new_gc = []
            for j in range(cfg.scan_group):
                h, cj = decode_layer(gp[j], cfg, base + j, h, gc[j], pos, enc_out)
                new_gc.append(cj)
            return h, new_gc

        x, new_stack = jax.lax.scan(group_body, x,
                                    (params["stack"], cache["stack"]))

    new_rem = []
    for i, lp, c in zip(rem, params["remainder"], cache["remainder"]):
        x, c = decode_layer(lp, cfg, i, x, c, pos, enc_out)
        new_rem.append(c)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, h)
    return logits, {"prefix": new_prefix, "stack": new_stack,
                    "remainder": new_rem}
