"""Mamba-2 block via the SSD (state-space duality) chunked algorithm.

Train path: chunked SSD — intra-chunk quadratic attention-like term plus an
inter-chunk state recurrence (lax.scan over chunk states), per the minimal
SSD formulation of the Mamba-2 paper.  Decode path: O(1) recurrent state
update per token (this is what makes the ``long_500k`` shape tractable).

Single B/C group (mamba2 default), causal depthwise conv over the xBC
stream, gated RMSNorm before the output projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Builder, rms_norm


def init_ssm(key, cfg):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    nh = d_in // hd
    ck = cfg.ssm_conv_kernel
    b = Builder(key, jnp.dtype(cfg.param_dtype))
    # in_proj emits [z | x | B | C | dt]
    b.dense("in_proj", (d, 2 * d_in + 2 * n + nh), ("embed", "inner"))
    b.dense("conv_w", (ck, d_in + 2 * n), (None, "inner"), fan_in=ck)
    b.const("conv_b", (d_in + 2 * n,), ("inner",))
    b.const("a_log", (nh,), (None,), value=0.0)
    b.const("d_skip", (nh,), (None,), value=1.0)
    b.const("dt_bias", (nh,), (None,))
    b.const("out_norm", (d_in,), ("inner",))
    b.dense("out_proj", (d_in, d), ("inner", "embed"), fan_in=d_in)
    return b.build()


def _split_proj(cfg, proj):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    z = proj[..., :d_in]
    xbc = proj[..., d_in:2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n:]
    return z, xbc, dt, d_in, n, nh


def _causal_conv(xbc, w, b):
    """xbc: [B, S, ch]; w: [K, ch] depthwise; left-padded causal."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(x):
    """x: [..., q] -> [..., q, q] lower-tri pairwise cumulative sums."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xh, dt, a, bmat, cmat, chunk):
    """Minimal SSD.

    xh:   [B, S, H, P]  head inputs
    dt:   [B, S, H]     positive step sizes
    a:    [H]           negative state decay rates
    bmat: [B, S, N], cmat: [B, S, N]  (single group)
    returns y: [B, S, H, P]
    """
    bsz, s, h, p = xh.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    c = s // chunk
    q = chunk

    xd = xh * dt[..., None]                            # fold dt into x
    abar = (dt * a[None, None, :])                     # [B,S,H]

    # chunked views
    xc = xd.reshape(bsz, c, q, h, p)
    ac = abar.reshape(bsz, c, q, h).transpose(0, 3, 1, 2)   # [B,H,C,Q]
    bc = bmat.reshape(bsz, c, q, n)
    cc = cmat.reshape(bsz, c, q, n)

    acum = jnp.cumsum(ac, axis=-1)                     # [B,H,C,Q]

    # 1) intra-chunk (diagonal) term
    l = jnp.exp(_segsum(ac))                           # [B,H,C,Q,Q]
    y_diag = jnp.einsum("bcqn,bcsn,bhcqs,bcshp->bcqhp", cc, bc, l, xc)

    # 2) per-chunk final states
    decay_states = jnp.exp(acum[..., -1:] - acum)      # [B,H,C,Q]
    states = jnp.einsum("bcqn,bhcq,bcqhp->bchpn", bc, decay_states, xc)

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(acum[..., -1])               # [B,H,C]

    def scan_fn(carry, inp):
        st, dec = inp
        new = st + dec[..., None, None] * carry        # [B,H,P,N]
        return new, carry                              # emit state *before* chunk

    states_t = states.transpose(1, 0, 2, 3, 4)         # [C,B,H,P,N]
    decay_t = chunk_decay.transpose(2, 0, 1)           # [C,B,H]
    init = jnp.zeros_like(states_t[0])
    _, prev_states = jax.lax.scan(scan_fn, init, (states_t, decay_t))
    prev = prev_states.transpose(1, 0, 2, 3, 4)        # [B,C,H,P,N]

    # 4) inter-chunk (off-diagonal) output
    state_decay = jnp.exp(acum)                        # [B,H,C,Q]
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", cc, prev, state_decay)

    return (y_diag + y_off).reshape(bsz, s, h, p)


def apply_ssm(p, cfg, x):
    """Train/prefill path. x: [B, S, d] -> [B, S, d]."""
    dtp = x.dtype
    proj = x @ p["in_proj"].astype(dtp)
    z, xbc, dt_raw, d_in, n, nh = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"].astype(dtp), p["conv_b"].astype(dtp))
    xs = xbc[..., :d_in]
    bmat = xbc[..., d_in:d_in + n].astype(jnp.float32)
    cmat = xbc[..., d_in + n:].astype(jnp.float32)
    hd = cfg.ssm_head_dim
    xh = xs.reshape(*xs.shape[:2], nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y = ssd_chunked(xh, dt, a, bmat, cmat, cfg.ssm_chunk)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(*xs.shape[:2], d_in).astype(dtp)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(dtp)


# ---------------------------------------------------------------------------
# Decode (recurrent) path

def init_ssm_cache(cfg, batch, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_kernel - 1, d_in + 2 * n), dtype),
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32),
    }


def decode_ssm(p, cfg, x, cache):
    """x: [B, 1, d]. O(1) recurrent update."""
    dtp = x.dtype
    proj = x[:, 0] @ p["in_proj"].astype(dtp)           # [B, ...]
    z, xbc, dt_raw, d_in, n, nh = _split_proj(cfg, proj)
    # conv over the cached window
    win = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # [B,K,ch]
    w = p["conv_w"].astype(dtp)
    conv = jax.nn.silu((win * w[None]).sum(1) + p["conv_b"].astype(dtp))
    xs = conv[..., :d_in]
    bvec = conv[..., d_in:d_in + n].astype(jnp.float32)
    cvec = conv[..., d_in + n:].astype(jnp.float32)
    hd = cfg.ssm_head_dim
    xh = xs.reshape(-1, nh, hd).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])                    # [B,H]
    # state: [B,H,P,N]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, bvec)
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cvec)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(-1, d_in).astype(dtp)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dtp))[:, None, :]
    return out, {"conv": win[:, 1:], "state": state}
