"""Attention variants: GQA (+ sliding window, qk-norm, biasless), MLA, cross.

Train path: full-sequence causal attention (optionally windowed).
Decode path: single-token query against a KV cache; for MLA the cache holds
the compressed c_kv/k_rope streams (paper-accurate kv_lora caching).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import Builder, apply_rope, rms_norm
from repro.distributed.sharding import shard_act

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA

def init_gqa(key, cfg):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b = Builder(key, jnp.dtype(cfg.param_dtype))
    b.dense("wq", (d, h, hd), ("embed", "heads", None))
    b.dense("wk", (d, kvh, hd), ("embed", "kv", None))
    b.dense("wv", (d, kvh, hd), ("embed", "kv", None))
    b.dense("wo", (h, hd, d), ("heads", None, "embed"), fan_in=h * hd)
    if cfg.qk_norm:
        b.const("q_norm", (hd,), (None,))
        b.const("k_norm", (hd,), (None,))
    return b.build()


def _qkv(p, cfg, x, positions):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, softcap=None):
    """q: [B,S,H,hd], k/v: [B,T,KV,hd]; grouped-query broadcast."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    q = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap is not None:
        scores = jnp.tanh(scores / softcap) * softcap
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, hd)


def causal_mask(s: int, window=None):
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m &= (i - j) < window
    return m[None, None, None]  # [1,1,1,S,T]


# Query-block size for the memory-bounded attention path.  Scores are
# materialized per block ([B,H,Qc,T] instead of [B,H,S,T]) — the structural
# fix that makes the 32k-seq shapes fit (DESIGN.md §5); exact softmax, no
# approximation.
QCHUNK = 2048


def _block_mask(i_idx, j_idx, causal, window):
    m = jnp.ones((i_idx.shape[0], j_idx.shape[0]), dtype=bool)
    if causal:
        m &= j_idx[None, :] <= i_idx[:, None]
    if window is not None:
        m &= (i_idx[:, None] - j_idx[None, :]) < window
    return m[None, None, None]  # [1,1,1,Qc,T]


def _sdpa_chunked(q, k, v, *, causal=True, window=None, softcap=None,
                  qchunk: int = QCHUNK):
    """Exact attention, scanned over query blocks: live scores are
    [B,KV,G,Qc,T].  Falls back to one block when S <= qchunk or S % qchunk."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    j_idx = jnp.arange(t)
    if s <= qchunk or s % qchunk != 0:
        mask = _block_mask(jnp.arange(s), j_idx, causal, window)
        return _sdpa(q, k, v, mask, softcap)
    nblk = s // qchunk
    qb = q.reshape(b, nblk, qchunk, h, hd).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nblk) * qchunk

    def body(_, inp):
        qi, start = inp
        i_idx = start + jnp.arange(qchunk)
        mask = _block_mask(i_idx, j_idx, causal, window)
        return None, _sdpa(qi, k, v, mask, softcap)

    _, out = jax.lax.scan(body, None, (qb, starts))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def apply_gqa(p, cfg, x, positions, window=None):
    q, k, v = _qkv(p, cfg, x, positions)
    # optional context parallelism: queries sharded over "model", K/V
    # all-gathered (cheap for GQA) — see activation_sharding(attn_seq_parallel)
    q = shard_act(q, "attn_q")
    if (cfg.use_flash_attention and window is None
            and cfg.attn_logit_softcap is None
            and x.shape[1] % 128 == 0):
        from repro.kernels.flash_attention import flash_gqa
        out = flash_gqa(q, k, v, causal=True,
                        bq=min(512, x.shape[1]), bk=min(512, x.shape[1]))
    else:
        out = _sdpa_chunked(q, k, v, causal=True, window=window,
                            softcap=cfg.attn_logit_softcap)
    out = shard_act(out, "attn_q")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def gqa_cache_len(max_len: int, window=None) -> int:
    """Ring-buffer length: sliding-window layers cache only ~window
    positions (128-aligned) — at 500k context this is a ~1000x cache
    memory/compute saving for gemma3-style local layers."""
    if window is None:
        return max_len
    return min(max_len, max((window + 127) // 128 * 128, 128))


def init_gqa_cache(cfg, batch, max_len, dtype, window=None):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    t_buf = gqa_cache_len(max_len, window)
    shape = (batch, t_buf, kvh, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_gqa(p, cfg, x, cache, pos, window=None):
    """x: [B,1,d]; pos: scalar current position. Returns (out, new_cache).

    The cache is a ring buffer of length t_buf <= max_len: slot
    ``pos % t_buf`` holds the newest entry and each slot j's global
    position is recovered as ``pos - ((pos - j) mod t_buf)``.  With
    t_buf == max_len this degenerates to the plain linear cache."""
    positions = pos[None, None] if jnp.ndim(pos) == 0 else pos[:, None]
    q, k, v = _qkv(p, cfg, x, positions.astype(jnp.int32))
    t_buf = cache["k"].shape[1]
    slot = pos % t_buf
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    j = jnp.arange(t_buf)[None, :]
    gpos = pos - ((pos - j) % t_buf)
    mask = gpos >= 0
    if window is not None:
        mask &= (pos - gpos) < window
    mask = mask[None, None, None]                       # [1,1,1,1,Tb]
    out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), mask,
                cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank compressed KV, rope/nope split

def init_mla(key, cfg):
    d, h = cfg.d_model, cfg.num_heads
    r, qr, qn, vd = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    b = Builder(key, jnp.dtype(cfg.param_dtype))
    b.dense("wq", (d, h, qn + qr), ("embed", "heads", None))
    b.dense("wkv_down", (d, r + qr), ("embed", "lora"))
    b.dense("wk_up", (r, h, qn), ("lora", "heads", None))
    b.dense("wv_up", (r, h, vd), ("lora", "heads", None))
    b.dense("wo", (h, vd, d), ("heads", None, "embed"), fan_in=h * vd)
    b.const("kv_norm", (r,), (None,))
    return b.build()


def _mla_qc(p, cfg, x, positions):
    dt = x.dtype
    r, qr, qn = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    down = x @ p["wkv_down"].astype(dt)                  # [B,S,r+qr]
    c_kv, k_rope = down[..., :r], down[..., r:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, mask):
    """Absorbed-weight MLA attention: score in compressed space."""
    dt = q_nope.dtype
    qn = cfg.qk_nope_dim
    # absorb wk_up into the query: q_c [B,S,H,r]
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_up"].astype(dt))
    scores = (
        jnp.einsum("bshr,btr->bhst", q_c, c_kv)
        + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)
    ).astype(jnp.float32) / math.sqrt(qn + cfg.qk_rope_dim)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(dt)
    ctx = jnp.einsum("bhst,btr->bshr", w, c_kv)           # compressed context
    out = jnp.einsum("bshr,rhv->bshv", ctx, p["wv_up"].astype(dt))
    return jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(dt))


def apply_mla(p, cfg, x, positions, qchunk: int = QCHUNK):
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, cfg, x, positions)
    b, s = x.shape[0], x.shape[1]
    j_idx = jnp.arange(s)
    if s <= qchunk or s % qchunk != 0:
        mask = (j_idx[None, :] <= j_idx[:, None])[None, None]
        return _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, mask)
    nblk = s // qchunk
    h = q_nope.shape[2]
    qn_b = q_nope.reshape(b, nblk, qchunk, h, -1).transpose(1, 0, 2, 3, 4)
    qr_b = q_rope.reshape(b, nblk, qchunk, h, -1).transpose(1, 0, 2, 3, 4)
    starts = jnp.arange(nblk) * qchunk

    def body(_, inp):
        qn_i, qr_i, start = inp
        i_idx = start + jnp.arange(qchunk)
        mask = (j_idx[None, :] <= i_idx[:, None])[None, None]
        return None, _mla_attend(p, cfg, qn_i, qr_i, c_kv, k_rope, mask)

    _, out = jax.lax.scan(body, None, (qn_b, qr_b, starts))
    return out.transpose(1, 0, 2, 3).reshape(b, s, -1)


def init_mla_cache(cfg, batch, max_len, dtype):
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def decode_mla(p, cfg, x, cache, pos):
    positions = pos[None, None].astype(jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _mla_qc(p, cfg, x, positions)
    ck = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), pos, axis=1)
    t = ck.shape[1]
    mask = (jnp.arange(t)[None, :] <= pos)[None, None]
    y = _mla_attend(p, cfg, q_nope, q_rope, ck.astype(x.dtype),
                    kr.astype(x.dtype), mask)
    return y, {"c_kv": ck, "k_rope": kr}


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec)

def init_cross(key, cfg):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b = Builder(key, jnp.dtype(cfg.param_dtype))
    b.dense("wq", (d, h, hd), ("embed", "heads", None))
    b.dense("wk", (d, kvh, hd), ("embed", "kv", None))
    b.dense("wv", (d, kvh, hd), ("embed", "kv", None))
    b.dense("wo", (h, hd, d), ("heads", None, "embed"), fan_in=h * hd)
    return b.build()


def cross_kv(p, enc_out):
    dt = enc_out.dtype
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"].astype(dt))
    return k, v


def apply_cross(p, cfg, x, enc_kv):
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    out = _sdpa_chunked(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Bidirectional self-attention (encoder)

def apply_bidir(p, cfg, x, positions):
    q, k, v = _qkv(p, cfg, x, positions)
    out = _sdpa_chunked(q, k, v, causal=False,
                        softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
