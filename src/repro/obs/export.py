"""Render measured traces in the simulator's event vocabulary.

:func:`trace_view` reshapes a :class:`~repro.obs.trace.TraceRecorder`
into the duck type :func:`repro.core.analytics.chrome_trace` consumes —
a ``timeline`` of ``(engine, start, end, label)`` spans in seconds plus
``makespan``/``tflops`` — using the *same* engine names and labels the
simulators emit (``h2d``/``cmp``/``d2h``/``dsk`` at ndev=1;
``d{d}:h2d|cmp|d2h``, shared ``link`` and ``dsk``, and ``d{d}:pipe``
ahead/trail lanes at lookahead>0 for ndev>1).  That shared vocabulary is
the point: a measured chrome trace opens side-by-side with the simulated
one and the lanes line up.

:func:`chrome_trace_measured` is the one-call path to chrome://tracing
JSON; :func:`write_jsonl` emits the raw spans as a JSON-lines structured
event log (one object per line — greppable, streamable, no schema
beyond the :class:`~repro.obs.trace.Span` fields).
"""
from __future__ import annotations

import json

_COMPUTE = {"syrk", "gemm", "potrf", "trsm"}
# dispatch phases emitted ahead of the trailing update (must match
# analytics.simulate_multi's _AHEAD_PHASES)
_AHEAD_PHASES = {"push", "recv-ahead", "advance"}


class _TraceView:
    """Measured-trace adapter satisfying the ``chrome_trace`` duck type
    (``timeline`` + ``makespan`` + ``tflops``)."""

    def __init__(self, timeline, makespan, flops_useful):
        self.timeline = timeline
        self.makespan = makespan
        self.flops_useful = flops_useful

    @property
    def tflops(self) -> float:
        if self.makespan <= 0:
            return 0.0
        return self.flops_useful / self.makespan / 1e12


def _engine_label(span, ndev):
    """Map one measured span onto the simulator's (engine, label) pair."""
    k, d, i, j = span.kind, span.device, span.i, span.j
    if ndev == 1:
        if k == "load":
            return "h2d", f"L{i},{j}"
        if k == "store":
            return "d2h", f"S{i},{j}"
        if k == "fetch":
            return "dsk", f"F{i},{j}"
        if k == "spill":
            return "dsk", f"W{i},{j}"
        return "cmp", k if k in _COMPUTE else k
    if k == "load":
        return f"d{d}:h2d", f"L{i},{j}"
    if k == "store":
        return f"d{d}:d2h", f"S{i},{j}"
    if k == "fetch":
        return "dsk", f"F{i},{j}@d{d}"
    if k == "spill":
        return "dsk", f"W{i},{j}@d{d}"
    if k == "recv":
        return "link", f"B{i},{j}->d{d}"
    return f"d{d}:cmp", k


def trace_view(trace) -> _TraceView:
    """Build a simulator-shaped view of a measured trace.

    Spans are rebased to the trace's first start (``t=0``) and converted
    to seconds; engines/labels follow the simulator vocabulary for the
    trace's ``meta["ndev"]`` (inferred from span devices when unset).
    At ``lookahead > 0`` every compute span is mirrored onto its
    device's ``d{d}:pipe`` lane with the ``ahead:``/``trail:`` prefix
    :func:`~repro.core.analytics.chrome_trace` colors.
    """
    spans = trace.spans
    meta = getattr(trace, "meta", {}) or {}
    ndev = meta.get("ndev") or (max((s.device for s in spans), default=0) + 1)
    lookahead = meta.get("lookahead", 0)
    if not spans:
        return _TraceView([], 0.0, 0.0)
    t0 = min(s.t_start for s in spans)
    timeline = []
    for s in spans:
        engine, label = _engine_label(s, ndev)
        start = (s.t_start - t0) / 1e9
        end = (s.t_end - t0) / 1e9
        timeline.append((engine, start, end, label))
        if ndev > 1 and lookahead > 0 and s.kind in _COMPUTE:
            tag = "ahead" if s.phase in _AHEAD_PHASES else "trail"
            timeline.append((f"d{s.device}:pipe", start, end,
                             f"{tag}:{s.kind}"))
    makespan = max(e for _, _, e, _ in timeline)
    n = meta.get("n", 0)
    return _TraceView(timeline, makespan, n**3 / 3.0)


def chrome_trace_measured(trace, path=None) -> dict:
    """Export a measured trace as chrome://tracing JSON (reusing
    :func:`repro.core.analytics.chrome_trace`'s event emission, so the
    lanes/colors match the simulated traces).  Returns the trace dict;
    with ``path`` it is also written there."""
    from repro.core.analytics import chrome_trace
    view = trace_view(trace)
    if not view.timeline:
        raise ValueError("empty trace: run factor(..., trace=recorder) "
                         "before exporting")
    return chrome_trace(view, path)


def write_jsonl(trace, path) -> int:
    """Write the trace as a JSON-lines event log: one header line with
    the run ``meta`` + ``dropped``, then one object per span.  Returns
    the number of span lines written."""
    spans = trace.spans
    meta = getattr(trace, "meta", {}) or {}
    with open(path, "w") as f:
        f.write(json.dumps({"event": "meta", "meta": meta,
                            "spans": len(spans),
                            "dropped": getattr(trace, "dropped", 0)}) + "\n")
        for s in spans:
            f.write(json.dumps({
                "event": "span", "op_index": s.op_index, "kind": s.kind,
                "device": s.device, "t_start": s.t_start, "t_end": s.t_end,
                "bytes": s.bytes, "cls": s.cls, "i": s.i, "j": s.j,
                "phase": s.phase,
            }) + "\n")
    return len(spans)
