"""Process-wide counter/gauge registry: one snapshot for every subsystem.

Before this module, the repo had three ad-hoc stats surfaces — the plan
cache (``repro.plan_cache_stats()``), the executors (``jit_traces``,
``transfer_stats()``, spill fetch/spill bytes), and the service layer
(``serve.metrics``).  :data:`REGISTRY` absorbs them: solvers bump
counters as they execute, long-lived components register *source*
callables that are polled at snapshot time, and
:func:`repro.obs.snapshot` returns the union as one nested dict (with
:func:`render_text` as a text exposition format for scraping/logging).

Lock discipline: counter/gauge mutation and the registry's own state are
guarded by one lock; **source callables are invoked outside it** (they
take their own locks — e.g. ``ServiceMetrics.snapshot()`` — and calling
foreign code under a registry lock is how deadlocks are built).
"""
from __future__ import annotations

import threading


class MetricsRegistry:
    """Counters (monotonic), gauges (last-write-wins), and named source
    callables polled at snapshot time.  All methods are thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._sources: dict[str, object] = {}

    # -- mutation ----------------------------------------------------------
    def inc(self, name: str, delta: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + delta

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = value

    def register_source(self, name: str, fn) -> None:
        """Register ``fn`` (zero-arg, returns a dict) to be polled under
        ``name`` at every snapshot.  Re-registering a name overwrites —
        the latest component owns it."""
        with self._lock:
            self._sources[name] = fn

    def unregister_source(self, name: str, fn=None) -> None:
        """Drop a source; with ``fn`` given, only when it is still the
        registered callable (a replaced registration is left alone)."""
        with self._lock:
            if name in self._sources and (fn is None
                                          or self._sources[name] is fn):
                del self._sources[name]

    def clear(self) -> None:
        """Reset counters/gauges and drop all sources (tests)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._sources.clear()

    # -- export ------------------------------------------------------------
    def snapshot(self) -> dict:
        """``{"counters": {...}, "gauges": {...}, "sources": {name: dict}}``.

        Counters/gauges are copied under the lock; sources are polled
        *after* it is released.  A source that raises reports
        ``{"error": repr(exc)}`` instead of poisoning the snapshot.
        """
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            sources = dict(self._sources)
        polled = {}
        for name, fn in sources.items():
            try:
                polled[name] = fn()
            except Exception as exc:  # noqa: BLE001 — snapshot must not die
                polled[name] = {"error": repr(exc)}
        return {"counters": counters, "gauges": gauges, "sources": polled}

    def render_text(self) -> str:
        """Flat ``name value`` exposition (one metric per line, sorted;
        nested source dicts flatten with ``.`` separators; non-numeric
        leaves are skipped)."""
        snap = self.snapshot()
        lines = []

        def emit(prefix, value):
            if isinstance(value, dict):
                for k in sorted(value):
                    emit(f"{prefix}.{k}", value[k])
            elif isinstance(value, bool):
                lines.append(f"{prefix} {int(value)}")
            elif isinstance(value, (int, float)):
                lines.append(f"{prefix} {value:g}")

        for name in sorted(snap["counters"]):
            emit(name, snap["counters"][name])
        for name in sorted(snap["gauges"]):
            emit(name, snap["gauges"][name])
        for name in sorted(snap["sources"]):
            emit(name, snap["sources"][name])
        return "\n".join(lines) + "\n" if lines else ""


#: the process-wide registry every subsystem reports into
REGISTRY = MetricsRegistry()


def _plan_cache_source() -> dict:
    from repro.core import api  # lazy: obs must import without core
    return api.plan_cache_stats()


# the plan cache is process-global, so its source is registered at
# import time; serve/executors register theirs when instantiated
REGISTRY.register_source("plan_cache", _plan_cache_source)


def snapshot() -> dict:
    """Snapshot the process-wide registry (module-level convenience)."""
    return REGISTRY.snapshot()


def render_text() -> str:
    """Text exposition of the process-wide registry."""
    return REGISTRY.render_text()
