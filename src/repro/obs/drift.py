"""Model-vs-measured drift: align a measured trace against the simulator.

The traced executors and the event simulators replay the *same* static
op stream in the *same* dispatch order, so alignment is positional: the
k-th modeled span of the measured trace corresponds to the k-th span of
the predicted timeline.  The only bookkeeping is agreeing on which ops
produce spans — the simulators emit none for ALLOC/FREE/BCAST (and add
decorative ``d{d}:pipe`` lanes at lookahead>0), so the measured side
filters to :data:`MODELED_KINDS` and the predicted side drops pipe
lanes; after that both sequences must match kind-for-kind or the report
refuses (rather than attribute a GEMM's drift to a LOAD).

:func:`drift_report` produces a :class:`DriftReport`: per-op-kind
measured/predicted time ratios, the top-N mispredicted ops, both sides'
overlap efficiency (how much copy/disk/link time hides under compute),
and the total absolute per-op error — the scalar
``tune.calibrate(refine_from=trace)`` is scored against.

Caveat worth stating plainly: traced runs fence every op
(``block_until_ready``), so the *measured* overlap efficiency of a
traced run is genuinely ~0 — tracing serializes the engines it
observes.  Per-op durations and kind ratios are the trustworthy signal;
the measured-vs-predicted overlap gap quantifies what fencing forfeits.
"""
from __future__ import annotations

import dataclasses

#: op kinds the simulators model with a timeline span (everything else —
#: ALLOC/FREE/BCAST — is bookkeeping with no span to align against)
MODELED_KINDS = frozenset(
    {"load", "store", "fetch", "spill", "recv",
     "syrk", "gemm", "potrf", "trsm"})

_COPY_KINDS = frozenset({"load", "store", "fetch", "spill", "recv"})
_COMPUTE_KINDS = frozenset({"syrk", "gemm", "potrf", "trsm"})


def _predicted_ops(timeline) -> list:
    """Flatten a simulator timeline into ``(kind, duration_s)`` in op
    order, dropping the decorative ``:pipe`` lanes."""
    out = []
    for engine, start, end, label in timeline:
        if engine.endswith(":pipe"):
            continue
        if engine == "link":
            kind = "recv"
        elif engine == "dsk":
            kind = "fetch" if label.startswith("F") else "spill"
        elif engine.endswith("h2d") or engine == "h2d":
            kind = "load"
        elif engine.endswith("d2h") or engine == "d2h":
            kind = "store"
        else:                      # cmp lanes carry the kind as the label
            kind = label
        out.append((kind, end - start))
    return out


def _overlap_efficiency(makespan, compute_busy, copy_busy):
    """Fraction of copy/disk/link time hidden under compute: busy copy
    time minus the part of the makespan compute cannot cover, over total
    copy time.  ``None`` when there is no copy time to hide."""
    if copy_busy <= 0:
        return None
    exposed = max(makespan - compute_busy, 0.0)
    return max(copy_busy - exposed, 0.0) / copy_busy


@dataclasses.dataclass
class DriftReport:
    """Measured-vs-predicted comparison of one traced run."""
    nops: int                       # aligned (modeled) op count
    measured_makespan: float        # seconds, first start to last end
    predicted_makespan: float
    measured_total: float           # summed span durations, seconds
    predicted_total: float
    total_abs_error: float          # sum of |measured - predicted| per op
    per_kind: dict                  # kind -> {count, measured_s, predicted_s, ratio}
    top_mispredicted: list          # worst ops by |measured - predicted|
    measured_overlap_efficiency: float | None
    predicted_overlap_efficiency: float | None

    @property
    def makespan_ratio(self) -> float:
        return (self.measured_makespan / self.predicted_makespan
                if self.predicted_makespan > 0 else float("inf"))

    def summary(self) -> str:
        lines = [
            f"drift: {self.nops} ops, makespan measured "
            f"{self.measured_makespan * 1e3:.2f} ms vs predicted "
            f"{self.predicted_makespan * 1e3:.2f} ms "
            f"(x{self.makespan_ratio:.2f}), "
            f"total |error| {self.total_abs_error * 1e3:.2f} ms",
        ]
        for kind in sorted(self.per_kind):
            row = self.per_kind[kind]
            lines.append(
                f"  {kind:>6s}: n={row['count']:<4d} measured "
                f"{row['measured_s'] * 1e3:8.2f} ms  predicted "
                f"{row['predicted_s'] * 1e3:8.2f} ms  x{row['ratio']:.2f}")
        m, p = (self.measured_overlap_efficiency,
                self.predicted_overlap_efficiency)
        lines.append(
            "  overlap eff: measured "
            + ("n/a" if m is None else f"{m:.2f}")
            + " vs predicted "
            + ("n/a" if p is None else f"{p:.2f}")
            + " (traced runs fence per-op, so measured ~0 is expected)")
        for t in self.top_mispredicted:
            lines.append(
                f"  worst: op#{t['op_index']} {t['kind']}"
                f"({t['i']},{t['j']})@d{t['device']} measured "
                f"{t['measured_s'] * 1e6:.0f} us vs "
                f"{t['predicted_s'] * 1e6:.0f} us")
        return "\n".join(lines)


def drift_report(trace, predicted, top_n: int = 10) -> DriftReport:
    """Align a measured trace against a simulator result positionally.

    ``predicted`` is a :class:`~repro.core.analytics.SimResult` or
    :class:`~repro.core.analytics.MultiSimResult` produced from the
    *same schedule* with ``record_timeline=True``.  Raises ``ValueError``
    on a truncated trace (ring-buffer drops), an unrecorded timeline, or
    any positional kind mismatch — misalignment must fail loudly, never
    produce a subtly wrong report.
    """
    if getattr(trace, "dropped", 0):
        raise ValueError(
            f"trace dropped {trace.dropped} spans (ring buffer too small "
            f"for this schedule): raise TraceRecorder(capacity=...)")
    if not predicted.timeline:
        raise ValueError("predicted timeline not recorded: simulate with "
                         "record_timeline=True")
    measured = [s for s in trace.spans if s.kind in MODELED_KINDS]
    modeled = _predicted_ops(predicted.timeline)
    if len(measured) != len(modeled):
        raise ValueError(
            f"cannot align: {len(measured)} measured modeled spans vs "
            f"{len(modeled)} predicted — trace and simulation must come "
            f"from the same schedule (and one full traced run)")

    per_kind: dict = {}
    rows = []
    total_err = 0.0
    for pos, (span, (pkind, pdur)) in enumerate(zip(measured, modeled)):
        if span.kind != pkind:
            raise ValueError(
                f"kind mismatch at modeled op {pos}: measured "
                f"{span.kind!r} vs predicted {pkind!r} — dispatch orders "
                f"diverge, refusing to misattribute drift")
        mdur = span.duration_s
        err = abs(mdur - pdur)
        total_err += err
        agg = per_kind.setdefault(
            span.kind, {"count": 0, "measured_s": 0.0, "predicted_s": 0.0})
        agg["count"] += 1
        agg["measured_s"] += mdur
        agg["predicted_s"] += pdur
        rows.append({
            "op_index": span.op_index, "kind": span.kind,
            "i": span.i, "j": span.j, "device": span.device,
            "measured_s": mdur, "predicted_s": pdur, "abs_error_s": err,
        })
    for agg in per_kind.values():
        agg["ratio"] = (agg["measured_s"] / agg["predicted_s"]
                        if agg["predicted_s"] > 0 else float("inf"))

    m_make = ((max(s.t_end for s in measured)
               - min(s.t_start for s in measured)) / 1e9 if measured else 0.0)
    m_cmp = sum(s.duration_s for s in measured
                if s.kind in _COMPUTE_KINDS)
    m_copy = sum(s.duration_s for s in measured if s.kind in _COPY_KINDS)
    p_cmp = sum(d for k, d in modeled if k in _COMPUTE_KINDS)
    p_copy = sum(d for k, d in modeled if k in _COPY_KINDS)

    rows.sort(key=lambda r: r["abs_error_s"], reverse=True)
    return DriftReport(
        nops=len(measured),
        measured_makespan=m_make,
        predicted_makespan=predicted.makespan,
        measured_total=sum(r["measured_s"] for r in rows),
        predicted_total=sum(r["predicted_s"] for r in rows),
        total_abs_error=total_err,
        per_kind=per_kind,
        top_mispredicted=rows[:top_n],
        measured_overlap_efficiency=_overlap_efficiency(
            m_make, m_cmp, m_copy),
        predicted_overlap_efficiency=_overlap_efficiency(
            predicted.makespan, p_cmp, p_copy),
    )


def total_abs_error(trace, predicted) -> float:
    """Summed per-op |measured - predicted| seconds — the scalar a
    refined :class:`~repro.core.analytics.HardwareModel` must reduce
    (``tune.calibrate(refine_from=trace)`` acceptance check)."""
    return drift_report(trace, predicted, top_n=0).total_abs_error
