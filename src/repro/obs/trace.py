"""Measured per-op runtime tracing: the span recorder executors report to.

Every executor accepts a ``trace=`` recorder (threaded through
``OOCSolver.factor(a, trace=...)`` / ``plan().compile(trace=...)``) and,
when it is *active*, switches to a fenced op-by-op execution mode: each
schedule op is dispatched, the produced buffers are blocked on
(``jax.block_until_ready`` — without the fence, async dispatch would
timestamp queue insertion, not execution), and one :class:`Span` is
recorded.  The result is a *measured* timeline with exactly one span per
executed op, positionally aligned with the static schedule's dispatch
order — which is what lets :mod:`repro.obs.drift` compare it op-by-op
against the event simulator's prediction.

The default is :data:`NULL`, a :class:`NullRecorder` whose ``active``
flag is ``False``: executors test that one attribute and take their
ordinary (jitted / segment-batched) path, so untraced runs are
bit-identical to pre-obs behaviour with unchanged ``jit_traces``.

Timestamps are ``time.perf_counter_ns`` integers (monotonic,
process-local); :meth:`TraceRecorder.duration_s` and friends convert.
The buffer is a bounded ring (``capacity`` spans): tracing a schedule
larger than the ring keeps the *most recent* spans and counts the rest
in ``dropped`` — drift analysis refuses truncated traces rather than
misaligning silently.
"""
from __future__ import annotations

import time
from collections import deque
from typing import NamedTuple


class Span(NamedTuple):
    """One executed op: ``(op_index, kind, device, t_start, t_end, bytes)``
    plus alignment metadata (precision class name, tile coordinates, and
    the dispatch phase for pipelined multi-device schedules)."""
    op_index: int
    kind: str                # OpKind.value ("load", "gemm", "recv", ...)
    device: int              # executing device stream (0 for ndev=1)
    t_start: int             # time.perf_counter_ns
    t_end: int
    bytes: int               # transfer bytes (0 for compute/bookkeeping)
    cls: str = ""            # precision class name (plan.ladder[op.cls])
    i: int = -1              # tile row
    j: int = -1              # tile col
    phase: str = ""          # dispatch-chunk phase (lookahead pipelines)

    @property
    def duration_s(self) -> float:
        return (self.t_end - self.t_start) / 1e9


class TraceRecorder:
    """Bounded ring buffer of measured :class:`Span` records.

    Pass one to ``OOCSolver.factor(a, trace=rec)`` (or pin it at
    ``plan.compile(trace=rec)``) and the executor records one span per
    op it runs.  ``meta`` is stamped by the executor with the run's
    shape (``n``/``tb``/``ndev``/``policy``/``backend``/...), which is
    what :func:`repro.tune.calibrate` needs to turn spans back into
    kernel rates (``refine_from=``).

    Not thread-safe by design: one recorder traces one run.  Reuse
    across runs is fine — call :meth:`clear` between them, or let the
    spans of consecutive runs concatenate (``op_index`` restarts at 0).
    """

    #: default ring capacity — comfortably above any test/bench schedule,
    #: bounded so tracing a huge factorization cannot exhaust memory
    DEFAULT_CAPACITY = 1 << 20

    active = True

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._spans: deque[Span] = deque(maxlen=capacity)
        self.dropped = 0         # spans evicted by the ring bound
        self.meta: dict = {}     # run metadata stamped by the executor

    @staticmethod
    def now() -> int:
        """The recorder's clock: ``time.perf_counter_ns``."""
        return time.perf_counter_ns()

    def record(self, op_index: int, kind: str, device: int,
               t_start: int, t_end: int, nbytes: int, cls: str = "",
               i: int = -1, j: int = -1, phase: str = "") -> None:
        if len(self._spans) == self.capacity:
            self.dropped += 1
        self._spans.append(Span(op_index, kind, device, t_start, t_end,
                                nbytes, cls, i, j, phase))

    @property
    def spans(self) -> list[Span]:
        """The recorded spans, oldest first."""
        return list(self._spans)

    def __len__(self) -> int:
        return len(self._spans)

    def __bool__(self) -> bool:        # an empty recorder is still a recorder
        return True

    def clear(self) -> None:
        self._spans.clear()
        self.dropped = 0
        self.meta = {}

    # -- aggregate views ---------------------------------------------------
    def makespan_s(self) -> float:
        """Wall span of the trace (first start to last end), seconds."""
        if not self._spans:
            return 0.0
        t0 = min(s.t_start for s in self._spans)
        t1 = max(s.t_end for s in self._spans)
        return (t1 - t0) / 1e9

    def busy_s(self, kinds=None) -> float:
        """Summed span durations, optionally restricted to ``kinds``."""
        return sum(s.duration_s for s in self._spans
                   if kinds is None or s.kind in kinds)

    def by_kind(self) -> dict:
        """``{kind: (count, total_seconds, total_bytes)}``."""
        out: dict = {}
        for s in self._spans:
            c, t, b = out.get(s.kind, (0, 0.0, 0))
            out[s.kind] = (c + 1, t + s.duration_s, b + s.bytes)
        return out


class NullRecorder:
    """The zero-cost default: ``active`` is False, so executors never
    leave their ordinary (jitted) path — a ``trace=NULL`` run is the
    *same objects and code path* as ``trace=None``, checkable by
    identity, not timing."""

    active = False
    dropped = 0
    capacity = 0
    meta: dict = {}

    @staticmethod
    def now() -> int:
        return 0

    def record(self, *a, **kw) -> None:
        pass

    @property
    def spans(self) -> list:
        return []

    def __len__(self) -> int:
        return 0

    def clear(self) -> None:
        pass


#: process-wide no-op recorder; ``resolve(None) is NULL``
NULL = NullRecorder()


def resolve(trace) -> "TraceRecorder | NullRecorder":
    """Normalize a ``trace=`` argument: ``None`` -> the :data:`NULL`
    singleton, anything else passes through unchanged."""
    return NULL if trace is None else trace


def is_active(trace) -> bool:
    """True when ``trace`` is a recorder that wants spans (executors'
    one-attribute fast path; ``None`` and :data:`NULL` are inactive)."""
    return trace is not None and getattr(trace, "active", False)
