"""repro.obs — measured runtime tracing, drift analysis, and metrics.

The observability substrate the paper's timeline claims are checked
against.  Three layers, one import:

- :mod:`repro.obs.trace` — :class:`TraceRecorder` ring buffer; pass one
  as ``OOCSolver.factor(a, trace=rec)`` and every executor records one
  measured :class:`Span` per schedule op (``block_until_ready``-fenced).
  The :data:`NULL` recorder is the zero-cost default.
- :mod:`repro.obs.export` / :mod:`repro.obs.drift` — render measured
  traces as chrome://tracing JSON in the simulator's lane vocabulary,
  and align them op-by-op against ``simulate``/``simulate_multi`` into
  a :class:`DriftReport` (per-kind ratios, top mispredictions, overlap
  efficiency).  ``repro.tune.calibrate(refine_from=trace)`` closes the
  loop by refitting the :class:`~repro.core.analytics.HardwareModel`.
- :mod:`repro.obs.metrics` — the process-wide :data:`REGISTRY`
  absorbing plan-cache stats, executor counters, and serve metrics
  under one :func:`snapshot` / :func:`render_text`.
"""
from .drift import MODELED_KINDS, DriftReport, drift_report, total_abs_error
from .export import chrome_trace_measured, trace_view, write_jsonl
from .metrics import REGISTRY, MetricsRegistry, render_text, snapshot
from .trace import NULL, NullRecorder, Span, TraceRecorder, is_active, resolve

__all__ = [
    "TraceRecorder", "NullRecorder", "Span", "NULL", "resolve", "is_active",
    "chrome_trace_measured", "trace_view", "write_jsonl",
    "DriftReport", "drift_report", "total_abs_error", "MODELED_KINDS",
    "MetricsRegistry", "REGISTRY", "snapshot", "render_text",
]
