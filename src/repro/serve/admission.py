"""Device-memory admission control for the serve front end.

A plan's device footprint is static — the schedule pins ``cache_slots``
managed slots plus (multi-device) its RECV panel region, all ``tb x tb``
f64 tiles — so admission is exact bookkeeping, not heuristics: the
controller reads each plan's per-device slot requirement straight off
the built schedule (:meth:`MultiDeviceSchedule.stream_nslots`), converts
to bytes, and reserves against :attr:`HardwareModel.mem_bytes`.

Decisions, in the order the service applies them:

* **reject** — a plan whose slot requirement alone exceeds
  :meth:`HardwareModel.max_cache_slots` for its tile size can *never*
  run on this hardware; the request future fails immediately with
  :class:`AdmissionError` (same eager-failure philosophy as
  ``CholeskyConfig``'s validation).
* **queue** — a plan that fits alone but would oversubscribe the
  currently reserved memory stays queued; its session is skipped by the
  dispatch loop until another tenant releases (session close).
* **admit** — memory is reserved for the session until it is closed;
  the reservation covers the factored tile working set for every
  subsequent request of that session, so steady-state traffic never
  re-negotiates.

With no hardware model (``hw=None``) or an unknown capacity
(``mem_bytes == 0``) the controller admits everything — serving on the
host replay backend has no device budget to protect.
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core.analytics import HardwareModel


class AdmissionError(RuntimeError):
    """Request refused by admission control (plan cannot fit)."""


def plan_device_slots(plan) -> int:
    """Worst per-device slot count a :class:`CholeskyPlan`'s schedule
    pins (cache table + panel region), read off the built streams."""
    msched = plan.schedule
    return max(msched.stream_nslots(d) for d in range(msched.ndev))


def plan_device_bytes(plan) -> int:
    """Per-device reservation for one in-flight plan: its slot count in
    ``tb x tb`` f64 tiles (the executor's device-buffer dtype ceiling)."""
    return plan_device_slots(plan) * plan.config.tb * plan.config.tb * 8


class AdmissionController:
    """Tracks per-session device-memory reservations against one
    :class:`HardwareModel`; see the module docstring for the policy."""

    def __init__(self, hw: Optional[HardwareModel] = None):
        self.hw = hw
        self._lock = threading.Lock()
        self._reserved: dict = {}      # session key -> bytes

    @property
    def unbounded(self) -> bool:
        return self.hw is None or self.hw.mem_bytes <= 0

    def reserved_bytes(self) -> int:
        with self._lock:
            return sum(self._reserved.values())

    def check_feasible(self, plan) -> None:
        """Raise :class:`AdmissionError` iff ``plan`` can never fit
        (its slot pin count exceeds the device's total slot capacity)."""
        if self.unbounded:
            return
        tb = plan.config.tb
        need = plan_device_slots(plan)
        cap = self.hw.max_cache_slots(tb)
        if need > cap:
            raise AdmissionError(
                f"plan needs {need} device slots of {tb}x{tb} f64 tiles "
                f"({plan_device_bytes(plan) / 1e9:.2f} GB) but "
                f"hw={self.hw.name!r} fits at most {cap} "
                f"(mem_bytes={self.hw.mem_bytes / 1e9:.1f} GB); shrink "
                f"tb/cache_slots or serve on larger hardware")

    def try_reserve(self, key: str, plan) -> bool:
        """Reserve ``plan``'s footprint for session ``key``; False means
        currently oversubscribed (caller keeps the session queued).
        Idempotent: a session already holding a reservation is admitted."""
        if self.unbounded:
            return True
        need = plan_device_bytes(plan)
        with self._lock:
            if key in self._reserved:
                return True
            if sum(self._reserved.values()) + need > self.hw.mem_bytes:
                return False
            self._reserved[key] = need
            return True

    def release(self, key: str) -> None:
        with self._lock:
            self._reserved.pop(key, None)
