"""`repro.serve` — solver-as-a-service over the static-plan machinery.

The planner API amortizes schedule construction and jit tracing across
same-shape calls; this package exploits that at traffic scale (the
ROADMAP's "millions of users" direction): an admission-controlled
request queue in front of a pool of per-session
:class:`~repro.core.api.OOCSolver`\\ s, with multi-RHS batching of
concurrent solves and first-class observability.

    from repro.serve import SolverService

    with SolverService(workers=4) as svc:
        s = svc.session("tenant-a", n, tb=64, policy="v3")
        s.factor(sigma)                       # sync facade, or *_async
        x = s.solve(b)                        # coalesced under load
        print(svc.metrics.snapshot())

Layers (docs/serving.md walks the request lifecycle):

* :mod:`~repro.serve.service` — front end, sessions, worker pool
* :mod:`~repro.serve.batching` — multi-RHS solve coalescing
* :mod:`~repro.serve.admission` — device-memory admission control
* :mod:`~repro.serve.metrics` — latency/queue/batch/cache counters and
  a chrome-trace timeline
"""
from .admission import (AdmissionController, AdmissionError,
                        plan_device_bytes, plan_device_slots)
from .batching import coalesce_head, split_solutions, stack_rhs
from .metrics import RequestRecord, ServiceMetrics, ServiceTimeline
from .service import Session, SolverService

__all__ = [
    "SolverService", "Session",
    "AdmissionController", "AdmissionError",
    "plan_device_slots", "plan_device_bytes",
    "stack_rhs", "split_solutions", "coalesce_head",
    "ServiceMetrics", "ServiceTimeline", "RequestRecord",
]
