"""Multi-RHS coalescing: many queued solves -> one stacked sweep.

The OOC solve cost is dominated by streaming the factor's tiles through
host memory, and that traffic is identical for 1 or ``k`` right-hand
sides (``repro.core.solve`` sweeps once per call, with the per-block
update a ``(tb, tb) @ (tb, k)`` GEMM).  The batcher therefore turns a
burst of concurrent single-RHS ``solve``/``solve_lower`` requests
against the *same* factor into one stacked ``solve(B)`` call:

* :func:`coalesce_head` decides, under the service lock, how many
  requests at the head of a session queue ride together — contiguous
  same-kind solves only (a ``factor`` in between is a barrier: requests
  after it target a different matrix), capped at ``max_batch`` total
  columns.  A batch that could still grow (queue tail, under the cap)
  is held back until the oldest member's deadline
  (``arrival + batch_window``) expires — the classic
  latency-for-throughput window, sized in milliseconds.
* :func:`stack_rhs` / :func:`split_solutions` do the column packing and
  unpacking around the solver call, preserving each request's original
  rhs shape (vector in, vector out).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

#: request kinds the batcher may coalesce (same stacked code path)
BATCHABLE = ("solve", "solve_lower")


def coalesce_head(queue: Sequence, now: float, max_batch: int,
                  batch_window: float) -> Tuple[int, Optional[float]]:
    """How many head-of-queue requests execute as one work item.

    ``queue`` holds request objects with ``kind``/``k``/``t_deadline``
    attributes.  Returns ``(count, hold_until)``: ``count >= 1`` means
    the first ``count`` requests form the next work item; ``count == 0``
    means the batch at the head should be *held* until time
    ``hold_until`` (it may still grow and no member's window has
    expired).  Non-batchable head kinds always dispatch alone, as does
    everything when batching is disabled (``max_batch <= 1`` or a
    non-positive window) — the one-RHS-at-a-time baseline.
    """
    head = queue[0]
    if head.kind not in BATCHABLE or max_batch <= 1 or batch_window <= 0:
        return 1, None
    count, cols = _take(queue, head.kind, max_batch)
    if (count == len(queue) and cols < max_batch
            and now < head.t_deadline):
        # still growable and within the window: hold for more arrivals
        return 0, head.t_deadline
    return count, None


def _take(queue: Sequence, kind: str, max_batch: int) -> Tuple[int, int]:
    """(requests, total columns) of the contiguous same-kind head run."""
    count = cols = 0
    for req in queue:
        if req.kind != kind or (cols and cols + req.k > max_batch):
            break
        count += 1
        cols += req.k
    return count, cols


def stack_rhs(rhss: List[np.ndarray]) -> Tuple[np.ndarray, List[Tuple[int,
                                                                      bool]]]:
    """Pack per-request rhs arrays into one ``(n, K)`` column stack.

    Returns the stack and per-request ``(k, was_vector)`` so
    :func:`split_solutions` can restore original shapes.  All rhss must
    share the row count (the service validated each against the plan).
    """
    cols, splits = [], []
    for b in rhss:
        b = np.asarray(b, dtype=np.float64)
        if b.ndim == 1:
            cols.append(b[:, None])
            splits.append((1, True))
        else:
            cols.append(b)
            splits.append((b.shape[1], False))
    return np.concatenate(cols, axis=1), splits


def split_solutions(x: np.ndarray,
                    splits: List[Tuple[int, bool]]) -> List[np.ndarray]:
    """Slice the stacked solution back into per-request results."""
    out, c = [], 0
    for k, was_vector in splits:
        part = x[:, c:c + k]
        out.append(part[:, 0] if was_vector else part)
        c += k
    return out
