"""Service observability: counters, latency percentiles, and a timeline.

:class:`ServiceMetrics` is the single sink every serve component reports
into — the front end on submit/reject, the workers on execute.  It is
deliberately boring: a lock, monotonically growing counters, and a list
of per-request records; :meth:`snapshot` reduces them to the metrics
schema documented in docs/serving.md (latency p50/p99, queue depth,
batch occupancy, plan-cache hit/miss deltas, solver reuse), and
:meth:`timeline` re-expresses the executed batches as a
``(engine, start, end, label)`` span list shaped exactly like the event
simulator's, so :func:`repro.core.analytics.chrome_trace` renders a
served traffic window with the same tooling as a simulated
factorization (one track per worker thread).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional

import numpy as np

from repro.core import api as _api


@dataclasses.dataclass
class RequestRecord:
    """One completed (or rejected) request, timestamps in service time."""
    kind: str
    session: str
    worker: int = -1
    k: int = 1                 # RHS columns this request carried
    batch_k: int = 1           # total columns of the batch it rode in
    t_arrive: float = 0.0
    t_start: float = 0.0
    t_end: float = 0.0
    ok: bool = True

    @property
    def latency(self) -> float:
        return self.t_end - self.t_arrive


@dataclasses.dataclass
class ServiceTimeline:
    """Span view of a traffic window; duck-compatible with the simulator
    results that :func:`repro.core.analytics.chrome_trace` accepts."""
    timeline: list
    makespan: float
    tflops: float = 0.0


def _pct(xs, q):
    """Percentile of a series, or ``None`` when nothing was recorded —
    an empty window reads as "no data", never as a zero-latency claim."""
    return float(np.percentile(np.asarray(xs), q)) if xs else None


class ServiceMetrics:
    """Thread-safe metrics sink shared by the service front end and its
    workers; see module docstring for the consumer surface."""

    def __init__(self):
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self._records: List[RequestRecord] = []
        self._rejected = 0
        self._submitted = 0
        self._kind_counts: dict = {}
        self._queue_depth_samples: List[int] = []
        self._batches = 0            # executed work items
        self._batched_solves = 0     # work items coalescing >= 2 requests
        self._batch_occupancy: List[int] = []   # RHS columns per solve batch
        self._solver_compiles = 0    # sessions that built their solver
        self._solver_reuse = 0       # requests served by an existing solver
        self._cache0 = _api.plan_cache_stats()

    def now(self) -> float:
        """Service-relative clock (seconds since metrics creation)."""
        return time.perf_counter() - self._t0

    # -- front end ---------------------------------------------------------
    def on_submit(self, kind: str, queue_depth: int) -> None:
        with self._lock:
            self._submitted += 1
            self._kind_counts[kind] = self._kind_counts.get(kind, 0) + 1
            self._queue_depth_samples.append(queue_depth)

    def on_reject(self, kind: str, session: str) -> None:
        with self._lock:
            self._rejected += 1
            now = self.now()
            self._records.append(RequestRecord(
                kind=kind, session=session, t_arrive=now, t_start=now,
                t_end=now, ok=False))

    # -- workers -----------------------------------------------------------
    def on_solver_compile(self) -> None:
        with self._lock:
            self._solver_compiles += 1

    def on_execute(self, worker: int, records: List[RequestRecord],
                   solve_batch: bool, reused_solver: bool) -> None:
        """Record one executed work item (possibly a coalesced batch)."""
        with self._lock:
            self._batches += 1
            if solve_batch:
                self._batch_occupancy.append(sum(r.k for r in records))
                if len(records) >= 2:
                    self._batched_solves += 1
            if reused_solver:
                self._solver_reuse += len(records)
            self._records.extend(records)

    # -- consumers ---------------------------------------------------------
    def snapshot(self) -> dict:
        """Reduce everything recorded so far to one JSON-able dict.

        Latency/occupancy aggregates are ``None`` (not 0.0) when the
        window holds no completed requests.  The plan-cache poll happens
        *outside* ``self._lock`` — it takes the cache's own lock, and
        nesting foreign locks inside ours is how deadlocks are born.
        """
        cache = _api.plan_cache_stats()
        with self._lock:
            recs = [r for r in self._records if r.ok]
            lat = [r.latency for r in recs]
            occ = list(self._batch_occupancy)
            t_lo = min((r.t_arrive for r in recs), default=0.0)
            t_hi = max((r.t_end for r in recs), default=0.0)
            wall = max(t_hi - t_lo, 1e-12)
            solves = sum(r.k for r in recs
                         if r.kind in ("solve", "solve_lower"))
            return {
                "submitted": self._submitted,
                "completed": len(recs),
                "rejected": self._rejected,
                "kinds": dict(self._kind_counts),
                "latency_s": {"p50": _pct(lat, 50), "p99": _pct(lat, 99),
                              "mean": float(np.mean(lat)) if lat else None,
                              "max": max(lat, default=None)},
                "queue_depth": {
                    "max": max(self._queue_depth_samples, default=0),
                    "mean": (float(np.mean(self._queue_depth_samples))
                             if self._queue_depth_samples else 0.0)},
                "batch": {"batches": self._batches,
                          "batched_solves": self._batched_solves,
                          "max_occupancy": max(occ, default=0),
                          "mean_occupancy": (float(np.mean(occ))
                                             if occ else 0.0)},
                "plan_cache": {
                    "hits": cache["hits"] - self._cache0["hits"],
                    "misses": cache["misses"] - self._cache0["misses"],
                    "size": cache["size"]},
                "solver": {"compiles": self._solver_compiles,
                           "reuse": self._solver_reuse},
                "wall_s": wall,
                "solves_per_s": solves / wall,
                "requests_per_s": len(recs) / wall,
            }

    def timeline(self) -> ServiceTimeline:
        """Executed-request spans, one engine track per worker thread."""
        with self._lock:
            spans = [(f"worker{r.worker}", r.t_start, r.t_end,
                      f"{r.kind}:{r.session}"
                      + (f" k={r.batch_k}" if r.batch_k > 1 else ""))
                     for r in self._records if r.ok and r.worker >= 0]
            makespan = max((r.t_end for r in self._records if r.ok),
                           default=0.0)
        return ServiceTimeline(timeline=spans, makespan=makespan)
