"""`SolverService` — concurrent, plan-cached factor/solve serving.

The paper's motivating application (geospatial Matérn MLE) does not
issue one factorization: every optimizer step fans out many correlated
``factor``/``solve``/``logdet`` calls.  This module turns the planner
API into a front end for exactly that request stream:

* **Sessions** are tenants.  ``service.session(key, n, config)`` routes
  through the process-wide ``(n, config)`` plan cache (`repro.plan`), so
  same-shape tenants share one static schedule and one jitted executor;
  each session owns its *own* :class:`~repro.core.api.OOCSolver`,
  because a solver is single-factor stateful (``factor()`` overwrites
  the tile store — see its docstring).
* **The request queue** is per-session FIFO with one in-flight work
  item per session (serial semantics per tenant, concurrency across
  tenants) and round-robin dispatch across session keys (a flooding
  tenant cannot starve the others).
* **Batching**: bursts of single-RHS ``solve``/``solve_lower`` against
  the same factor coalesce into one stacked ``solve(B)`` within a
  deadline window (:mod:`repro.serve.batching`).
* **Admission** reserves device memory per in-flight plan against the
  service's :class:`~repro.core.analytics.HardwareModel` and rejects
  plans that can never fit (:mod:`repro.serve.admission`).
* **Metrics**: every submit/execute lands in
  :class:`~repro.serve.metrics.ServiceMetrics`
  (``service.metrics.snapshot()`` / chrome-trace timeline).

Requests return :class:`concurrent.futures.Future`; each session also
exposes a synchronous facade that duck-types the solver surface, so
e.g. :func:`repro.geo.likelihood.gaussian_loglik` evaluates against a
served session exactly as it does against a local solver.  Workers are
threads: the heavy lifting (BLAS sweeps, jitted executors) releases the
GIL, and thread workers let every tenant share one plan cache and one
device pool.  See docs/serving.md for the request lifecycle.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from concurrent.futures import Future
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core import api as _api
from repro.core.analytics import HardwareModel
from repro.core.api import CholeskyConfig

from .admission import AdmissionController, AdmissionError
from .batching import BATCHABLE, coalesce_head, split_solutions, stack_rhs
from .metrics import RequestRecord, ServiceMetrics

KINDS = ("factor", "solve", "solve_lower", "logdet", "factor_solve")


@dataclasses.dataclass
class _Request:
    kind: str
    payload: Any
    future: Future
    t_arrive: float
    t_deadline: float     # batch-window deadline (batchable kinds only)
    k: int = 1            # RHS columns carried


class Session:
    """One tenant's handle: per-session FIFO ordering, one pooled solver.

    Async methods (``*_async``) return futures; the plain methods block
    on them and — together with ``n`` — make a session duck-compatible
    with :class:`~repro.core.api.OOCSolver` for read-style consumers
    like :func:`repro.geo.likelihood.gaussian_loglik`.
    """

    def __init__(self, service: "SolverService", key: str, n: int,
                 config: CholeskyConfig, plan):
        self._service = service
        self.key = key
        self.n = n
        self.config = config
        self._plan = plan            # shared CholeskyPlan (plan cache)
        self._solver = None          # this session's pooled OOCSolver
        self._factored = False
        self._queue: collections.deque = collections.deque()
        self._in_flight = False
        self._closed = False

    # -- async surface -----------------------------------------------------
    def factor_async(self, a: np.ndarray,
                     materialize: bool = False) -> Future:
        a = np.asarray(a, dtype=np.float64)
        if a.shape != (self.n, self.n):
            raise ValueError(f"matrix shape {a.shape} does not match the "
                             f"session's n={self.n}")
        return self._service._submit(self, "factor", (a, materialize))

    def solve_async(self, b: np.ndarray) -> Future:
        return self._service._submit(self, "solve", self._rhs(b),
                                     k=self._cols(b))

    def solve_lower_async(self, b: np.ndarray) -> Future:
        return self._service._submit(self, "solve_lower", self._rhs(b),
                                     k=self._cols(b))

    def solve_batch_async(self, b: np.ndarray) -> Future:
        """Explicitly stacked ``(n, k)`` request (one future for all k)."""
        b = self._rhs(b)
        if b.ndim != 2:
            raise ValueError(f"solve_batch expects stacked columns (n, k), "
                             f"got shape {b.shape}")
        return self._service._submit(self, "solve", b, k=b.shape[1])

    def logdet_async(self) -> Future:
        return self._service._submit(self, "logdet", None)

    def factor_solve_async(self, a: np.ndarray, b: np.ndarray,
                           materialize: bool = False) -> Future:
        """Fused factor+solve: one queue slot, no inter-request gap."""
        a = np.asarray(a, dtype=np.float64)
        if a.shape != (self.n, self.n):
            raise ValueError(f"matrix shape {a.shape} does not match the "
                             f"session's n={self.n}")
        return self._service._submit(self, "factor_solve",
                                     (a, materialize, self._rhs(b)))

    # -- sync facade (OOCSolver duck type) ---------------------------------
    def factor(self, a: np.ndarray, materialize: bool = False):
        return self.factor_async(a, materialize=materialize).result()

    def solve(self, b: np.ndarray) -> np.ndarray:
        return self.solve_async(b).result()

    def solve_lower(self, b: np.ndarray) -> np.ndarray:
        return self.solve_lower_async(b).result()

    def solve_batch(self, b: np.ndarray) -> np.ndarray:
        return self.solve_batch_async(b).result()

    def logdet(self) -> float:
        return self.logdet_async().result()

    def factor_solve(self, a: np.ndarray, b: np.ndarray,
                     materialize: bool = False):
        return self.factor_solve_async(a, b,
                                       materialize=materialize).result()

    def close(self) -> None:
        """Retire the session: queued work still drains, new submits
        raise, and the admission reservation is released once idle."""
        self._service._close_session(self)

    # -- validation --------------------------------------------------------
    def _rhs(self, b) -> np.ndarray:
        b = np.asarray(b)
        if b.dtype.kind not in "fiub":
            raise TypeError(f"rhs dtype {b.dtype} is not real-valued")
        if b.ndim not in (1, 2) or b.shape[0] != self.n \
                or (b.ndim == 2 and b.shape[1] == 0):
            raise ValueError(f"rhs shape {b.shape} does not match the "
                             f"session's n={self.n} (expect (n,) or (n, k))")
        return np.asarray(b, dtype=np.float64)

    @staticmethod
    def _cols(b) -> int:
        b = np.asarray(b)
        return b.shape[1] if b.ndim == 2 else 1


class SolverService:
    """Front end + worker pool over the plan cache; see module docstring.

    ``workers`` threads execute admitted work items; ``hw`` bounds the
    admitted set (None = unbounded); ``batch_window``/``max_batch``
    shape the solve coalescing (window 0 or max_batch 1 = the
    one-RHS-at-a-time baseline).  Use as a context manager or call
    :meth:`close`.
    """

    def __init__(self, workers: int = 4,
                 hw: Optional[HardwareModel] = None,
                 batch_window: float = 0.002, max_batch: int = 32):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if batch_window < 0:
            raise ValueError(f"batch_window must be >= 0 seconds, "
                             f"got {batch_window}")
        self._batch_window = batch_window
        self._max_batch = max_batch
        self.admission = AdmissionController(hw)
        self.metrics = ServiceMetrics()
        self._obs_source = self.metrics.snapshot
        try:
            from repro.obs.metrics import REGISTRY
            REGISTRY.register_source("serve", self._obs_source)
        except Exception:
            pass
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._sessions: dict = {}
        self._rr: List[str] = []      # round-robin key order
        self._rr_idx = 0
        self._stop = False
        self._threads = [
            threading.Thread(target=self._worker_loop, args=(i,),
                             name=f"repro-serve-w{i}", daemon=True)
            for i in range(workers)
        ]
        for t in self._threads:
            t.start()

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "SolverService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        """Drain queued work, then stop and join the workers."""
        with self._work:
            self._stop = True
            self._work.notify_all()
        for t in self._threads:
            t.join()
        try:
            from repro.obs.metrics import REGISTRY
            # fn-matched: a newer service that took the name keeps it
            REGISTRY.unregister_source("serve", self._obs_source)
        except Exception:
            pass

    # -- tenants -----------------------------------------------------------
    def session(self, key: str, n: int,
                config: Optional[CholeskyConfig] = None,
                **overrides) -> Session:
        """Open (or re-fetch) the tenant session ``key``.

        The static plan is built/fetched *here*, through the process-wide
        plan cache — same-shape tenants share it.  The config must be
        fully resolved (``tb > 0``, concrete policy, no ``eps_target``):
        serving cannot re-tune per request, so open dimensions are a
        caller decision (``repro.tune.tune`` or ``repro.plan`` resolve
        them ahead of session creation).
        """
        if config is None:
            config = CholeskyConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        if config.needs_tuning:
            raise ValueError(
                "serve sessions need a fully resolved config (tb > 0 and a "
                "concrete policy): resolve open dimensions first, e.g. "
                "cfg = repro.plan(n, cfg).config after autotuning")
        plan = _api.plan(n, config)
        with self._work:
            if self._stop:
                raise RuntimeError("service is closed")
            existing = self._sessions.get(key)
            if existing is not None:
                if existing.n != n or existing.config != plan.config:
                    raise ValueError(
                        f"session {key!r} already exists with n="
                        f"{existing.n} and a different config")
                return existing
            s = Session(self, key, n, plan.config, plan)
            self._sessions[key] = s
            self._rr.append(key)
            return s

    def _close_session(self, session: Session) -> None:
        with self._work:
            session._closed = True
            self._finish_session_locked(session)
            self._work.notify_all()

    def _finish_session_locked(self, session: Session) -> None:
        """Release a retired session's reservation once it has drained."""
        if (session._closed and not session._queue
                and not session._in_flight
                and session.key in self._sessions):
            self.admission.release(session.key)
            del self._sessions[session.key]
            self._rr.remove(session.key)

    # -- front end ---------------------------------------------------------
    def _submit(self, session: Session, kind: str, payload,
                k: int = 1) -> Future:
        fut: Future = Future()
        now = self.metrics.now()
        deadline = now + (self._batch_window if kind in BATCHABLE else 0.0)
        req = _Request(kind=kind, payload=payload, future=fut,
                       t_arrive=now, t_deadline=deadline, k=k)
        with self._work:
            if self._stop:
                raise RuntimeError("service is closed")
            if session._closed or session.key not in self._sessions:
                raise RuntimeError(f"session {session.key!r} is closed")
            try:
                self.admission.check_feasible(session._plan)
            except AdmissionError as e:
                self.metrics.on_reject(kind, session.key)
                fut.set_exception(e)
                return fut
            session._queue.append(req)
            depth = sum(len(s._queue) for s in self._sessions.values())
            self.metrics.on_submit(kind, depth)
            self._work.notify_all()
        return fut

    # -- dispatch ----------------------------------------------------------
    def _has_pending_locked(self) -> bool:
        return any(s._queue or s._in_flight
                   for s in self._sessions.values())

    def _next_item_locked(self) -> Tuple[Optional[tuple], Optional[float]]:
        """Round-robin pick of the next work item; ``(None, wait)`` when
        nothing is ready (wait = seconds until the nearest held-batch
        deadline, None = wait for a notify)."""
        best_wait = None
        nrr = len(self._rr)
        for off in range(nrr):
            idx = (self._rr_idx + off) % nrr
            s = self._sessions[self._rr[idx]]
            if s._in_flight or not s._queue:
                continue
            if not self.admission.try_reserve(s.key, s._plan):
                continue          # oversubscribed: keep queued
            now = self.metrics.now()
            count, hold = coalesce_head(
                s._queue, now, self._max_batch,
                # a closing service flushes held batches immediately
                0.0 if self._stop else self._batch_window)
            if count == 0:
                wait = max(hold - now, 0.0)
                best_wait = wait if best_wait is None \
                    else min(best_wait, wait)
                continue
            reqs = [s._queue.popleft() for _ in range(count)]
            self._rr_idx = (idx + 1) % max(nrr, 1)
            return (s, reqs), None
        return None, best_wait

    def _worker_loop(self, wid: int) -> None:
        while True:
            with self._work:
                while True:
                    item, wait = self._next_item_locked()
                    if item is not None:
                        break
                    if self._stop and not self._has_pending_locked():
                        return
                    self._work.wait(timeout=wait)
                session, reqs = item
                session._in_flight = True
            try:
                self._execute(wid, session, reqs)
            finally:
                with self._work:
                    session._in_flight = False
                    self._finish_session_locked(session)
                    self._work.notify_all()

    # -- execution (worker threads, no service lock held) ------------------
    def _ensure_solver(self, session: Session):
        if session._solver is None:
            session._solver = session._plan.compile()
            self.metrics.on_solver_compile()
        return session._solver

    def _require_factor(self, session: Session):
        if session._solver is None or not session._factored:
            raise RuntimeError(
                f"session {session.key!r} has no factor: submit factor() "
                f"(or factor_solve()) before solve()/logdet()")
        return session._solver

    def _execute(self, wid: int, session: Session,
                 reqs: List[_Request]) -> None:
        kind = reqs[0].kind
        reused = session._factored
        t_start = self.metrics.now()
        results: List[Any] = []        # per-request values, parallel to reqs
        error: Optional[Exception] = None
        try:
            if kind in ("factor", "factor_solve"):
                solver = self._ensure_solver(session)
                (a, materialize, *rest) = reqs[0].payload
                l = solver.factor(a, materialize=materialize)
                session._factored = True
                if kind == "factor_solve":
                    x = solver.solve(rest[0])
                    results = [(l, x) if materialize else x]
                else:
                    results = [l]
            elif kind in BATCHABLE:
                solver = self._require_factor(session)
                op = solver.solve if kind == "solve" else solver.solve_lower
                if len(reqs) == 1:
                    results = [op(reqs[0].payload)]
                else:
                    stacked, splits = stack_rhs([r.payload for r in reqs])
                    results = split_solutions(op(stacked), splits)
            elif kind == "logdet":
                solver = self._require_factor(session)
                results = [solver.logdet()]
            else:                                    # pragma: no cover
                raise AssertionError(f"unknown request kind {kind!r}")
        except Exception as e:  # noqa: BLE001 — fault isolation per batch
            error = e
        t_end = self.metrics.now()
        batch_k = sum(r.k for r in reqs)
        # metrics first, futures second: a client that wakes on its
        # future must already see its own request in snapshot()
        self.metrics.on_execute(
            wid,
            [RequestRecord(kind=r.kind, session=session.key, worker=wid,
                           k=r.k, batch_k=batch_k, t_arrive=r.t_arrive,
                           t_start=t_start, t_end=t_end, ok=error is None)
             for r in reqs],
            solve_batch=kind in BATCHABLE, reused_solver=reused)
        if error is not None:
            for r in reqs:
                r.future.set_exception(error)
        else:
            for r, value in zip(reqs, results):
                r.future.set_result(value)
